//! Policy parameter set: shapes from artifacts/meta.json, values owned by
//! the rust side (initialised here, updated by the train_step artifact),
//! persisted as a simple binary file.

use crate::util::json::Json;
use crate::util::Rng;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Named f32 arrays in the exact positional order of the HLO parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSet {
    pub names: Vec<String>,
    pub shapes: Vec<Vec<usize>>,
    pub values: Vec<Vec<f32>>,
}

impl ParamSet {
    /// Build from meta.json's `param_specs` with policy-style init
    /// (scaled normal for matrices — matching `model.init_params` — zero
    /// for vectors; the logits head is down-scaled for a near-uniform
    /// initial policy).
    pub fn init(meta: &Json, seed: u64) -> Result<ParamSet> {
        let specs = meta
            .get("param_specs")
            .and_then(|j| j.as_arr())
            .context("meta.json missing param_specs")?;
        let mut rng = Rng::new(seed);
        let mut names = Vec::new();
        let mut shapes = Vec::new();
        let mut values = Vec::new();
        for spec in specs {
            let name = spec.idx(0).and_then(|j| j.as_str())
                .context("param spec name")?.to_string();
            let shape: Vec<usize> = spec
                .idx(1)
                .and_then(|j| j.as_arr())
                .context("param spec shape")?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect();
            let n: usize = shape.iter().product();
            let vals = if shape.len() == 2 {
                let mut scale = (2.0 / shape[0] as f64).sqrt() as f32;
                if name == "wl" {
                    scale *= 0.01;
                }
                (0..n).map(|_| scale * rng.normal() as f32).collect()
            } else {
                vec![0.0f32; n]
            };
            names.push(name);
            shapes.push(shape);
            values.push(vals);
        }
        Ok(ParamSet { names, shapes, values })
    }

    pub fn num_params(&self) -> usize {
        self.values.iter().map(|v| v.len()).sum()
    }
}

const MAGIC: &[u8; 8] = b"QMMCPAR1";

/// Persist a parameter set (binary: magic, count, then per-array name
/// length/name/rank/dims/f32 data, little-endian).
pub fn save_params(p: &ParamSet, path: &Path) -> Result<()> {
    assert!(
        p.names.len() == p.values.len() && p.shapes.len() == p.values.len(),
        "ParamSet arrays misaligned: {} names / {} shapes / {} values",
        p.names.len(), p.shapes.len(), p.values.len()
    );
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&(p.values.len() as u32).to_le_bytes())?;
    for ((name, shape), values) in
        p.names.iter().zip(&p.shapes).zip(&p.values)
    {
        let name = name.as_bytes();
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name)?;
        f.write_all(&(shape.len() as u32).to_le_bytes())?;
        for &d in shape {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        let bytes: Vec<u8> =
            values.iter().flat_map(|v| v.to_le_bytes()).collect();
        f.write_all(&bytes)?;
    }
    Ok(())
}

pub fn load_params(path: &Path) -> Result<ParamSet> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {path:?}"))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a parameter file");
    }
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let count = u32::from_le_bytes(u32b) as usize;
    if count > 1024 {
        bail!("implausible param count {count}");
    }
    let mut out = ParamSet { names: vec![], shapes: vec![], values: vec![] };
    for _ in 0..count {
        f.read_exact(&mut u32b)?;
        let nlen = u32::from_le_bytes(u32b) as usize;
        let mut name = vec![0u8; nlen];
        f.read_exact(&mut name)?;
        f.read_exact(&mut u32b)?;
        let rank = u32::from_le_bytes(u32b) as usize;
        let mut shape = Vec::with_capacity(rank);
        let mut u64b = [0u8; 8];
        for _ in 0..rank {
            f.read_exact(&mut u64b)?;
            shape.push(u64::from_le_bytes(u64b) as usize);
        }
        let n: usize = shape.iter().product();
        let mut data = vec![0u8; n * 4];
        f.read_exact(&mut data)?;
        let values: Vec<f32> = data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.names.push(String::from_utf8(name)?);
        out.shapes.push(shape);
        out.values.push(values);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_meta() -> Json {
        Json::parse(
            r#"{"param_specs":[["w1",[4,8]],["b1",[8]],["wl",[8,3]]]}"#,
        )
        .unwrap()
    }

    #[test]
    fn init_shapes_and_scaling() {
        let p = ParamSet::init(&demo_meta(), 1).unwrap();
        assert_eq!(p.names, vec!["w1", "b1", "wl"]);
        assert_eq!(p.values[0].len(), 32);
        assert!(p.values[1].iter().all(|&v| v == 0.0));
        // wl is down-scaled 100x
        let w1_mag: f32 = p.values[0].iter().map(|v| v.abs()).sum::<f32>() / 32.0;
        let wl_mag: f32 = p.values[2].iter().map(|v| v.abs()).sum::<f32>() / 24.0;
        assert!(wl_mag < w1_mag / 10.0);
    }

    #[test]
    fn init_deterministic() {
        let a = ParamSet::init(&demo_meta(), 7).unwrap();
        let b = ParamSet::init(&demo_meta(), 7).unwrap();
        assert_eq!(a, b);
        let c = ParamSet::init(&demo_meta(), 8).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn save_load_roundtrip() {
        let p = ParamSet::init(&demo_meta(), 3).unwrap();
        let dir = std::env::temp_dir().join("qimeng_param_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.bin");
        save_params(&p, &path).unwrap();
        let q = load_params(&path).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("qimeng_param_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"not a param file").unwrap();
        assert!(load_params(&path).is_err());
    }
}
