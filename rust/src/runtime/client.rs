//! The PJRT client wrapper: compile HLO-text artifacts once, execute many.
//!
//! The real client (feature `pjrt`) drives the `xla` crate. That crate is
//! not vendored in the offline build environment, so the default build
//! compiles a **stub** with the same surface whose `load` always fails:
//! every caller already handles load failure (the eval harness falls back
//! to the greedy macro policy, `hotpath` prints SKIP, the PJRT
//! integration tests skip when artifacts are absent).

use super::params::ParamSet;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;

/// Parsed artifacts/meta.json.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub obs_dim: usize,
    pub act_dim: usize,
    pub hidden: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub num_params: usize,
    pub raw: Json,
}

impl ArtifactMeta {
    pub fn parse(raw: Json) -> Result<ArtifactMeta> {
        let cfg = raw.get("config").context("meta.json: no config")?;
        let g = |k: &str| -> Result<usize> {
            cfg.get(k).and_then(|j| j.as_usize())
                .with_context(|| format!("meta.json: config.{k}"))
        };
        Ok(ArtifactMeta {
            obs_dim: g("obs_dim")?,
            act_dim: g("act_dim")?,
            hidden: g("hidden")?,
            train_batch: g("train_batch")?,
            eval_batch: g("eval_batch")?,
            num_params: raw
                .get("num_params")
                .and_then(|j| j.as_usize())
                .context("meta.json: num_params")?,
            raw,
        })
    }
}

/// Adam optimizer state + step counter, shaped like the parameters.
#[derive(Clone, Debug)]
pub struct TrainState {
    pub params: ParamSet,
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    pub t: f32,
}

impl TrainState {
    pub fn new(params: ParamSet) -> TrainState {
        let zeros: Vec<Vec<f32>> =
            params.values.iter().map(|p| vec![0.0; p.len()]).collect();
        TrainState { m: zeros.clone(), v: zeros, params, t: 0.0 }
    }
}

/// One PPO minibatch, row-major.
pub struct TrainBatch<'a> {
    pub obs: &'a [f32],      // [B * obs_dim]
    pub mask: &'a [f32],     // [B * act_dim]
    pub act: &'a [i32],      // [B]
    pub old_logp: &'a [f32], // [B]
    pub adv: &'a [f32],      // [B]
    pub ret: &'a [f32],      // [B]
}

/// Compiled artifacts + the CPU PJRT client.
#[cfg(feature = "pjrt")]
pub struct PjrtRuntime {
    pub meta: ArtifactMeta,
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
fn literal_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), rows * cols);
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

#[cfg(feature = "pjrt")]
fn param_literal(values: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(values);
    if shape.len() <= 1 {
        Ok(lit)
    } else {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    /// Load and compile every artifact in `dir` (built by `make
    /// artifacts`).
    pub fn load(dir: &Path) -> Result<PjrtRuntime> {
        let meta_text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("read {dir:?}/meta.json — run `make artifacts`"))?;
        let meta = ArtifactMeta::parse(
            Json::parse(&meta_text).map_err(|e| anyhow::anyhow!("{e}"))?,
        )?;
        let client = xla::PjRtClient::cpu()?;
        let mut exes = HashMap::new();
        for name in ["policy_fwd_b1", "policy_fwd_b64", "train_step"] {
            let path: PathBuf = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            exes.insert(name.to_string(), client.compile(&comp)?);
        }
        Ok(PjrtRuntime { meta, client, exes })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Policy forward at batch 1 — the macro-thinking request path.
    /// Returns (logp[act_dim], value).
    pub fn fwd_b1(&self, params: &ParamSet, obs: &[f32], mask: &[f32])
                  -> Result<(Vec<f32>, f32)> {
        let (logp, value) = self.fwd(params, obs, mask, 1, "policy_fwd_b1")?;
        Ok((logp, value[0]))
    }

    /// Batched policy forward (batch = meta.eval_batch).
    pub fn fwd_batch(&self, params: &ParamSet, obs: &[f32], mask: &[f32])
                     -> Result<(Vec<f32>, Vec<f32>)> {
        self.fwd(params, obs, mask, self.meta.eval_batch, "policy_fwd_b64")
    }

    fn fwd(&self, params: &ParamSet, obs: &[f32], mask: &[f32], batch: usize,
           exe: &str) -> Result<(Vec<f32>, Vec<f32>)> {
        if obs.len() != batch * self.meta.obs_dim {
            bail!("obs length {} != {}x{}", obs.len(), batch, self.meta.obs_dim);
        }
        if mask.len() != batch * self.meta.act_dim {
            bail!("mask length {} != {}x{}", mask.len(), batch, self.meta.act_dim);
        }
        let mut args: Vec<xla::Literal> = Vec::with_capacity(params.values.len() + 2);
        for i in 0..params.values.len() {
            args.push(param_literal(&params.values[i], &params.shapes[i])?);
        }
        args.push(literal_2d(obs, batch, self.meta.obs_dim)?);
        args.push(literal_2d(mask, batch, self.meta.act_dim)?);
        let result = self.exes[exe].execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 2 {
            bail!("fwd returned {} outputs, expected 2", outs.len());
        }
        let logp = outs[0].to_vec::<f32>()?;
        let value = outs[1].to_vec::<f32>()?;
        Ok((logp, value))
    }

    /// One fused PPO+Adam update (batch = meta.train_batch). Returns the
    /// metrics vector [loss, pg_loss, v_loss, entropy, approx_kl,
    /// grad_norm] and replaces the train state in place.
    pub fn train_step(&self, state: &mut TrainState, batch: &TrainBatch)
                      -> Result<Vec<f32>> {
        let b = self.meta.train_batch;
        if batch.act.len() != b {
            bail!("train batch size {} != {}", batch.act.len(), b);
        }
        let np = state.params.values.len();
        let mut args: Vec<xla::Literal> = Vec::with_capacity(3 * np + 7);
        for i in 0..np {
            args.push(param_literal(&state.params.values[i],
                                    &state.params.shapes[i])?);
        }
        for i in 0..np {
            args.push(param_literal(&state.m[i], &state.params.shapes[i])?);
        }
        for i in 0..np {
            args.push(param_literal(&state.v[i], &state.params.shapes[i])?);
        }
        args.push(xla::Literal::scalar(state.t));
        args.push(literal_2d(batch.obs, b, self.meta.obs_dim)?);
        args.push(literal_2d(batch.mask, b, self.meta.act_dim)?);
        args.push(xla::Literal::vec1(batch.act));
        args.push(xla::Literal::vec1(batch.old_logp));
        args.push(xla::Literal::vec1(batch.adv));
        args.push(xla::Literal::vec1(batch.ret));
        let result = self.exes["train_step"].execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 3 * np + 1 {
            bail!("train_step returned {} outputs, expected {}", outs.len(),
                  3 * np + 1);
        }
        for (i, out) in outs.iter().take(np).enumerate() {
            state.params.values[i] = out.to_vec::<f32>()?;
        }
        for (i, out) in outs.iter().skip(np).take(np).enumerate() {
            state.m[i] = out.to_vec::<f32>()?;
        }
        for (i, out) in outs.iter().skip(2 * np).take(np).enumerate() {
            state.v[i] = out.to_vec::<f32>()?;
        }
        state.t += 1.0;
        let metrics = outs[3 * np].to_vec::<f32>()?;
        Ok(metrics)
    }
}

/// Stub runtime (default build): same surface, `load` always fails.
///
/// The struct is uninhabitable in practice — no constructor succeeds — so
/// the method bodies after `load` are unreachable; they exist to keep the
/// call sites (train loop, eval harness, benches, integration tests)
/// compiling unchanged.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtRuntime {
    pub meta: ArtifactMeta,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    pub fn load(dir: &Path) -> Result<PjrtRuntime> {
        bail!(
            "PJRT backend unavailable: built without the `pjrt` feature \
             (the `xla` crate is not vendored offline); artifacts dir {dir:?}"
        )
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn fwd_b1(&self, _params: &ParamSet, _obs: &[f32], _mask: &[f32])
                  -> Result<(Vec<f32>, f32)> {
        bail!("PJRT backend unavailable (stub build)")
    }

    pub fn fwd_batch(&self, _params: &ParamSet, _obs: &[f32], _mask: &[f32])
                     -> Result<(Vec<f32>, Vec<f32>)> {
        bail!("PJRT backend unavailable (stub build)")
    }

    pub fn train_step(&self, _state: &mut TrainState, _batch: &TrainBatch)
                      -> Result<Vec<f32>> {
        bail!("PJRT backend unavailable (stub build)")
    }
}

// PJRT integration tests live in rust/tests/runtime_pjrt.rs (they need
// `make artifacts` to have run).
