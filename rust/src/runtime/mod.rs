//! PJRT runtime: loads the HLO-text artifacts produced by `make artifacts`
//! and executes them on the CPU PJRT client. This is the **only** bridge
//! between the rust coordinator and the L2/L1 model — python never runs
//! at inference or training time.
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser re-assigns ids (see /opt/xla-example/README.md).

mod client;
mod params;

pub use client::{ArtifactMeta, PjrtRuntime, TrainBatch, TrainState};
pub use params::{load_params, save_params, ParamSet};
