//! Table formatting for the regenerated paper tables (plain text, aligned
//! columns — printed by `cargo bench` and the CLI).

use crate::eval::SuiteResult;

/// A simple aligned text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Standard metric row: Accuracy(%), fast1/fast2(%), Mean Speedup.
pub fn metric_cells(r: &SuiteResult, with_call_acc: bool) -> Vec<String> {
    let m = &r.metrics;
    let mut cells = vec![r.method.clone()];
    if with_call_acc {
        cells.push(format!("{:.2}", m.call_acc * 100.0));
    }
    cells.push(format!("{:.0}", m.exec_acc * 100.0));
    cells.push(format!("{:.0}/{:.0}", m.fast1 * 100.0, m.fast2 * 100.0));
    cells.push(format!("{:.2}", m.mean_speedup));
    cells
}

/// Write rendered tables to a results file (appended, with a timestamp
/// marker line the EXPERIMENTS.md references).
pub fn append_report(path: &std::path::Path, text: &str) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "{text}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Method", "Acc"]);
        t.row(vec!["short".into(), "1".into()]);
        t.row(vec!["a-much-longer-method".into(), "100".into()]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // header and rows align on the second column
        let col = lines[1].find("Acc").unwrap();
        assert!(lines[3].len() >= col);
        assert!(lines[4].contains("a-much-longer-method"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
