//! Deterministic fault injection for the sweep engine.
//!
//! A [`FaultPlan`] is a pure function from (site, key, attempt) to
//! "does this operation fail here?", seeded once per run
//! (`--inject-faults <seed>` / `QIMENG_FAULT_SEED`). Injection is off by
//! default — every site is behind an `Option<&FaultPlan>` check, so the
//! disabled path costs one branch — and when it is on, the decisions
//! depend only on stable identities (edge seed, segment index, record
//! bytes, unit key), never on thread interleaving or call order. Two runs
//! with the same plan inject the same faults at the same places.
//!
//! Injected failures are *classed*: transient faults (verif-trial flake,
//! segment I/O, sink write) unwind as a [`TransientFault`] payload via
//! [`std::panic::panic_any`] or surface as synthesized `io::Error`s, and
//! the unit retry loop in [`crate::eval::BatchRunner`] recognises the
//! class and retries with bounded backoff. Because an injected fault
//! fires on at most [`FaultPlan::burst`] consecutive attempts and the
//! retry budget (`--max-retries`, default 2) is at least that large, a
//! fault-injected sweep converges to the *same bytes* as a fault-free
//! one — the invariant the CI chaos job asserts end to end.
//!
//! Retry/recovery *counters* ([`FaultStats`]) are schedule-dependent —
//! with a shared edge memo, which worker pays for a flaky transition
//! varies with thread interleaving — but sweep *outcomes* are not.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Environment fallback for `--inject-faults <seed>`.
pub const FAULT_SEED_ENV: &str = "QIMENG_FAULT_SEED";
/// Abort the process after this many successful sink writes (the CI
/// chaos job's deterministic "kill partway" lever).
pub const FAULT_KILL_ENV: &str = "QIMENG_FAULT_KILL_AFTER";
/// Override the per-fault consecutive-failure burst (default 2).
pub const FAULT_BURST_ENV: &str = "QIMENG_FAULT_BURST";

/// Where a fault can be injected. `name()` is stable output — the
/// `--stats-json` `faults.injected` object and tests key on it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// A spurious dynamic-verification failure inside
    /// `OptimEnv::transition`, keyed by the edge seed.
    VerifFlake,
    /// A memo-store segment read error at warm start, keyed by segment
    /// index.
    SegmentRead,
    /// A memo-store segment write error at flush, keyed by segment
    /// index.
    SegmentWrite,
    /// A JSONL sink write error, keyed by the record bytes.
    SinkWrite,
    /// An explicit non-transient unit panic (`panic_unit`), used by the
    /// isolation tests; never fired by the seeded rate gate.
    UnitPanic,
}

pub const SITE_COUNT: usize = 5;

impl FaultSite {
    pub fn name(&self) -> &'static str {
        match self {
            FaultSite::VerifFlake => "verif-flake",
            FaultSite::SegmentRead => "segment-read",
            FaultSite::SegmentWrite => "segment-write",
            FaultSite::SinkWrite => "sink-write",
            FaultSite::UnitPanic => "unit-panic",
        }
    }

    pub fn all() -> [FaultSite; SITE_COUNT] {
        [
            FaultSite::VerifFlake,
            FaultSite::SegmentRead,
            FaultSite::SegmentWrite,
            FaultSite::SinkWrite,
            FaultSite::UnitPanic,
        ]
    }

    fn index(&self) -> usize {
        match self {
            FaultSite::VerifFlake => 0,
            FaultSite::SegmentRead => 1,
            FaultSite::SegmentWrite => 2,
            FaultSite::SinkWrite => 3,
            FaultSite::UnitPanic => 4,
        }
    }

    /// One in `rate()` keys is fault-gated (0 = never rate-gated).
    fn rate(&self) -> u64 {
        match self {
            FaultSite::VerifFlake => 16,
            FaultSite::SegmentRead => 4,
            FaultSite::SegmentWrite => 4,
            FaultSite::SinkWrite => 8,
            FaultSite::UnitPanic => 0,
        }
    }
}

/// The typed panic payload of an injected transient fault. Riding the
/// unwind channel means deep sites (the env stepper, three layers below
/// the batch loop) need no `Result` plumbing: the unit retry loop
/// downcasts the payload with [`classify`] and retries.
#[derive(Clone, Copy, Debug)]
pub struct TransientFault {
    pub site: FaultSite,
}

fn mix(a: u64, b: u64) -> u64 {
    let mut x = a ^ b.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

thread_local! {
    /// Which retry attempt of the current unit this worker thread is
    /// executing. Set by the batch retry loop so deep injection sites
    /// (the stepper) can stop firing once the attempt index reaches the
    /// fault's burst length.
    static ATTEMPT: Cell<u32> = const { Cell::new(0) };
}

/// Record the current unit attempt for this worker thread (see
/// [`FaultPlan::raise_if`]).
pub fn set_unit_attempt(attempt: u32) {
    ATTEMPT.with(|c| c.set(attempt));
}

pub fn unit_attempt() -> u32 {
    ATTEMPT.with(|c| c.get())
}

/// A seeded, deterministic fault schedule. See the module docs for the
/// decision function and the burst-vs-retry-budget invariant.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    burst: u32,
    panic_unit: Option<u64>,
    kill_after: Option<u64>,
    injected: [AtomicUsize; SITE_COUNT],
    sink_writes: AtomicUsize,
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            burst: 2,
            panic_unit: None,
            kill_after: None,
            injected: [(); SITE_COUNT].map(|_| AtomicUsize::new(0)),
            sink_writes: AtomicUsize::new(0),
        }
    }

    /// Build a plan from an optional CLI seed, falling back to
    /// `QIMENG_FAULT_SEED`, and picking up the kill/burst env knobs.
    /// `None` (no seed anywhere) means injection stays off.
    pub fn from_env_or(cli_seed: Option<u64>) -> Option<FaultPlan> {
        let seed = cli_seed.or_else(|| {
            std::env::var(FAULT_SEED_ENV).ok()?.parse().ok()
        })?;
        let mut plan = FaultPlan::new(seed);
        if let Some(k) =
            std::env::var(FAULT_KILL_ENV).ok().and_then(|v| v.parse().ok())
        {
            plan.kill_after = Some(k);
        }
        if let Some(b) =
            std::env::var(FAULT_BURST_ENV).ok().and_then(|v| v.parse().ok())
        {
            plan.burst = b;
        }
        Some(plan)
    }

    /// Maximum consecutive attempts one fault keeps failing. Keep this
    /// `<= max_retries` or injected faults become unit losses.
    pub fn burst(&self) -> u32 {
        self.burst
    }

    pub fn with_burst(mut self, burst: u32) -> FaultPlan {
        self.burst = burst.max(1);
        self
    }

    /// Arm a hard (non-transient) panic for exactly one unit key (see
    /// [`crate::eval::unit_fault_key`]).
    pub fn with_panic_unit(mut self, unit_key: u64) -> FaultPlan {
        self.panic_unit = Some(unit_key);
        self
    }

    pub fn with_kill_after(mut self, writes: u64) -> FaultPlan {
        self.kill_after = Some(writes);
        self
    }

    /// Does this fault fire at `(site, key)` on retry `attempt`? Gated
    /// keys fail their first `fail_count` attempts (`1..=burst`), then
    /// recover — so any retry budget `>= burst` clears every injected
    /// transient fault. Counts the injection when it fires.
    pub fn fires_at(&self, site: FaultSite, key: u64, attempt: u32) -> bool {
        let rate = site.rate();
        if rate == 0 {
            return false;
        }
        let h = mix(mix(self.seed, site.index() as u64), key);
        if h % rate != 0 {
            return false;
        }
        let fail_count = 1 + ((h >> 32) % self.burst.max(1) as u64) as u32;
        let fires = attempt < fail_count;
        if fires {
            self.injected[site.index()].fetch_add(1, Ordering::Relaxed);
        }
        fires
    }

    /// Unwind with a [`TransientFault`] payload if `(site, key)` is
    /// fault-gated on this thread's current unit attempt. The call sites
    /// are deep inside the env; the batch retry loop catches and
    /// classifies the payload.
    pub fn raise_if(&self, site: FaultSite, key: u64) {
        if self.fires_at(site, key, unit_attempt()) {
            std::panic::panic_any(TransientFault { site });
        }
    }

    /// Panic (non-transiently) if `unit_key` is the armed panic unit.
    pub fn raise_unit_panic_if(&self, unit_key: u64) {
        if self.panic_unit == Some(unit_key) {
            self.injected[FaultSite::UnitPanic.index()]
                .fetch_add(1, Ordering::Relaxed);
            panic!("injected unit panic (fault plan)");
        }
    }

    /// Count one successful sink write; abort the process once the
    /// `kill_after` budget is reached. Per-record flushing in the sink
    /// makes this a *deterministic* mid-run kill: the file holds exactly
    /// `kill_after` complete records when the process dies.
    pub fn note_sink_write(&self) {
        let n = self.sink_writes.fetch_add(1, Ordering::SeqCst) + 1;
        if self.kill_after == Some(n as u64) {
            eprintln!("fault plan: aborting after {n} sink writes");
            std::process::abort();
        }
    }

    /// How many times `site` injected a fault so far.
    pub fn injected(&self, site: FaultSite) -> usize {
        self.injected[site.index()].load(Ordering::Relaxed)
    }

    pub fn injected_total(&self) -> usize {
        self.injected.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

/// Downcast a caught panic payload to its fault class. `Some(site)`
/// means an injected transient fault (retry it); `None` means a real
/// panic (isolate and report it).
pub fn classify(payload: &(dyn std::any::Any + Send)) -> Option<FaultSite> {
    payload.downcast_ref::<TransientFault>().map(|t| t.site)
}

/// A stable human-readable message for a caught panic payload, for the
/// sink record's `error` field.
pub fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(t) = payload.downcast_ref::<TransientFault>() {
        return format!("injected transient fault at {}", t.site.name());
    }
    if let Some(s) = payload.downcast_ref::<&str>() {
        return (*s).to_string();
    }
    if let Some(s) = payload.downcast_ref::<String>() {
        return s.clone();
    }
    "opaque panic payload".to_string()
}

/// Deterministic jittered backoff before retry `attempt` of a unit:
/// exponential base (5, 10, 20, ... ms) plus a 0-4 ms jitter derived
/// from the unit seed — never from wall clock or thread identity.
pub fn backoff_ms(unit_seed: u64, attempt: u32) -> u64 {
    let base = 5u64 << attempt.min(6);
    base + mix(unit_seed, attempt as u64 + 1) % 5
}

/// Session-owned fault-tolerance counters: what the retry loop and the
/// degradation paths actually did. Always present (all-zero on a clean
/// run); surfaced by the `StatsRegistry` on stderr and in
/// `--stats-json` as the `faults` object.
#[derive(Debug, Default)]
pub struct FaultStats {
    panicked: AtomicUsize,
    retried: AtomicUsize,
    recovered: AtomicUsize,
    exhausted: AtomicUsize,
    sink_retries: AtomicUsize,
}

impl FaultStats {
    pub fn new() -> FaultStats {
        FaultStats::default()
    }

    /// A unit died with a non-transient panic and was isolated.
    pub fn note_panicked(&self) {
        self.panicked.fetch_add(1, Ordering::Relaxed);
    }

    /// A unit failed transiently and is being retried.
    pub fn note_retried(&self) {
        self.retried.fetch_add(1, Ordering::Relaxed);
    }

    /// A retried unit completed cleanly.
    pub fn note_recovered(&self) {
        self.recovered.fetch_add(1, Ordering::Relaxed);
    }

    /// A unit kept failing transiently past the retry budget.
    pub fn note_exhausted(&self) {
        self.exhausted.fetch_add(1, Ordering::Relaxed);
    }

    /// One sink write attempt failed and was retried in place.
    pub fn note_sink_retry(&self) {
        self.sink_retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn panicked(&self) -> usize {
        self.panicked.load(Ordering::Relaxed)
    }
    pub fn retried(&self) -> usize {
        self.retried.load(Ordering::Relaxed)
    }
    pub fn recovered(&self) -> usize {
        self.recovered.load(Ordering::Relaxed)
    }
    pub fn exhausted(&self) -> usize {
        self.exhausted.load(Ordering::Relaxed)
    }
    pub fn sink_retries(&self) -> usize {
        self.sink_retries.load(Ordering::Relaxed)
    }

    /// Anything nonzero? (Gates the stderr line.)
    pub fn any(&self) -> bool {
        self.panicked() + self.retried() + self.recovered()
            + self.exhausted() + self.sink_retries()
            > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_and_key_scoped() {
        let a = FaultPlan::new(0xFA17);
        let b = FaultPlan::new(0xFA17);
        let c = FaultPlan::new(0xFA18);
        let mut diverged = false;
        for key in 0..512u64 {
            let fa = a.fires_at(FaultSite::VerifFlake, key, 0);
            assert_eq!(fa, b.fires_at(FaultSite::VerifFlake, key, 0));
            if fa != c.fires_at(FaultSite::VerifFlake, key, 0) {
                diverged = true;
            }
        }
        assert!(diverged, "different seeds must schedule different faults");
    }

    #[test]
    fn rate_gate_fires_at_roughly_its_rate() {
        let plan = FaultPlan::new(7);
        let n = 4096u64;
        let fired = (0..n)
            .filter(|&k| plan.fires_at(FaultSite::SinkWrite, k, 0))
            .count();
        // 1/8 nominal; allow a generous band
        assert!(fired > 300 && fired < 800, "fired {fired}/{n}");
        assert_eq!(plan.injected(FaultSite::SinkWrite), fired);
    }

    #[test]
    fn every_gated_fault_recovers_within_burst_attempts() {
        let plan = FaultPlan::new(99).with_burst(2);
        for key in 0..2048u64 {
            for site in [FaultSite::VerifFlake, FaultSite::SinkWrite] {
                assert!(
                    !plan.fires_at(site, key, plan.burst()),
                    "site {} key {key} still fails at attempt {}",
                    site.name(),
                    plan.burst()
                );
            }
        }
    }

    #[test]
    fn fail_counts_are_monotone_in_attempt() {
        let plan = FaultPlan::new(3);
        for key in 0..1024u64 {
            let mut prev = true;
            for attempt in 0..4 {
                let now = plan.fires_at(FaultSite::VerifFlake, key, attempt);
                assert!(prev || !now, "fault resumed firing after recovery");
                prev = now;
            }
        }
    }

    #[test]
    fn classify_and_messages() {
        let caught = std::panic::catch_unwind(|| {
            std::panic::panic_any(TransientFault {
                site: FaultSite::VerifFlake,
            })
        })
        .unwrap_err();
        assert_eq!(classify(caught.as_ref()), Some(FaultSite::VerifFlake));
        assert_eq!(
            panic_msg(caught.as_ref()),
            "injected transient fault at verif-flake"
        );

        let caught =
            std::panic::catch_unwind(|| panic!("plain panic")).unwrap_err();
        assert_eq!(classify(caught.as_ref()), None);
        assert_eq!(panic_msg(caught.as_ref()), "plain panic");
    }

    #[test]
    fn panic_unit_is_exact_and_non_transient() {
        let plan = FaultPlan::new(0).with_panic_unit(42);
        plan.raise_unit_panic_if(41); // no-op
        let caught =
            std::panic::catch_unwind(|| plan.raise_unit_panic_if(42))
                .unwrap_err();
        assert_eq!(classify(caught.as_ref()), None);
        assert!(panic_msg(caught.as_ref()).contains("injected unit panic"));
        assert_eq!(plan.injected(FaultSite::UnitPanic), 1);
    }

    #[test]
    fn backoff_is_deterministic_and_bounded() {
        for attempt in 0..3 {
            assert_eq!(
                backoff_ms(0xAB, attempt),
                backoff_ms(0xAB, attempt),
                "jitter must derive from the seed"
            );
            let ms = backoff_ms(0xAB, attempt);
            let base = 5u64 << attempt;
            assert!((base..base + 5).contains(&ms), "attempt {attempt}: {ms}");
        }
    }

    #[test]
    fn unit_attempt_is_thread_local() {
        set_unit_attempt(2);
        assert_eq!(unit_attempt(), 2);
        let other = std::thread::spawn(unit_attempt).join().unwrap();
        assert_eq!(other, 0, "attempt state must not leak across threads");
        set_unit_attempt(0);
    }

    #[test]
    fn fault_stats_counters() {
        let fs = FaultStats::new();
        assert!(!fs.any());
        fs.note_retried();
        fs.note_recovered();
        fs.note_panicked();
        fs.note_exhausted();
        fs.note_sink_retry();
        assert_eq!(
            (fs.retried(), fs.recovered(), fs.panicked(), fs.exhausted(),
             fs.sink_retries()),
            (1, 1, 1, 1, 1)
        );
        assert!(fs.any());
    }
}
