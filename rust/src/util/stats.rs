//! Small statistics helpers used by the eval harness and bench runners:
//! mean / geomean / percentiles / stddev plus a wall-clock timing helper.

use std::time::Instant;

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Geometric mean of positive values (zeros clamped to `eps`).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let eps = 1e-12;
    (xs.iter().map(|x| x.max(eps).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Linear-interpolated percentile, q in [0, 100]. NaN-tolerant: samples
/// are ordered by IEEE `total_cmp` (NaNs sort above +inf) instead of a
/// panicking `partial_cmp().unwrap()`.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    percentile_of_sorted(&v, q)
}

/// [`percentile`] over an already-sorted (ascending) slice — lets callers
/// computing several percentiles sort once.
pub fn percentile_of_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Timing summary over repeated runs of `f` (used by the in-repo bench
/// harness — criterion is unavailable offline).
pub struct BenchStats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn per_iter_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "iters={} mean={:.3}ms p50={:.3}ms p95={:.3}ms min={:.3}ms",
            self.iters,
            self.mean_ns / 1e6,
            self.p50_ns / 1e6,
            self.p95_ns / 1e6,
            self.min_ns / 1e6
        )
    }
}

/// Run `f` repeatedly for at least `min_iters` and ~`budget_ms` total.
pub fn bench<F: FnMut()>(min_iters: usize, budget_ms: u64, mut f: F) -> BenchStats {
    // warmup
    f();
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters
        || (start.elapsed().as_millis() as u64) < budget_ms
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if samples.len() > 100_000 {
            break;
        }
    }
    // sort once; each percentile call used to clone + re-sort the samples
    samples.sort_by(|a, b| a.total_cmp(b));
    BenchStats {
        iters: samples.len(),
        mean_ns: mean(&samples),
        p50_ns: percentile_of_sorted(&samples, 50.0),
        p95_ns: percentile_of_sorted(&samples, 95.0),
        min_ns: samples.first().copied().unwrap_or(f64::INFINITY),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_geomean_percentile() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-9);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile_of_sorted(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_tolerates_nan() {
        // regression: partial_cmp().unwrap() used to panic on NaN input
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        // NaN sorts above +inf under total_cmp, so low quantiles are the
        // finite values and only the top of the range sees the NaN
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn sorted_variant_matches_unsorted() {
        let xs = [5.0, 1.0, 4.0, 2.0, 3.0];
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for q in [0.0, 25.0, 50.0, 90.0, 100.0] {
            assert_eq!(percentile(&xs, q), percentile_of_sorted(&sorted, q));
        }
    }

    #[test]
    fn bench_runs() {
        let mut n = 0u64;
        let s = bench(5, 1, || n = n.wrapping_add(1));
        assert!(s.iters >= 5);
        assert!(s.mean_ns >= 0.0);
    }
}
