//! Deterministic RNG: splitmix64 seeding + xoshiro256** core, plus the
//! distributions the simulator needs (uniform, normal, categorical from
//! log-probabilities, bernoulli, shuffles).
//!
//! Everything downstream (dataset generation, microcode competence draws,
//! PPO rollouts, baseline sweeps) threads an explicit [`Rng`] so every
//! experiment is reproducible from a single u64 seed.

/// xoshiro256** PRNG (public-domain algorithm by Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via splitmix64 (any u64, including 0, gives a good state).
    /// The first few xoshiro outputs are discarded: callers routinely use
    /// structured seeds (task-index XOR constants) whose low-entropy
    /// states bias the very first draw.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut rng = Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm),
                                splitmix64(&mut sm), splitmix64(&mut sm)] };
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Derive an independent stream (for per-task / per-thread splits).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (n << 2^64): modulo
        // bias is < 2^-40 for any n < 2^24, acceptable for a simulator.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform i64 in [lo, hi].
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Sample an index from unnormalised log-probabilities (the policy's
    /// action head). Uses the Gumbel-max trick: argmax(logp + G).
    pub fn categorical_logp(&mut self, logp: &[f32]) -> usize {
        let mut best = 0usize;
        let mut best_v = f64::NEG_INFINITY;
        for (i, &lp) in logp.iter().enumerate() {
            let g = -(-(self.f64().max(1e-300)).ln()).ln();
            let v = lp as f64 + g;
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best
    }

    /// Sample an index proportional to non-negative weights.
    pub fn weighted(&mut self, w: &[f64]) -> usize {
        let total: f64 = w.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, &wi) in w.iter().enumerate() {
            x -= wi;
            if x <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_construction() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn categorical_respects_masking_scale() {
        // ~-1e9 lanes must never be chosen.
        let mut r = Rng::new(3);
        let logp = [-1e9f32, 0.0, -1e9, -0.5];
        for _ in 0..500 {
            let k = r.categorical_logp(&logp);
            assert!(k == 1 || k == 3);
        }
    }

    #[test]
    fn categorical_frequencies_track_probs() {
        let mut r = Rng::new(5);
        // p = softmax([ln1, ln2, ln4]/...) -> 1/7, 2/7, 4/7
        let logp = [0.0f32, (2f32).ln(), (4f32).ln()];
        let mut c = [0usize; 3];
        let n = 30_000;
        for _ in 0..n {
            c[r.categorical_logp(&logp)] += 1;
        }
        let f: Vec<f64> = c.iter().map(|&x| x as f64 / n as f64).collect();
        assert!((f[0] - 1.0 / 7.0).abs() < 0.02);
        assert!((f[2] - 4.0 / 7.0).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
