//! Scoped parallel map over std threads (tokio unavailable offline; the
//! coordinator's concurrency needs are data-parallel sweeps, which scoped
//! threads express directly).

/// Apply `f` to every element of `items` across up to `threads` workers,
/// preserving order. `f` must be `Sync` (called from many threads).
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = Vec::new();
    slots.resize_with(items.len(), || None);
    let slots_ptr = std::sync::Mutex::new(&mut slots);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                let mut guard = slots_ptr.lock().unwrap();
                guard[i] = Some(r);
            });
        }
    });
    slots.into_iter().map(|s| s.unwrap()).collect()
}

/// Default worker count: physical parallelism minus one (leave a core for
/// the coordinator), at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_values() {
        let items: Vec<u64> = (0..257).collect();
        let out = par_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let items = vec![3u32];
        assert_eq!(par_map(&items, 4, |i, &x| (i, x)), vec![(0, 3)]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        let out: Vec<u32> = par_map(&items, 4, |_, &x| x);
        assert!(out.is_empty());
    }
}
