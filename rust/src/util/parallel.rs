//! Scoped parallel map over std threads (tokio unavailable offline; the
//! coordinator's concurrency needs are data-parallel sweeps, which scoped
//! threads express directly).

/// Apply `f` to every element of `items` across up to `threads` workers,
/// preserving order. `f` must be `Sync` (called from many threads).
///
/// Work distribution is a sharded queue: the output vector is split into
/// many small chunks (`~8` per worker) and workers pull whole chunks from
/// a shared iterator. The lock is held only to *take* the next chunk,
/// never while computing, and every result is written through the
/// worker's exclusively-owned `&mut` chunk — so result collection scales
/// with worker count. (The previous implementation took a global `Mutex`
/// around the whole slots vector for every single item, serializing all
/// writers on the hot path.)
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let mut slots: Vec<Option<R>> = Vec::new();
    slots.resize_with(items.len(), || None);
    // Small chunks keep dynamic load balance for heterogeneous items
    // (an L3 network prices ~30x slower than an L1 single op) while the
    // per-chunk handoff keeps queue contention negligible.
    let chunk = (items.len() / (threads * 8)).max(1);
    let queue = std::sync::Mutex::new(slots.chunks_mut(chunk).enumerate());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                // ChunksMut yields slices borrowing `slots`, not the
                // guard, so the chunk outlives the brief lock.
                let (ci, out) = {
                    let mut q = queue.lock().unwrap();
                    match q.next() {
                        Some(next) => next,
                        None => break,
                    }
                };
                let base = ci * chunk;
                for (off, slot) in out.iter_mut().enumerate() {
                    let i = base + off;
                    *slot = Some(f(i, &items[i]));
                }
            });
        }
    });
    // `queue` holds the ChunksMut borrow of `slots`; end it before the
    // collection below takes ownership.
    drop(queue);
    slots.into_iter().map(|s| s.unwrap()).collect()
}

/// Default worker count: physical parallelism minus one (leave a core for
/// the coordinator), at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_values() {
        let items: Vec<u64> = (0..257).collect();
        let out = par_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let items = vec![3u32];
        assert_eq!(par_map(&items, 4, |i, &x| (i, x)), vec![(0, 3)]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        let out: Vec<u32> = par_map(&items, 4, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let items: Vec<u32> = (0..3).collect();
        let out = par_map(&items, 64, |i, &x| (i as u32) * 100 + x);
        assert_eq!(out, vec![0, 101, 202]);
    }

    #[test]
    fn indices_match_positions() {
        let items: Vec<u32> = (0..1000).collect();
        let out = par_map(&items, 7, |i, &x| i as u32 == x);
        assert!(out.into_iter().all(|ok| ok));
    }

    #[test]
    fn uneven_chunk_tail_covered() {
        // len not divisible by the internal chunk size: every slot filled
        for len in [2usize, 17, 63, 64, 65, 129] {
            let items: Vec<usize> = (0..len).collect();
            let out = par_map(&items, 4, |i, &x| i + x);
            assert_eq!(out, (0..len).map(|i| 2 * i).collect::<Vec<_>>());
        }
    }
}
