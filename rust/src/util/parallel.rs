//! Scoped parallel map over std threads (tokio unavailable offline; the
//! coordinator's concurrency needs are data-parallel sweeps, which scoped
//! threads express directly).

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// What one panicking item yields from [`par_map_catch`]: the caught
/// panic payload, ready for `faults::classify`.
pub type CaughtPanic = Box<dyn Any + Send>;

/// Apply `f` to every element of `items` across up to `threads` workers,
/// preserving order. `f` must be `Sync` (called from many threads).
///
/// A panic in `f` propagates (via `resume_unwind`) after all workers
/// finish their queues — use [`par_map_catch`] when a panicking item
/// must be isolated instead of aborting the sweep.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let mut first_panic = None;
    let out: Vec<R> = par_map_catch(items, threads, f)
        .into_iter()
        .filter_map(|r| match r {
            Ok(v) => Some(v),
            Err(p) => {
                first_panic.get_or_insert(p);
                None
            }
        })
        .collect();
    if let Some(p) = first_panic {
        std::panic::resume_unwind(p);
    }
    out
}

/// [`par_map`] with per-item panic isolation: every element is wrapped
/// in `catch_unwind`, so one panicking item becomes an `Err(payload)`
/// in its output slot instead of poisoning the pool — sibling items
/// complete normally and keep their exact no-fault results. The chunk
/// queue lock is also taken poison-tolerantly, so even a panic in the
/// harness itself (outside the per-item guard) cannot cascade into
/// every other worker.
pub fn par_map_catch<T, R, F>(
    items: &[T],
    threads: usize,
    f: F,
) -> Vec<Result<R, CaughtPanic>>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let call = |i: usize, t: &T| catch_unwind(AssertUnwindSafe(|| f(i, t)));
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| call(i, t)).collect();
    }
    let mut slots: Vec<Option<Result<R, CaughtPanic>>> = Vec::new();
    slots.resize_with(items.len(), || None);
    // Small chunks keep dynamic load balance for heterogeneous items
    // (an L3 network prices ~30x slower than an L1 single op) while the
    // per-chunk handoff keeps queue contention negligible. The lock is
    // held only to *take* the next chunk, never while computing, and
    // every result is written through the worker's exclusively-owned
    // `&mut` chunk — so result collection scales with worker count.
    let chunk = (items.len() / (threads * 8)).max(1);
    let queue = std::sync::Mutex::new(slots.chunks_mut(chunk).enumerate());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                // ChunksMut yields slices borrowing `slots`, not the
                // guard, so the chunk outlives the brief lock.
                let (ci, out) = {
                    let mut q =
                        queue.lock().unwrap_or_else(|p| p.into_inner());
                    match q.next() {
                        Some(next) => next,
                        None => break,
                    }
                };
                let base = ci * chunk;
                for (off, slot) in out.iter_mut().enumerate() {
                    let i = base + off;
                    *slot = Some(call(i, &items[i]));
                }
            });
        }
    });
    // `queue` holds the ChunksMut borrow of `slots`; end it before the
    // collection below takes ownership.
    drop(queue);
    slots.into_iter().map(|s| s.unwrap()).collect()
}

/// Default worker count: physical parallelism minus one (leave a core for
/// the coordinator), at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_values() {
        let items: Vec<u64> = (0..257).collect();
        let out = par_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let items = vec![3u32];
        assert_eq!(par_map(&items, 4, |i, &x| (i, x)), vec![(0, 3)]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        let out: Vec<u32> = par_map(&items, 4, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let items: Vec<u32> = (0..3).collect();
        let out = par_map(&items, 64, |i, &x| (i as u32) * 100 + x);
        assert_eq!(out, vec![0, 101, 202]);
    }

    #[test]
    fn indices_match_positions() {
        let items: Vec<u32> = (0..1000).collect();
        let out = par_map(&items, 7, |i, &x| i as u32 == x);
        assert!(out.into_iter().all(|ok| ok));
    }

    #[test]
    fn uneven_chunk_tail_covered() {
        // len not divisible by the internal chunk size: every slot filled
        for len in [2usize, 17, 63, 64, 65, 129] {
            let items: Vec<usize> = (0..len).collect();
            let out = par_map(&items, 4, |i, &x| i + x);
            assert_eq!(out, (0..len).map(|i| 2 * i).collect::<Vec<_>>());
        }
    }

    /// The isolation contract: one panicking item lands in its own slot
    /// as `Err`, every sibling keeps its exact value, at any thread
    /// count (including the sequential path).
    #[test]
    fn catch_isolates_a_panicking_item() {
        for threads in [1usize, 4, 8] {
            let items: Vec<u32> = (0..100).collect();
            let out = par_map_catch(&items, threads, |_, &x| {
                if x == 37 {
                    panic!("boom {x}");
                }
                x * 3
            });
            assert_eq!(out.len(), 100);
            for (i, r) in out.into_iter().enumerate() {
                match r {
                    Ok(v) => {
                        assert_ne!(i, 37);
                        assert_eq!(v, i as u32 * 3);
                    }
                    Err(p) => {
                        assert_eq!(i, 37);
                        assert_eq!(
                            p.downcast_ref::<String>().map(String::as_str),
                            Some("boom 37")
                        );
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn plain_par_map_still_propagates_panics() {
        let items: Vec<u32> = (0..10).collect();
        par_map(&items, 4, |_, &x| {
            if x == 5 {
                panic!("boom");
            }
            x
        });
    }
}
