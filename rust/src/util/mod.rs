//! Offline-environment substrates: RNG, JSON, CLI parsing, stats, and a
//! scoped thread-pool. The vendored crate set has no `rand`/`serde`/`clap`,
//! so these are implemented in-repo (DESIGN.md system inventory).

pub mod rng;
pub mod json;
pub mod cli;
pub mod stats;
pub mod parallel;
pub mod faults;

pub use rng::Rng;
