//! Tiny CLI argument parser (no clap offline): subcommand + `--flag value`
//! pairs + `--switch` booleans.

use std::collections::BTreeMap;

/// Parsed command line: `repro <cmd> [--key value|--switch]...`.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub cmd: String,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.cmd = it.next().unwrap();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.flags.insert(name.to_string(), v);
                    }
                    _ => out.switches.push(name.to_string()),
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_flags_switches() {
        // note: a bare token after `--name` is consumed as its value, so
        // positionals go before switches (documented parser behaviour)
        let a = parse("eval --suite kernelbench --gpu A100 x.bin --verbose");
        assert_eq!(a.cmd, "eval");
        assert_eq!(a.get("suite"), Some("kernelbench"));
        assert_eq!(a.get("gpu"), Some("A100"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["x.bin"]);
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let a = parse("train --steps 500 --lr 0.0003");
        assert_eq!(a.usize_or("steps", 1), 500);
        assert_eq!(a.f64_or("lr", 1.0), 0.0003);
        assert_eq!(a.usize_or("missing", 9), 9);
    }

    #[test]
    fn empty_is_ok() {
        let a = parse("");
        assert_eq!(a.cmd, "");
    }
}
