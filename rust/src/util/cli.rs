//! Tiny CLI argument parser (no clap offline): subcommand + `--flag value`
//! pairs + `--switch` booleans.
//!
//! Boolean switches are recognized by a registry (the `--no-*` family
//! plus [`KNOWN_SWITCHES`]) so they never consume a following bare token
//! as a value — `eval --no-edge-memo out.jsonl` keeps both the switch
//! and the positional.

use std::collections::BTreeMap;

/// Boolean switches that take no value. Every `--no-*` flag is a switch
/// implicitly; anything else boolean must be listed here, or a following
/// bare token will be eaten as its value.
const KNOWN_SWITCHES: &[&str] =
    &["verbose", "show-code", "json", "fix", "resume"];

fn is_switch(name: &str) -> bool {
    name.starts_with("no-") || KNOWN_SWITCHES.contains(&name)
}

/// Parsed command line: `repro <cmd> [--key value|--switch]...`.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub cmd: String,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.cmd = it.next().unwrap();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if is_switch(name) {
                    out.switches.push(name.to_string());
                    continue;
                }
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.flags.insert(name.to_string(), v);
                    }
                    _ => out.switches.push(name.to_string()),
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_flags_switches() {
        let a = parse("eval --suite kernelbench --gpu A100 x.bin --verbose");
        assert_eq!(a.cmd, "eval");
        assert_eq!(a.get("suite"), Some("kernelbench"));
        assert_eq!(a.get("gpu"), Some("A100"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["x.bin"]);
    }

    /// The regression: a boolean switch must never consume a following
    /// bare token as its value (`--no-edge-memo out.jsonl` used to drop
    /// both the switch and the positional).
    #[test]
    fn switches_never_eat_a_following_positional() {
        let a = parse("eval --no-edge-memo out.jsonl");
        assert!(a.has("no-edge-memo"));
        assert_eq!(a.positional, vec!["out.jsonl"]);
        assert!(a.get("no-edge-memo").is_none());

        let a = parse("eval --verbose out.jsonl --no-cost-cache more.jsonl");
        assert!(a.has("verbose"));
        assert!(a.has("no-cost-cache"));
        assert_eq!(a.positional, vec!["out.jsonl", "more.jsonl"]);
    }

    /// Every switch-then-positional ordering round-trips: before flags,
    /// between flags, and trailing.
    #[test]
    fn switch_positional_orderings() {
        let a = parse("eval --show-code x.bin --suite kb1 --no-analysis-cache y.bin --verbose");
        assert_eq!(a.cmd, "eval");
        assert!(a.has("show-code"));
        assert!(a.has("no-analysis-cache"));
        assert!(a.has("verbose"));
        assert_eq!(a.get("suite"), Some("kb1"));
        assert_eq!(a.positional, vec!["x.bin", "y.bin"]);
    }

    /// Value-taking flags still consume their argument; an unknown
    /// `--flag` followed by another `--flag` still parses as a switch.
    #[test]
    fn value_flags_still_take_values() {
        let a = parse("eval --memo-store shared.store --limit 3 --dry-run --verbose");
        assert_eq!(a.get("memo-store"), Some("shared.store"));
        assert_eq!(a.usize_or("limit", 0), 3);
        // unknown non-registry flag followed by another `--flag`:
        // degrades to a switch, exactly as before
        assert!(a.has("dry-run"));
        assert!(a.has("verbose"));
    }

    /// `lint --json` and `store fsck --fix` are boolean: neither may eat
    /// a following bare token (the store path, typically).
    #[test]
    fn json_and_fix_are_switches() {
        let a = parse("store fsck --fix data/edges.store --json");
        assert_eq!(a.cmd, "store");
        assert!(a.has("fix"));
        assert!(a.has("json"));
        assert_eq!(a.positional, vec!["fsck", "data/edges.store"]);
    }

    /// `--resume` is boolean: `eval --resume out.jsonl` must keep both
    /// the switch and the positional (the sink path, typically).
    #[test]
    fn resume_is_a_switch() {
        let a = parse("eval --resume out.jsonl --max-retries 3");
        assert!(a.has("resume"));
        assert_eq!(a.positional, vec!["out.jsonl"]);
        assert_eq!(a.usize_or("max-retries", 2), 3);
        assert!(a.get("resume").is_none());
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let a = parse("train --steps 500 --lr 0.0003");
        assert_eq!(a.usize_or("steps", 1), 500);
        assert_eq!(a.f64_or("lr", 1.0), 0.0003);
        assert_eq!(a.usize_or("missing", 9), 9);
    }

    #[test]
    fn empty_is_ok() {
        let a = parse("");
        assert_eq!(a.cmd, "");
    }
}
