//! Minimal JSON: a value model, a recursive-descent parser and a writer.
//!
//! Used for `artifacts/meta.json` (written by the python AOT step), run
//! configs, and the experiment report emitters. No serde facade is
//! available offline, so this stays small and dependency-free.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are f64 (ints round-trip exactly to 2^53 which is
/// far beyond anything we store).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(thiserror::Error, Debug)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // -- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self { Json::Num(v) }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self { Json::Num(v as f64) }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self { Json::Str(v.to_string()) }
}
impl From<String> for Json {
    fn from(v: String) -> Self { Json::Str(v) }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self { Json::Bool(v) }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos..self.pos + 4],
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = &self.b[self.pos..];
                    let st = std::str::from_utf8(s)
                        .map_err(|_| self.err("bad utf8"))?;
                    let ch = st.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true},
                      "e": null}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(),
                   Some("x\ny"));
    }

    #[test]
    fn parses_meta_like_document() {
        let src = r#"{"config":{"obs_dim":64,"act_dim":65,"lr":0.0003},
                      "param_specs":[["w1",[64,128]],["b1",[128]]]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("config").unwrap().get("obs_dim").unwrap()
                       .as_usize(), Some(64));
        let specs = v.get("param_specs").unwrap().as_arr().unwrap();
        assert_eq!(specs[0].idx(0).unwrap().as_str(), Some("w1"));
        assert_eq!(specs[0].idx(1).unwrap().idx(1).unwrap().as_usize(),
                   Some(128));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn int_display_is_integral() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }
}
