//! Benchmark evaluation harness: runs every method (baseline LLM
//! profiles, MTMC variants, ablations) over the task suites and computes
//! the paper's metrics (execute/call accuracy, fast_1/fast_2, mean
//! speedup vs PyTorch Eager).

mod metrics;
mod methods;
mod harness;
mod batch;

pub use batch::{
    roster_sweep, unit_fault_key, BatchCfg, BatchJob, BatchRunner, JsonlSink,
};
pub use harness::{evaluate, evaluate_in, evaluate_task,
                  greedy_best_action_excluding, EvalCfg, SuiteResult,
                  TaskResult};
pub use methods::{
    table3_methods, table4_methods, table6_variants, MacroKind, Method,
};
pub use metrics::{aggregate, Metrics};
