//! Benchmark evaluation harness: runs every method (baseline LLM
//! profiles, MTMC variants, ablations) over the task suites and computes
//! the paper's metrics (execute/call accuracy, fast_1/fast_2, mean
//! speedup vs PyTorch Eager).

mod metrics;
mod methods;
mod harness;

pub use harness::{evaluate, EvalCfg, SuiteResult, TaskResult};
pub use methods::{table3_methods, table4_methods, MacroKind, Method};
pub use metrics::{aggregate, Metrics};
