//! The paper's metrics (§5.1): Call Accuracy, Execute Accuracy, fast_p,
//! Mean Speedup.

/// Per-suite aggregated metrics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Metrics {
    /// Fraction that compiled and ran ("Call Accuracy", TritonBench).
    pub call_acc: f64,
    /// Fraction that produced correct results ("Execute Accuracy").
    pub exec_acc: f64,
    /// fast_1: correct AND speedup > 1 over eager.
    pub fast1: f64,
    /// fast_2: correct AND speedup > 2.
    pub fast2: f64,
    /// Arithmetic mean of speedups (incorrect kernels contribute 0).
    pub mean_speedup: f64,
    pub n_tasks: usize,
}

/// One task's outcome for one method.
#[derive(Clone, Debug)]
pub struct TaskOutcome {
    pub task_id: String,
    pub compiled: bool,
    pub correct: bool,
    /// Speedup vs eager of the produced kernel (whatever it computes);
    /// metric aggregation zeroes it when incorrect.
    pub speedup: f64,
}

/// Aggregate per-task outcomes (Eq. 3-4 of the paper).
pub fn aggregate(outcomes: &[TaskOutcome]) -> Metrics {
    let n = outcomes.len().max(1) as f64;
    let call = outcomes.iter().filter(|o| o.compiled).count() as f64;
    let exec = outcomes.iter().filter(|o| o.correct).count() as f64;
    let fast1 = outcomes
        .iter()
        .filter(|o| o.correct && o.speedup > 1.0)
        .count() as f64;
    let fast2 = outcomes
        .iter()
        .filter(|o| o.correct && o.speedup > 2.0)
        .count() as f64;
    let mean_speedup = outcomes
        .iter()
        .map(|o| if o.correct { o.speedup } else { 0.0 })
        .sum::<f64>()
        / n;
    Metrics {
        call_acc: call / n,
        exec_acc: exec / n,
        fast1: fast1 / n,
        fast2: fast2 / n,
        mean_speedup,
        n_tasks: outcomes.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn o(compiled: bool, correct: bool, speedup: f64) -> TaskOutcome {
        TaskOutcome { task_id: "t".into(), compiled, correct, speedup }
    }

    #[test]
    fn aggregation_matches_paper_formulas() {
        let outcomes = vec![
            o(true, true, 2.5),   // fast1+fast2
            o(true, true, 1.2),   // fast1
            o(true, false, 9.0),  // wrong: speedup zeroed
            o(false, false, 0.0), // compile fail
        ];
        let m = aggregate(&outcomes);
        assert_eq!(m.call_acc, 0.75);
        assert_eq!(m.exec_acc, 0.5);
        assert_eq!(m.fast1, 0.5);
        assert_eq!(m.fast2, 0.25);
        assert!((m.mean_speedup - (2.5 + 1.2) / 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_zero() {
        let m = aggregate(&[]);
        assert_eq!(m.exec_acc, 0.0);
        assert_eq!(m.mean_speedup, 0.0);
    }

    #[test]
    fn incorrect_fast_kernels_do_not_count() {
        let m = aggregate(&[o(true, false, 5.0)]);
        assert_eq!(m.fast1, 0.0);
        assert_eq!(m.mean_speedup, 0.0);
    }
}
