//! The evaluation harness: method × suite × GPU -> metrics.

use super::metrics::{aggregate, Metrics, TaskOutcome};
use super::methods::{MacroKind, Method};
use crate::engine::Session;
use crate::env::{EnvConfig, OptimEnv};
use crate::gpusim::{GpuSpec, Pricer};
use crate::microcode::{
    check_correct, single_pass_generate, CheckOutcome, LlmProfile, ProfileId,
    SinglePassMode, SinglePassOutcome,
};
use crate::policy::{FreeformPolicy, HeuristicPolicy, Policy, PjrtPolicy,
                    RandomPolicy};
use crate::runtime::{load_params, PjrtRuntime};
use crate::tasks::{Suite, Task};
use crate::transform::{
    apply_action_with, decode_action, Analyzer, STOP_ACTION,
};
use crate::util::{parallel::par_map, Rng};

/// Harness configuration. Cache policy and persistence no longer live
/// here: all shared evaluation state (the memo trio, the `--memo-store`
/// tier, stats) flows through the [`Session`] handed to [`evaluate_in`].
#[derive(Clone, Debug)]
pub struct EvalCfg {
    pub seed: u64,
    pub threads: usize,
    pub env: EnvConfig,
    /// Target language is CUDA (Table 5).
    pub cuda: bool,
}

impl Default for EvalCfg {
    fn default() -> Self {
        EvalCfg {
            seed: 0xE7A1,
            threads: crate::util::parallel::default_threads(),
            env: EnvConfig::default(),
            cuda: false,
        }
    }
}

/// Result of one method over one suite.
#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub method: String,
    pub suite: &'static str,
    pub gpu: &'static str,
    pub metrics: Metrics,
    pub outcomes: Vec<TaskOutcome>,
}

pub type TaskResult = TaskOutcome;

/// Suite interface difficulty: TritonBench's real-world (G) and
/// PyTorch-aligned (T) interfaces are substantially harder to hit than
/// KernelBench's (calibration constants; see EXPERIMENTS.md §Calibration).
fn suite_difficulty(suite: Suite) -> f64 {
    match suite {
        Suite::TritonG => 1.3,
        Suite::TritonT => 1.2,
        _ => 1.0,
    }
}

/// Probability a generated kernel's *interface* matches TritonBench's
/// harness (signature conventions, launch wrappers, pointer vs tensor
/// calling styles). The paper's per-model exec accuracies on TritonBench
/// cluster at ~0.2-0.3x of their KernelBench accuracies — an interface
/// gate largely independent of model strength.
fn suite_interface_pass(suite: Suite) -> f64 {
    match suite {
        Suite::TritonG => 0.25,
        Suite::TritonT => 0.32,
        _ => 1.0,
    }
}

/// On TritonBench, failures overwhelmingly manifest as *call* failures
/// (interface/signature mismatches) rather than silent numeric bugs —
/// the paper's call-accuracy columns sit only a few points above execute
/// accuracy. KernelBench keeps each model's own compile/silent split.
fn suite_compile_frac(suite: Suite) -> Option<f64> {
    match suite {
        Suite::TritonG | Suite::TritonT => Some(0.9),
        _ => None,
    }
}

/// Base final-assembly failure probability per suite for MTMC runs:
/// KernelBench interfaces are trivial; TritonBench-T's PyTorch-aligned
/// signatures and -G's real-world harnesses gate a large fraction of
/// otherwise-correct kernels (paper: MTMC exec acc 54.8% on T, 22.8% on G
/// with near-perfect KernelBench L1-2).
fn suite_assembly_base(suite: Suite) -> f64 {
    match suite {
        Suite::TritonG => 0.76,
        Suite::TritonT => 0.42,
        _ => 0.0,
    }
}

/// KernelLLM's out-of-distribution collapse on TritonBench (paper §5.2
/// "severe degradation ... accuracy from 40-50% to 2-4%").
fn ood_multiplier(profile: ProfileId, suite: Suite) -> f64 {
    match (profile, suite) {
        (ProfileId::KernelLlm, Suite::TritonG | Suite::TritonT) => 1.5,
        (ProfileId::Kevin32B, Suite::TritonG | Suite::TritonT) => 1.4,
        _ => 1.0,
    }
}

fn effective_profile(profile: ProfileId, suite: Suite) -> LlmProfile {
    let mut p = LlmProfile::get(profile)
        .scaled(suite_difficulty(suite) * ood_multiplier(profile, suite));
    if let Some(cf) = suite_compile_frac(suite) {
        p.compile_frac = cf;
    }
    p
}

/// MTMC final-assembly risk: after the stepwise loop, the micro-coder
/// still has to assemble the full kernel file (imports, launch glue,
/// multi-kernel orchestration). Risk grows quadratically with graph size —
/// negligible for single ops, material for whole networks (the paper's
/// ~70% L3 accuracy).
fn assembly_error_prob(profile: &LlmProfile, op_count: usize,
                       suite: Suite) -> f64 {
    let size_risk = LlmProfile::get(profile.id).atomic_err
        * (op_count as f64 / 4.2).powf(2.2);
    (suite_assembly_base(suite) + size_risk).min(0.80)
}

/// Evaluate one method over a task set with a private, fully-cached
/// [`Session`] (the default configuration). Convenience over
/// [`evaluate_in`] for one-shot calls; for caches shared across many
/// calls — or any cache policy / persistence at all — build a Session
/// and use [`evaluate_in`] or drive [`crate::eval::BatchRunner`].
pub fn evaluate(method: &Method, tasks: &[Task], spec: &GpuSpec,
                cfg: &EvalCfg) -> SuiteResult {
    evaluate_in(method, tasks, spec, cfg, &Session::default())
}

/// Evaluate one method over a task set. Pricing, program analysis and
/// transitions route through the [`Session`]'s memo trio (whichever
/// tiers its policy enables); outcomes are bit-identical for every cache
/// combination.
pub fn evaluate_in(method: &Method, tasks: &[Task], spec: &GpuSpec,
                   cfg: &EvalCfg, session: &Session) -> SuiteResult {
    let outcomes: Vec<TaskOutcome> = match method {
        // The learned-policy path needs the (non-Sync) PJRT runtime: run
        // it sequentially; every other method parallelises over tasks
        // through the per-unit entry point below.
        Method::Mtmc {
            macro_kind: MacroKind::LearnedOrGreedy { params_path },
            micro,
        } => {
            let loaded = params_path.as_ref().and_then(|pp| {
                let arts = crate::paths::artifacts_dir();
                match (load_params(pp), PjrtRuntime::load(&arts)) {
                    (Ok(params), Ok(rt)) => Some((params, rt)),
                    _ => None,
                }
            });
            match loaded {
                Some((params, rt)) => tasks
                    .iter()
                    .enumerate()
                    .map(|(ti, task)| {
                        let mut policy = PjrtPolicy::new(&rt, params.clone(), false);
                        mtmc_task(&mut MacroRunner::ObsPolicy(&mut policy),
                                  *micro, task, spec, cfg, ti as u64, session)
                    })
                    .collect(),
                None => par_map(tasks, cfg.threads, |ti, task| {
                    evaluate_task(method, task, ti as u64, spec, cfg, session)
                }),
            }
        }
        _ => par_map(tasks, cfg.threads, |ti, task| {
            evaluate_task(method, task, ti as u64, spec, cfg, session)
        }),
    };
    SuiteResult {
        method: method.label(),
        suite: tasks.first().map_or("empty", |t| t.suite.label()),
        gpu: spec.name,
        metrics: aggregate(&outcomes),
        outcomes,
    }
}

/// Evaluate a single (method, task) unit — the [`crate::eval::BatchRunner`]
/// work item. `ti` is the task's index within its suite: it seeds the
/// per-task RNG streams, so calling this with suite-order indices
/// reproduces [`evaluate`] outcome-for-outcome regardless of thread count.
/// `session` carries the sweep's shared memo trio — pricing, program
/// analysis, and the transition transposition table (a session with all
/// tiers disabled runs everything cold; the outcome is bit-identical
/// either way).
///
/// The one divergence: `MacroKind::LearnedOrGreedy` always uses the greedy
/// cost-model surrogate here (the PJRT runtime is not `Sync`, so the
/// learned policy cannot be driven from a sharded work queue; the greedy
/// lookahead is the objective the policy converges to — see
/// EXPERIMENTS.md).
pub fn evaluate_task(method: &Method, task: &Task, ti: u64, spec: &GpuSpec,
                     cfg: &EvalCfg, session: &Session) -> TaskOutcome {
    match method {
        Method::Baseline { profile } => {
            baseline_task(*profile, task, spec, cfg, ti, session)
        }
        Method::MtmcNoHier { micro } => {
            no_hier_task(*micro, task, spec, cfg, ti, session)
        }
        Method::Mtmc { macro_kind, micro } => match macro_kind {
            MacroKind::LearnedOrGreedy { .. } | MacroKind::GreedyLookahead => {
                mtmc_task(&mut MacroRunner::Greedy, *micro, task, spec, cfg,
                          ti, session)
            }
            MacroKind::Heuristic { label, mistake_rate } => {
                let mut p = HeuristicPolicy::new(label, *mistake_rate, 4);
                mtmc_task(&mut MacroRunner::ObsPolicy(&mut p), *micro, task,
                          spec, cfg, ti, session)
            }
            MacroKind::Freeform { label, wildness, mistake_rate } => {
                let mut p = FreeformPolicy::new(label, *wildness, *mistake_rate);
                mtmc_task_scaled(&mut MacroRunner::ObsPolicy(&mut p), *micro,
                                 task, spec, cfg, ti, 2.2, session)
            }
            MacroKind::Random => {
                let mut p = RandomPolicy;
                mtmc_task(&mut MacroRunner::ObsPolicy(&mut p), *micro, task,
                          spec, cfg, ti, session)
            }
            MacroKind::Scripted(plan) => {
                mtmc_task(&mut MacroRunner::Scripted(plan.clone()), *micro,
                          task, spec, cfg, ti, session)
            }
        },
    }
}

// ------------------------------------------------------------ baselines

fn baseline_task(profile: ProfileId, task: &Task, spec: &GpuSpec,
                 cfg: &EvalCfg, ti: u64,
                 session: &Session) -> TaskOutcome {
    let prof = effective_profile(profile, task.suite);
    let shapes = crate::graph::infer_shapes(&task.graph);
    let pricer = Pricer::new(session.cost(), &task.graph, &shapes);
    let mut rng = Rng::new(cfg.seed ^ (ti << 17) ^ 0xBA5E);
    // interface gate (TritonBench only): a mismatch is a call failure
    // with high probability regardless of the kernel body
    if !rng.bool(suite_interface_pass(task.suite)) {
        return TaskOutcome {
            task_id: task.id.clone(),
            compiled: rng.bool(0.1),
            correct: false,
            speedup: 0.0,
        };
    }
    match single_pass_generate(&task.graph, &shapes, &prof, spec,
                               &SinglePassMode::Freeform, cfg.cuda, &mut rng) {
        SinglePassOutcome::CompileError => TaskOutcome {
            task_id: task.id.clone(),
            compiled: false,
            correct: false,
            speedup: 0.0,
        },
        SinglePassOutcome::Generated(p) => {
            score_program(&p, task, &shapes, spec, cfg, ti, &pricer)
        }
    }
}

fn score_program(p: &crate::kir::Program, task: &Task,
                 shapes: &[Vec<usize>], spec: &GpuSpec, cfg: &EvalCfg,
                 ti: u64, pricer: &Pricer) -> TaskOutcome {
    let correct = check_correct(p, &task.verif_graph, cfg.env.verif_trials,
                                cfg.seed ^ ti ^ 0xC4EC) == CheckOutcome::Correct;
    let affinity = crate::gpusim::library_affinity(&task.id);
    let eager = pricer.eager_time_us(&task.graph, shapes, spec, affinity);
    let speedup = eager / pricer.program_time_us(p, &task.graph, shapes, spec);
    TaskOutcome {
        task_id: task.id.clone(),
        compiled: true,
        correct,
        speedup: if correct { speedup } else { 0.0 },
    }
}

// ---------------------------------------------------------- w/o hier

/// Table 6: derive the greedy plan (what Macro Thinking would do), then
/// hand ALL of it to the LLM in a single prompt.
fn no_hier_task(micro: ProfileId, task: &Task, spec: &GpuSpec, cfg: &EvalCfg,
                ti: u64, session: &Session) -> TaskOutcome {
    let prof = effective_profile(micro, task.suite);
    let shapes = crate::graph::infer_shapes(&task.graph);
    let pricer = Pricer::new(session.cost(), &task.graph, &shapes);
    let analyzer = Analyzer::new(session.analysis(), &task.graph, &shapes);
    let plan = greedy_plan(task, &shapes, spec, cfg.env.max_steps, &pricer,
                           &analyzer);
    let mut rng = Rng::new(cfg.seed ^ (ti << 13) ^ 0x0441E4);
    match single_pass_generate(&task.graph, &shapes, &prof, spec,
                               &SinglePassMode::AllActionsAtOnce(plan),
                               cfg.cuda, &mut rng) {
        SinglePassOutcome::CompileError => TaskOutcome {
            task_id: task.id.clone(),
            compiled: false,
            correct: false,
            speedup: 0.0,
        },
        SinglePassOutcome::Generated(p) => {
            score_program(&p, task, &shapes, spec, cfg, ti, &pricer)
        }
    }
}

/// Greedy cost-model plan: repeatedly apply the valid action with the
/// best one-step time improvement (>1%).
fn greedy_plan(task: &Task, shapes: &[Vec<usize>], spec: &GpuSpec,
               max_steps: usize, pricer: &Pricer, analyzer: &Analyzer)
               -> Vec<crate::transform::Action> {
    let mut p = crate::kir::lower_naive(&task.graph);
    let mut plan = Vec::new();
    for _ in 0..max_steps {
        match greedy_best_action(&p, task, shapes, spec, pricer, analyzer) {
            Some((a, next)) => {
                plan.push(decode_action(a));
                p = next;
            }
            None => break,
        }
    }
    plan
}

/// Best one-step improvement, or None if nothing improves > 1%.
fn greedy_best_action(p: &crate::kir::Program, task: &Task,
                      shapes: &[Vec<usize>], spec: &GpuSpec, pricer: &Pricer,
                      analyzer: &Analyzer)
                      -> Option<(usize, crate::kir::Program)> {
    greedy_best_action_excluding(p, task, shapes, spec, &Default::default(),
                                 pricer, analyzer)
}

/// Greedy selection skipping edges that already failed in this episode
/// (the tree env is edge-deterministic: a failed micro-coding never
/// succeeds on retry, and the paper's policy likewise learns to move on).
///
/// This is the stepping hot path: every step prices every valid candidate
/// one lookahead deep. Two memos carry it: candidates differ from the
/// current program in exactly one kernel, so pricing through the
/// [`Pricer`]'s per-kernel memo re-computes only the mutated kernel — the
/// untouched siblings hit the cache (and so does `now`, re-priced every
/// step of the episode) — and the state's region analysis + action mask
/// come once from the [`Analyzer`], shared by every candidate instead of
/// being re-derived per `apply_action` call.
pub fn greedy_best_action_excluding(
    p: &crate::kir::Program, task: &Task, shapes: &[Vec<usize>],
    spec: &GpuSpec, exclude: &std::collections::HashSet<usize>,
    pricer: &Pricer, analyzer: &Analyzer,
) -> Option<(usize, crate::kir::Program)> {
    let now = pricer.program_time_us(p, &task.graph, shapes, spec);
    let regions = analyzer.regions(p, &task.graph);
    let mask = analyzer.mask(p, &task.graph, shapes, spec);
    let mut best: Option<(usize, crate::kir::Program)> = None;
    let mut best_t = f64::INFINITY;
    for a in 0..STOP_ACTION {
        if !mask[a] || exclude.contains(&a) {
            continue;
        }
        if let Ok(next) = apply_action_with(p, &task.graph, shapes, &regions,
                                            &decode_action(a), spec, 1.0)
        {
            let t = pricer.program_time_us(&next, &task.graph, shapes, spec);
            if t < now * 0.99 && t < best_t {
                best = Some((a, next));
                best_t = t;
            }
        }
    }
    best
}

// ---------------------------------------------------------------- MTMC

enum MacroRunner<'a> {
    Greedy,
    ObsPolicy(&'a mut dyn Policy),
    Scripted(Vec<crate::transform::Action>),
}

/// Run one MTMC episode on a task, then the final-assembly check.
fn mtmc_task(runner: &mut MacroRunner, micro: ProfileId, task: &Task,
             spec: &GpuSpec, cfg: &EvalCfg, ti: u64,
             session: &Session) -> TaskOutcome {
    mtmc_task_scaled(runner, micro, task, spec, cfg, ti, 1.0, session)
}

/// `micro_err_mult` > 1 models macro proposals arriving *without* the
/// action-space prompt template (paper Fig. 2: the action prompt carries
/// curated examples per optimization type — freeform suggestions don't).
#[allow(clippy::too_many_arguments)]
fn mtmc_task_scaled(runner: &mut MacroRunner, micro: ProfileId, task: &Task,
                    spec: &GpuSpec, cfg: &EvalCfg, ti: u64,
                    micro_err_mult: f64,
                    session: &Session) -> TaskOutcome {
    let prof = effective_profile(micro, task.suite).scaled(micro_err_mult);
    let mut env = OptimEnv::with_session(
        task, spec.clone(), prof.clone(),
        EnvConfig { cuda: cfg.cuda, ..cfg.env.clone() },
        cfg.seed ^ (ti << 21) ^ 0x47C0, session);
    let mut rng = Rng::new(cfg.seed ^ (ti << 9) ^ 0x9097);
    let mut scripted_idx = 0usize;
    // failed edges at the *current* tree node (cleared when state moves)
    let mut failed_here: std::collections::HashSet<usize> =
        Default::default();
    while !env.state.done {
        // the env is edge-deterministic: a failed edge never succeeds on
        // retry, so mask failed edges out for EVERY runner (Stop stays
        // valid) — not just the greedy one
        let mut mask = env.mask();
        for &a in &failed_here {
            if a < STOP_ACTION {
                mask[a] = false;
            }
        }
        let action = match runner {
            MacroRunner::Greedy => {
                match greedy_best_action_excluding(&env.state.program, task,
                                                   &env.shapes, spec,
                                                   &failed_here,
                                                   &env.pricer,
                                                   &env.analyzer) {
                    Some((a, _)) => a,
                    None => STOP_ACTION,
                }
            }
            MacroRunner::ObsPolicy(policy) => {
                let obs = env.observe(&mask);
                policy.act(&obs, &mask, &mut rng).action
            }
            MacroRunner::Scripted(plan) => loop {
                let a = plan
                    .get(scripted_idx)
                    .map(crate::transform::encode_action)
                    .unwrap_or(STOP_ACTION);
                scripted_idx += 1;
                // skip plan entries over known-failed edges instead of
                // burning a deterministic failure on them
                if a == STOP_ACTION || !failed_here.contains(&a) {
                    break a;
                }
            },
        };
        // freeform proposals may be invalid: the env rejects them
        let action = if action < mask.len() { action } else { STOP_ACTION };
        let before = env.state.path_hash;
        let _ = env.step(action);
        if env.state.path_hash == before {
            failed_here.insert(action); // step failed, don't retry the edge
        } else {
            failed_here.clear(); // new tree node
        }
    }
    // final assembly: integrating the optimized kernels into the full
    // runnable file — risk grows with graph size
    let op_count = task.graph.op_count();
    let mut asm_rng = Rng::new(cfg.seed ^ (ti << 5) ^ 0xA55E);
    if asm_rng.bool(assembly_error_prob(&prof, op_count, task.suite)) {
        // assembly failures are mostly call failures (~80%)
        let compiled = asm_rng.bool(0.2);
        return TaskOutcome {
            task_id: task.id.clone(),
            compiled,
            correct: false,
            speedup: 0.0,
        };
    }
    let best = env.state.best_program.clone();
    score_program(&best, task, &env.shapes, spec, cfg, ti, &env.pricer)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::PolicyDecision;
    use crate::tasks::kernelbench_level;

    fn small_suite() -> Vec<Task> {
        kernelbench_level(2)[..10].to_vec()
    }

    /// Plays a fixed action plan (then Stop), recording every mask it was
    /// offered — lets tests observe what the episode loop exposes.
    struct ProbePolicy {
        plan: Vec<usize>,
        masks: Vec<Vec<bool>>,
    }

    impl Policy for ProbePolicy {
        fn act(&mut self, _obs: &[f32], mask: &[bool], _rng: &mut Rng)
               -> PolicyDecision {
            self.masks.push(mask.to_vec());
            let action = self
                .plan
                .get(self.masks.len() - 1)
                .copied()
                .unwrap_or(STOP_ACTION);
            PolicyDecision { action, logp: 0.0, value: 0.0 }
        }

        fn name(&self) -> String {
            "probe".into()
        }
    }

    /// Regression: `failed_here` used to be honored only by the greedy
    /// runner — observation-driven policies (heuristic/random/freeform)
    /// could retry a deterministically-failed edge all episode. Now the
    /// episode loop masks failed edges out of every runner's view.
    #[test]
    fn failed_edges_are_masked_out_for_policy_runners() {
        let tasks = small_suite();
        let task = &tasks[0];
        let spec = GpuSpec::a100();
        let mult = 40.0; // drive micro-coding error to its cap
        for seed in 0..64u64 {
            let cfg = EvalCfg { seed, threads: 1, ..Default::default() };
            // replicate the episode env (ti = 0) to find a seed whose
            // first valid edge deterministically fails
            let prof =
                effective_profile(ProfileId::Gpt4o, task.suite).scaled(mult);
            let mut env = OptimEnv::new(
                task, spec.clone(), prof,
                EnvConfig { cuda: cfg.cuda, ..cfg.env.clone() },
                cfg.seed ^ 0x47C0);
            let mask0 = env.mask();
            let a = (0..STOP_ACTION).find(|&i| mask0[i]).unwrap();
            let before = env.state.path_hash;
            env.step(a);
            if env.state.path_hash != before {
                continue; // edge succeeded at this seed; try another
            }
            let mut probe = ProbePolicy { plan: vec![a], masks: Vec::new() };
            let cold = Session::builder()
                .cost_cache(false)
                .analysis_cache(false)
                .edge_memo(false)
                .build();
            mtmc_task_scaled(&mut MacroRunner::ObsPolicy(&mut probe),
                             ProfileId::Gpt4o, task, &spec, &cfg, 0, mult,
                             &cold);
            assert!(probe.masks.len() >= 2, "episode ended after one step");
            assert!(probe.masks[0][a], "first offer must include the edge");
            assert!(!probe.masks[1][a],
                    "a deterministically-failed edge was offered again");
            return;
        }
        panic!("no failing first edge in 64 seeds at capped error rate");
    }

    #[test]
    fn mtmc_greedy_beats_weak_baseline() {
        let tasks = small_suite();
        let spec = GpuSpec::a100();
        let cfg = EvalCfg { threads: 4, ..Default::default() };
        let mtmc = evaluate(
            &Method::Mtmc {
                macro_kind: MacroKind::GreedyLookahead,
                micro: ProfileId::GeminiPro25,
            },
            &tasks, &spec, &cfg,
        );
        let weak = evaluate(
            &Method::Baseline { profile: ProfileId::Gpt4o },
            &tasks, &spec, &cfg,
        );
        assert!(mtmc.metrics.exec_acc > weak.metrics.exec_acc + 0.2,
                "mtmc {:?} weak {:?}", mtmc.metrics, weak.metrics);
        assert!(mtmc.metrics.mean_speedup > weak.metrics.mean_speedup);
    }

    #[test]
    fn mtmc_l2_is_fast_and_accurate() {
        let tasks = small_suite();
        let spec = GpuSpec::a100();
        let cfg = EvalCfg { threads: 4, ..Default::default() };
        let r = evaluate(
            &Method::Mtmc {
                macro_kind: MacroKind::GreedyLookahead,
                micro: ProfileId::GeminiPro25,
            },
            &tasks, &spec, &cfg,
        );
        // 10-task sample: allow a couple of assembly-risk losses
        assert!(r.metrics.exec_acc >= 0.7, "{:?}", r.metrics);
        assert!(r.metrics.mean_speedup > 0.9, "{:?}", r.metrics);
    }

    #[test]
    fn evaluation_is_deterministic() {
        let tasks = small_suite();
        let spec = GpuSpec::v100();
        let cfg = EvalCfg { threads: 2, ..Default::default() };
        let m = Method::Baseline { profile: ProfileId::DeepSeekR1 };
        let a = evaluate(&m, &tasks, &spec, &cfg);
        let b = evaluate(&m, &tasks, &spec, &cfg);
        assert_eq!(a.metrics, b.metrics);
    }

    #[test]
    fn tritonbench_harder_than_kernelbench() {
        let kb = kernelbench_level(1)[..12].to_vec();
        let tb: Vec<Task> = crate::tasks::tritonbench_g()[..12].to_vec();
        let spec = GpuSpec::a100();
        let cfg = EvalCfg { threads: 4, ..Default::default() };
        let m = Method::Baseline { profile: ProfileId::GeminiPro25 };
        let r_kb = evaluate(&m, &kb, &spec, &cfg);
        let r_tb = evaluate(&m, &tb, &spec, &cfg);
        assert!(r_tb.metrics.exec_acc < r_kb.metrics.exec_acc,
                "kb {:?} tb {:?}", r_kb.metrics, r_tb.metrics);
    }
}
