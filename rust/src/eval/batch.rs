//! Batched parallel evaluation engine.
//!
//! The paper tables sweep method × suite × GPU; the old flow parallelised
//! only *within* one `evaluate` call, so a sweep ran its (method, suite)
//! cells back-to-back and the pool drained at every cell boundary. The
//! [`BatchRunner`] flattens a whole sweep into (method, suite, gpu, task)
//! **units** and runs them through one sharded work queue
//! ([`crate::util::parallel::par_map`]), so heavy batch traffic keeps
//! every worker busy end-to-end.
//!
//! Cross-cutting services:
//! - per-task outcomes stream to a JSON-lines sink ([`JsonlSink`], built
//!   on [`crate::util::json`]) as units complete, so a long sweep is
//!   observable and resumable downstream;
//! - the sweep's redundant work rides the [`Session`]'s thread-safe memo
//!   trio: the `CostCache` is the pricing engine (env steps,
//!   greedy-lookahead candidate pricing, eager baselines — (task, gpu)
//!   pairs repeat across methods and lookahead siblings share kernels),
//!   the `AnalysisCache` de-duplicates region analysis / action masks
//!   per program state, and the `EdgeMemo` transposition table replays
//!   whole env transitions across methods, repeated sweeps and threads
//!   (methods that walk the same trees — e.g. the greedy surrogate under
//!   several labels — pay for each micro-coding transition once). Cache
//!   policy, `--memo-store` persistence and stats all live on the
//!   Session; sink records are enriched with the memoized eager baseline.
//!
//! Determinism: unit seeds derive from (job seed, task index) exactly as
//! in [`super::evaluate`], never from thread identity — and every memo
//! stores only deterministic pure/edge-deterministic results — so results
//! are byte-identical across `threads = 1` and `threads = N` and across
//! every cache on/off combination (guarded by `rust/tests/batch.rs`).

use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use super::harness::{evaluate_task, EvalCfg, SuiteResult};
use super::metrics::{aggregate, TaskOutcome};
use super::methods::{MacroKind, Method};
use crate::engine::Session;
use crate::gpusim::{library_affinity, GpuSpec, Pricer};
use crate::graph::infer_shapes;
use crate::tasks::Task;
use crate::util::json::Json;
use crate::util::parallel::{default_threads, par_map};

/// One (method, suite, gpu) sweep cell: the tasks fan out into units.
/// Tasks are `Arc`-shared — a roster sweep points many jobs at the same
/// suite slice without cloning every task graph per method.
#[derive(Clone, Debug)]
pub struct BatchJob {
    pub method: Method,
    pub gpu: GpuSpec,
    pub tasks: Arc<Vec<Task>>,
    /// Per-job harness config (seed, env, target language). The `threads`
    /// field is ignored here — [`BatchCfg::threads`] owns parallelism.
    pub cfg: EvalCfg,
}

impl BatchJob {
    pub fn new(method: Method, gpu: GpuSpec, tasks: Vec<Task>) -> BatchJob {
        Self::shared(method, gpu, Arc::new(tasks))
    }

    /// Construct against an already-shared task slice (no clone).
    pub fn shared(method: Method, gpu: GpuSpec, tasks: Arc<Vec<Task>>)
                  -> BatchJob {
        BatchJob { method, gpu, tasks, cfg: EvalCfg::default() }
    }
}

/// Runner configuration.
#[derive(Clone, Debug)]
pub struct BatchCfg {
    /// Worker count for the sharded unit queue.
    pub threads: usize,
    /// Optional JSON-lines output path for per-task outcome records.
    pub sink: Option<PathBuf>,
}

impl Default for BatchCfg {
    fn default() -> Self {
        BatchCfg { threads: default_threads(), sink: None }
    }
}

/// Append-only JSON-lines writer shared across workers. The lock is held
/// per line; records are written in completion order (each carries its
/// job/task identity, so order never carries meaning). I/O errors are
/// reported to stderr once (first failure) and surfaced via
/// [`JsonlSink::failed`] — a sweep never aborts mid-flight on a full
/// disk, but the truncation is loud, not silent.
pub struct JsonlSink {
    w: Mutex<BufWriter<std::fs::File>>,
    write_failed: std::sync::atomic::AtomicBool,
}

impl JsonlSink {
    pub fn create(path: &Path) -> anyhow::Result<JsonlSink> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        Ok(JsonlSink {
            w: Mutex::new(BufWriter::new(std::fs::File::create(path)?)),
            write_failed: std::sync::atomic::AtomicBool::new(false),
        })
    }

    fn note_failure(&self, what: &str, e: &std::io::Error) {
        use std::sync::atomic::Ordering;
        if !self.write_failed.swap(true, Ordering::Relaxed) {
            eprintln!(
                "[batch] JSONL sink {what} failed ({e}); later records may \
                 be missing — treat the output as truncated"
            );
        }
    }

    pub fn write(&self, v: &Json) {
        let mut g = self.w.lock().unwrap();
        if let Err(e) = writeln!(g, "{v}") {
            drop(g);
            self.note_failure("write", &e);
        }
    }

    pub fn flush(&self) {
        let r = self.w.lock().unwrap().flush();
        if let Err(e) = r {
            self.note_failure("flush", &e);
        }
    }

    /// True if any write or flush failed since creation.
    pub fn failed(&self) -> bool {
        self.write_failed.load(std::sync::atomic::Ordering::Relaxed)
    }
}

/// The batched evaluation engine. Construct once per sweep over a
/// [`Session`]: the session's memo trio persists across
/// [`BatchRunner::run`] calls (and across runners), so repeated sweeps
/// replay from warm tables; cache policy, `--memo-store` warm-start/flush
/// and the stats registry are the session's job, not the runner's. A
/// sweep replayed entirely from a warm store performs no inserts, so the
/// session's end-of-run flush skips every segment (`written_segments: 0`
/// in `--stats-json` — the dirty-skip fast path CI asserts on).
pub struct BatchRunner<'s> {
    threads: usize,
    session: &'s Session,
    sink: Option<JsonlSink>,
}

impl<'s> BatchRunner<'s> {
    pub fn new(cfg: BatchCfg, session: &'s Session)
               -> anyhow::Result<BatchRunner<'s>> {
        let sink = match &cfg.sink {
            Some(path) => Some(JsonlSink::create(path)?),
            None => None,
        };
        Ok(BatchRunner { threads: cfg.threads.max(1), session, sink })
    }

    /// The session whose memo trio this runner sweeps through.
    pub fn session(&self) -> &'s Session {
        self.session
    }

    /// True if a configured JSONL sink dropped any record (I/O error).
    /// Callers that script on exit codes should fail the run when set.
    pub fn sink_failed(&self) -> bool {
        self.sink.as_ref().is_some_and(|s| s.failed())
    }

    /// Run a sweep: every job's tasks become units on one work queue.
    /// Returns one [`SuiteResult`] per job, in job order.
    pub fn run(&self, jobs: &[BatchJob]) -> Vec<SuiteResult> {
        // Batched mode drives every macro decision through the greedy
        // cost-model surrogate (see `evaluate_task`); say so once rather
        // than silently re-attributing learned-policy rows.
        if jobs.iter().any(|j| matches!(
            &j.method,
            Method::Mtmc {
                macro_kind: MacroKind::LearnedOrGreedy { params_path: Some(_) },
                ..
            }
        )) {
            eprintln!(
                "[batch] note: LearnedOrGreedy methods use the greedy \
                 cost-model surrogate in batched mode (the PJRT runtime is \
                 not Sync); run eval::evaluate for the learned policy"
            );
        }
        let units: Vec<(usize, usize)> = jobs
            .iter()
            .enumerate()
            .flat_map(|(ji, j)| (0..j.tasks.len()).map(move |ti| (ji, ti)))
            .collect();
        let evaluated: Vec<(usize, TaskOutcome)> =
            par_map(&units, self.threads, |_, &(ji, ti)| {
                let job = &jobs[ji];
                let task = &job.tasks[ti];
                // the session's memo trio serves the whole unit (env
                // steps, greedy lookahead, eager baselines, transition
                // replays) — whichever tiers its policy enables; outcomes
                // are bit-identical for every combination
                let outcome = evaluate_task(&job.method, task, ti as u64,
                                            &job.gpu, &job.cfg, self.session);
                if let Some(sink) = &self.sink {
                    // enrich the streamed record with the task's eager
                    // baseline — (task, gpu) pairs repeat across every
                    // method of a sweep, so this is almost always a cache
                    // hit; skipped entirely when nothing consumes it
                    let shapes = infer_shapes(&task.graph);
                    let eager_us = Pricer::new(self.session.cost(),
                                               &task.graph, &shapes)
                        .eager_time_us(&task.graph, &shapes, &job.gpu,
                                       library_affinity(&task.id));
                    sink.write(&unit_record(ji, job, task, &outcome, eager_us));
                }
                (ji, outcome)
            });
        if let Some(sink) = &self.sink {
            sink.flush();
        }
        let mut per_job: Vec<Vec<TaskOutcome>> =
            jobs.iter().map(|_| Vec::new()).collect();
        for (ji, outcome) in evaluated {
            per_job[ji].push(outcome);
        }
        jobs.iter()
            .zip(per_job)
            .map(|(job, outcomes)| SuiteResult {
                method: job.method.label(),
                suite: job.tasks.first().map_or("empty", |t| t.suite.label()),
                gpu: job.gpu.name,
                metrics: aggregate(&outcomes),
                outcomes,
            })
            .collect()
    }
}

/// Build the jobs for a rectangular roster sweep: one job per
/// ((gpu, tasks) block, method), block-major. Slice [`BatchRunner::run`]'s
/// results as `results[bi * methods.len()..(bi + 1) * methods.len()]` to
/// recover block `bi`'s rows in roster order. Shared by the table benches
/// and the `repro table` subcommand so the two cannot drift. Each block's
/// tasks are cloned once and `Arc`-shared across the whole roster.
pub fn roster_sweep(methods: &[Method], blocks: &[(GpuSpec, Vec<Task>)])
                    -> Vec<BatchJob> {
    let mut jobs = Vec::with_capacity(methods.len() * blocks.len());
    for (gpu, tasks) in blocks {
        let shared = Arc::new(tasks.clone());
        for m in methods {
            jobs.push(BatchJob::shared(m.clone(), gpu.clone(),
                                       Arc::clone(&shared)));
        }
    }
    jobs
}

fn unit_record(ji: usize, job: &BatchJob, task: &Task, o: &TaskOutcome,
               eager_us: f64) -> Json {
    Json::obj(vec![
        ("job", Json::from(ji)),
        ("method", Json::from(job.method.label())),
        ("suite", Json::from(task.suite.label())),
        ("gpu", Json::from(job.gpu.name)),
        ("task", Json::from(task.id.clone())),
        ("compiled", Json::from(o.compiled)),
        ("correct", Json::from(o.correct)),
        ("speedup", Json::from(o.speedup)),
        ("eager_us", Json::from(eager_us)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{evaluate, MacroKind};
    use crate::microcode::ProfileId;
    use crate::tasks::kernelbench_level;

    fn jobs_small() -> Vec<BatchJob> {
        let tasks = kernelbench_level(1)[..6].to_vec();
        vec![
            BatchJob::new(
                Method::Baseline { profile: ProfileId::GeminiPro25 },
                GpuSpec::a100(),
                tasks.clone(),
            ),
            BatchJob::new(
                Method::Mtmc {
                    macro_kind: MacroKind::GreedyLookahead,
                    micro: ProfileId::GeminiFlash25,
                },
                GpuSpec::v100(),
                tasks,
            ),
        ]
    }

    #[test]
    fn matches_unbatched_evaluate() {
        let jobs = jobs_small();
        let session = Session::default();
        let runner =
            BatchRunner::new(BatchCfg { threads: 4, sink: None }, &session)
                .unwrap();
        let batched = runner.run(&jobs);
        for (job, got) in jobs.iter().zip(&batched) {
            let direct = evaluate(&job.method, &job.tasks, &job.gpu, &job.cfg);
            assert_eq!(got.metrics, direct.metrics,
                       "job {} diverged from evaluate()", got.method);
            assert_eq!(got.suite, direct.suite);
            assert_eq!(got.gpu, direct.gpu);
        }
    }

    #[test]
    fn sink_streams_one_record_per_unit() {
        let dir = std::env::temp_dir().join("qimeng_batch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("records.jsonl");
        let jobs = jobs_small();
        let n_units: usize = jobs.iter().map(|j| j.tasks.len()).sum();
        let session = Session::default();
        let runner = BatchRunner::new(
            BatchCfg { threads: 3, sink: Some(path.clone()) },
            &session,
        )
        .unwrap();
        runner.run(&jobs);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), n_units);
        for line in lines {
            let v = Json::parse(line).unwrap();
            assert!(v.get("task").and_then(|j| j.as_str()).is_some());
            assert!(v.get("speedup").and_then(|j| j.as_f64()).is_some());
            assert!(v.get("eager_us").and_then(|j| j.as_f64())
                .is_some_and(|e| e > 0.0));
        }
    }

    #[test]
    fn cache_hits_accumulate_across_methods() {
        let dir = std::env::temp_dir().join("qimeng_batch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let jobs = jobs_small();
        let session = Session::default();
        let runner = BatchRunner::new(
            BatchCfg { threads: 2, sink: Some(dir.join("cache_hits.jsonl")) },
            &session,
        )
        .unwrap();
        runner.run(&jobs);
        let (h1, m1) = session.cost().unwrap().stats();
        // greedy-lookahead pricing alone guarantees warm traffic within
        // the first sweep (the current program is re-priced every step)
        assert!(h1 > 0, "no cache hits in a greedy-lookahead sweep");
        // both jobs share the same 6 tasks but differ in GPU, so the
        // second sweep re-prices only cached (task, gpu) pairs
        runner.run(&jobs);
        let (h2, m2) = session.cost().unwrap().stats();
        assert_eq!(m2, m1, "second sweep must be all hits");
        assert!(h2 >= jobs.iter().map(|j| j.tasks.len()).sum::<usize>());
    }

    #[test]
    fn roster_sweep_block_major_order() {
        let tasks = kernelbench_level(1)[..3].to_vec();
        let methods = vec![
            Method::Baseline { profile: ProfileId::GeminiPro25 },
            Method::Baseline { profile: ProfileId::Gpt4o },
        ];
        let blocks = vec![
            (GpuSpec::a100(), tasks.clone()),
            (GpuSpec::v100(), tasks),
        ];
        let jobs = roster_sweep(&methods, &blocks);
        assert_eq!(jobs.len(), 4);
        assert_eq!(jobs[0].gpu.name, "A100");
        assert_eq!(jobs[1].gpu.name, "A100");
        assert_eq!(jobs[2].gpu.name, "V100");
        assert_eq!(jobs[0].method.label(), jobs[2].method.label());
    }
}
