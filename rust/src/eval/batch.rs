//! Batched parallel evaluation engine.
//!
//! The paper tables sweep method × suite × GPU; the old flow parallelised
//! only *within* one `evaluate` call, so a sweep ran its (method, suite)
//! cells back-to-back and the pool drained at every cell boundary. The
//! [`BatchRunner`] flattens a whole sweep into (method, suite, gpu, task)
//! **units** and runs them through one sharded work queue
//! ([`crate::util::parallel::par_map`]), so heavy batch traffic keeps
//! every worker busy end-to-end.
//!
//! Cross-cutting services:
//! - per-task outcomes stream to a JSON-lines sink ([`JsonlSink`], built
//!   on [`crate::util::json`]) as units complete, so a long sweep is
//!   observable and resumable downstream;
//! - the sweep's redundant work rides the [`Session`]'s thread-safe memo
//!   trio: the `CostCache` is the pricing engine (env steps,
//!   greedy-lookahead candidate pricing, eager baselines — (task, gpu)
//!   pairs repeat across methods and lookahead siblings share kernels),
//!   the `AnalysisCache` de-duplicates region analysis / action masks
//!   per program state, and the `EdgeMemo` transposition table replays
//!   whole env transitions across methods, repeated sweeps and threads
//!   (methods that walk the same trees — e.g. the greedy surrogate under
//!   several labels — pay for each micro-coding transition once). Cache
//!   policy, `--memo-store` persistence and stats all live on the
//!   Session; sink records are enriched with the memoized eager baseline.
//!
//! Fault tolerance (the sweep engine's robustness contract):
//! - **unit isolation** — every unit runs under `catch_unwind`; a
//!   panicking unit becomes a `status: "panicked"` sink record with a
//!   zeroed outcome and the sweep keeps going. One bad (method, task)
//!   pair can no longer abort an hours-long table run.
//! - **retry with bounded backoff** — failures classed transient
//!   (injected faults from the session's
//!   [`FaultPlan`](crate::util::faults::FaultPlan)) retry up to
//!   [`BatchCfg::max_retries`] times with deterministic jittered backoff
//!   ([`crate::util::faults::backoff_ms`]); the session's
//!   [`FaultStats`](crate::util::faults::FaultStats) counts
//!   retried/recovered/exhausted transitions.
//! - **sweep resume** — [`BatchCfg::resume`] scans an existing sink
//!   file, truncates a torn final line (a crash mid-write), and skips
//!   every unit whose record is already present, reconstructing its
//!   [`TaskOutcome`] from the record so aggregate metrics match a full
//!   run. At `threads = 1` an interrupted-then-resumed sweep produces a
//!   sink byte-identical to an uninterrupted one.
//!
//! Determinism: unit seeds derive from (job seed, task index) exactly as
//! in [`super::evaluate`], never from thread identity — and every memo
//! stores only deterministic pure/edge-deterministic results — so results
//! are byte-identical across `threads = 1` and `threads = N` and across
//! every cache on/off combination (guarded by `rust/tests/batch.rs`).
//! Retries re-enter the same deterministic unit, so a retried sweep's
//! *outcomes* match a fault-free one (`rust/tests/faults.rs`).

use std::collections::HashMap;
use std::io::{BufWriter, Write};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use super::harness::{evaluate_task, EvalCfg, SuiteResult};
use super::metrics::{aggregate, TaskOutcome};
use super::methods::{MacroKind, Method};
use crate::engine::Session;
use crate::gpusim::{library_affinity, GpuSpec, Pricer};
use crate::graph::infer_shapes;
use crate::tasks::Task;
use crate::util::faults::{
    backoff_ms, classify, panic_msg, set_unit_attempt, FaultPlan, FaultSite,
    FaultStats,
};
use crate::util::json::Json;
use crate::util::parallel::{default_threads, par_map};

/// One (method, suite, gpu) sweep cell: the tasks fan out into units.
/// Tasks are `Arc`-shared — a roster sweep points many jobs at the same
/// suite slice without cloning every task graph per method.
#[derive(Clone, Debug)]
pub struct BatchJob {
    pub method: Method,
    pub gpu: GpuSpec,
    pub tasks: Arc<Vec<Task>>,
    /// Per-job harness config (seed, env, target language). The `threads`
    /// field is ignored here — [`BatchCfg::threads`] owns parallelism.
    pub cfg: EvalCfg,
}

impl BatchJob {
    pub fn new(method: Method, gpu: GpuSpec, tasks: Vec<Task>) -> BatchJob {
        Self::shared(method, gpu, Arc::new(tasks))
    }

    /// Construct against an already-shared task slice (no clone).
    pub fn shared(method: Method, gpu: GpuSpec, tasks: Arc<Vec<Task>>)
                  -> BatchJob {
        BatchJob { method, gpu, tasks, cfg: EvalCfg::default() }
    }
}

/// Runner configuration.
#[derive(Clone, Debug)]
pub struct BatchCfg {
    /// Worker count for the sharded unit queue.
    pub threads: usize,
    /// Optional JSON-lines output path for per-task outcome records.
    pub sink: Option<PathBuf>,
    /// Resume an interrupted sweep: scan `sink` for completed unit
    /// records (truncating a torn final line), open it in append mode,
    /// and skip every unit already recorded — its outcome is
    /// reconstructed from the record instead of re-run. Requires `sink`.
    pub resume: bool,
    /// Retry budget for transiently-failing units and sink writes
    /// (injected faults and I/O hiccups). Keep this at least as large as
    /// the fault plan's burst or injected faults become unit losses.
    pub max_retries: usize,
}

impl Default for BatchCfg {
    fn default() -> Self {
        BatchCfg {
            threads: default_threads(),
            sink: None,
            resume: false,
            max_retries: 2,
        }
    }
}

/// Append-only JSON-lines writer shared across workers. The lock is held
/// per line; records are written in completion order (each carries its
/// job/task identity, so order never carries meaning) and flushed per
/// record, so an interrupted process loses at most the line being
/// written — which `--resume` then truncates. A failing write retries in
/// place (bounded by the caller's budget); persistent I/O errors are
/// reported to stderr once (first failure) and surfaced via
/// [`JsonlSink::failed`] — a sweep never aborts mid-flight on a full
/// disk, but the truncation is loud, not silent. A worker that dies
/// while holding the lock poisons it; later writers recover the guard
/// rather than cascading the panic.
pub struct JsonlSink {
    w: Mutex<BufWriter<std::fs::File>>,
    write_failed: std::sync::atomic::AtomicBool,
}

impl JsonlSink {
    /// Create (truncate) `path` and its parent directories.
    pub fn create(path: &Path) -> anyhow::Result<JsonlSink> {
        Self::ensure_parent(path)?;
        Ok(Self::wrap(std::fs::File::create(path)?))
    }

    /// Open `path` for appending (sweep resume): existing records stay,
    /// new records append. Creates the file if missing.
    pub fn append(path: &Path) -> anyhow::Result<JsonlSink> {
        Self::ensure_parent(path)?;
        let f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        Ok(Self::wrap(f))
    }

    fn ensure_parent(path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        Ok(())
    }

    fn wrap(f: std::fs::File) -> JsonlSink {
        JsonlSink {
            w: Mutex::new(BufWriter::new(f)),
            write_failed: std::sync::atomic::AtomicBool::new(false),
        }
    }

    fn note_failure(&self, what: &str, e: &std::io::Error) {
        use std::sync::atomic::Ordering;
        if !self.write_failed.swap(true, Ordering::Relaxed) {
            eprintln!(
                "[batch] JSONL sink {what} failed ({e}); later records may \
                 be missing — treat the output as truncated"
            );
        }
    }

    /// Write one record (no retries, no fault plan).
    pub fn write(&self, v: &Json) {
        self.write_with(v, None, None, 0);
    }

    /// Write one record and flush it to disk, retrying a failed attempt
    /// up to `max_retries` times. `faults` injects deterministic
    /// [`FaultSite::SinkWrite`] failures keyed by the record bytes (an
    /// injected attempt touches nothing, so the retried bytes are
    /// identical); each successful write is counted toward the plan's
    /// kill-after budget. Real I/O errors retry too — `BufWriter` tracks
    /// consumed bytes across a failed flush, so a retry never duplicates
    /// a partial line.
    pub fn write_with(&self, v: &Json, faults: Option<&FaultPlan>,
                      stats: Option<&FaultStats>, max_retries: usize) {
        let line = v.to_string();
        let key = fnv1a(line.as_bytes());
        let mut g = self.w.lock().unwrap_or_else(|p| p.into_inner());
        let mut attempt = 0u32;
        loop {
            let injected = faults.is_some_and(|p| {
                p.fires_at(FaultSite::SinkWrite, key, attempt)
            });
            let r = if injected {
                Err(std::io::Error::other(
                    "injected transient fault (fault plan)",
                ))
            } else {
                writeln!(g, "{line}").and_then(|()| g.flush())
            };
            match r {
                Ok(()) => {
                    if let Some(p) = faults {
                        p.note_sink_write();
                    }
                    return;
                }
                Err(_) if (attempt as usize) < max_retries => {
                    if let Some(s) = stats {
                        s.note_sink_retry();
                    }
                    attempt += 1;
                }
                Err(e) => {
                    drop(g);
                    self.note_failure("write", &e);
                    return;
                }
            }
        }
    }

    pub fn flush(&self) {
        let r = self.w.lock().unwrap_or_else(|p| p.into_inner()).flush();
        if let Err(e) = r {
            self.note_failure("flush", &e);
        }
    }

    /// True if any write or flush failed since creation.
    pub fn failed(&self) -> bool {
        self.write_failed.load(std::sync::atomic::Ordering::Relaxed)
    }
}

/// FNV-1a over `bytes` — the stable record-identity hash behind
/// [`FaultSite::SinkWrite`] gating and [`unit_fault_key`].
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// The stable identity of one sweep unit, hashed from
/// (method, suite, gpu, task id, seed) — the same tuple `--resume` keys
/// records by. Feed it to
/// [`FaultPlan::with_panic_unit`](crate::util::faults::FaultPlan::with_panic_unit)
/// to arm a hard panic for exactly one unit.
pub fn unit_fault_key(method: &str, suite: &str, gpu: &str, task: &str,
                      seed: u64) -> u64 {
    fnv1a(sink_key(method, suite, gpu, task, seed).as_bytes())
}

/// The `--resume` skip key: unit identity joined with `\x1f` (a
/// separator that cannot appear in labels or task ids).
fn sink_key(method: &str, suite: &str, gpu: &str, task: &str, seed: u64)
            -> String {
    format!("{method}\x1f{suite}\x1f{gpu}\x1f{task}\x1f{seed}")
}

/// How one unit ended: cleanly, isolated after a real panic, or dropped
/// after exhausting its transient-retry budget. Non-ok statuses carry
/// the panic message for the record's `error` field.
enum UnitStatus {
    Ok,
    Panicked(String),
    Exhausted(String),
}

impl UnitStatus {
    fn label(&self) -> &'static str {
        match self {
            UnitStatus::Ok => "ok",
            UnitStatus::Panicked(_) => "panicked",
            UnitStatus::Exhausted(_) => "exhausted",
        }
    }

    fn error(&self) -> Option<&str> {
        match self {
            UnitStatus::Ok => None,
            UnitStatus::Panicked(m) | UnitStatus::Exhausted(m) => Some(m),
        }
    }
}

/// Scan an existing sink file for `--resume`: returns completed units
/// keyed by [`sink_key`], with outcomes reconstructed from the records
/// (f64s round-trip through the JSON writer exactly, so rebuilt metrics
/// match a full run bit-for-bit). A torn final line — no trailing
/// newline, the signature of a crash mid-write — is truncated away and
/// the scan continues; an unparsable *complete* line is mid-file
/// corruption and a hard error.
fn resume_scan(path: &Path) -> anyhow::Result<HashMap<String, TaskOutcome>> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(HashMap::new());
        }
        Err(e) => {
            return Err(anyhow::Error::new(e).context(format!(
                "resume: cannot read sink {}",
                path.display()
            )));
        }
    };
    let keep = bytes.iter().rposition(|&b| b == b'\n').map_or(0, |i| i + 1);
    if keep < bytes.len() {
        eprintln!(
            "[batch] resume: truncating torn final line of {} ({} bytes)",
            path.display(),
            bytes.len() - keep
        );
        let f = std::fs::OpenOptions::new().write(true).open(path)?;
        f.set_len(keep as u64)?;
    }
    let text = std::str::from_utf8(&bytes[..keep]).map_err(|_| {
        anyhow::anyhow!("resume: sink {} is not UTF-8", path.display())
    })?;
    let mut done = HashMap::new();
    for (li, line) in text.lines().enumerate() {
        let v = Json::parse(line).map_err(|e| {
            anyhow::anyhow!(
                "resume: sink {} line {}: {e} (mid-file corruption — only \
                 a torn final line is recoverable)",
                path.display(),
                li + 1
            )
        })?;
        let (key, outcome) = record_parts(&v).ok_or_else(|| {
            anyhow::anyhow!(
                "resume: sink {} line {}: record lacks unit identity \
                 fields (written by an older build?)",
                path.display(),
                li + 1
            )
        })?;
        done.insert(key, outcome);
    }
    Ok(done)
}

/// (skip key, reconstructed outcome) of one parsed sink record.
fn record_parts(v: &Json) -> Option<(String, TaskOutcome)> {
    let method = v.get("method")?.as_str()?;
    let suite = v.get("suite")?.as_str()?;
    let gpu = v.get("gpu")?.as_str()?;
    let task = v.get("task")?.as_str()?;
    let seed = v.get("seed")?.as_f64()? as u64;
    let outcome = TaskOutcome {
        task_id: task.to_string(),
        compiled: v.get("compiled")?.as_bool()?,
        correct: v.get("correct")?.as_bool()?,
        speedup: v.get("speedup")?.as_f64()?,
    };
    Some((sink_key(method, suite, gpu, task, seed), outcome))
}

/// The batched evaluation engine. Construct once per sweep over a
/// [`Session`]: the session's memo trio persists across
/// [`BatchRunner::run`] calls (and across runners), so repeated sweeps
/// replay from warm tables; cache policy, `--memo-store` warm-start/flush
/// and the stats registry are the session's job, not the runner's. A
/// sweep replayed entirely from a warm store performs no inserts, so the
/// session's end-of-run flush skips every segment (`written_segments: 0`
/// in `--stats-json` — the dirty-skip fast path CI asserts on). The
/// session also carries the optional fault plan and the fault-tolerance
/// counters the runner's retry loop feeds.
pub struct BatchRunner<'s> {
    threads: usize,
    session: &'s Session,
    sink: Option<JsonlSink>,
    max_retries: usize,
    /// Units already completed in a resumed sink, keyed by [`sink_key`].
    skip: HashMap<String, TaskOutcome>,
}

impl<'s> BatchRunner<'s> {
    pub fn new(cfg: BatchCfg, session: &'s Session)
               -> anyhow::Result<BatchRunner<'s>> {
        let mut skip = HashMap::new();
        let sink = match &cfg.sink {
            Some(path) if cfg.resume => {
                skip = resume_scan(path)?;
                if !skip.is_empty() {
                    eprintln!(
                        "[batch] resume: {} completed units found in {}",
                        skip.len(),
                        path.display()
                    );
                }
                Some(JsonlSink::append(path)?)
            }
            Some(path) => Some(JsonlSink::create(path)?),
            None if cfg.resume => anyhow::bail!(
                "--resume needs a JSONL sink to scan (pass --jsonl <path>)"
            ),
            None => None,
        };
        Ok(BatchRunner {
            threads: cfg.threads.max(1),
            session,
            sink,
            max_retries: cfg.max_retries,
            skip,
        })
    }

    /// The session whose memo trio this runner sweeps through.
    pub fn session(&self) -> &'s Session {
        self.session
    }

    /// True if a configured JSONL sink dropped any record (I/O error).
    /// Callers that script on exit codes should fail the run when set.
    pub fn sink_failed(&self) -> bool {
        self.sink.as_ref().is_some_and(|s| s.failed())
    }

    /// Run a sweep: every job's tasks become units on one work queue.
    /// Returns one [`SuiteResult`] per job, in job order.
    pub fn run(&self, jobs: &[BatchJob]) -> Vec<SuiteResult> {
        // Batched mode drives every macro decision through the greedy
        // cost-model surrogate (see `evaluate_task`); say so once rather
        // than silently re-attributing learned-policy rows.
        if jobs.iter().any(|j| matches!(
            &j.method,
            Method::Mtmc {
                macro_kind: MacroKind::LearnedOrGreedy { params_path: Some(_) },
                ..
            }
        )) {
            eprintln!(
                "[batch] note: LearnedOrGreedy methods use the greedy \
                 cost-model surrogate in batched mode (the PJRT runtime is \
                 not Sync); run eval::evaluate for the learned policy"
            );
        }
        let units: Vec<(usize, usize)> = jobs
            .iter()
            .enumerate()
            .flat_map(|(ji, j)| (0..j.tasks.len()).map(move |ti| (ji, ti)))
            .collect();
        let evaluated: Vec<(usize, TaskOutcome)> =
            par_map(&units, self.threads, |_, &(ji, ti)| {
                let job = &jobs[ji];
                let task = &job.tasks[ti];
                if let Some(prior) = self.skip.get(&sink_key(
                    &job.method.label(),
                    task.suite.label(),
                    job.gpu.name,
                    &task.id,
                    job.cfg.seed,
                )) {
                    // resumed unit: its record is already in the sink
                    return (ji, prior.clone());
                }
                // the session's memo trio serves the whole unit (env
                // steps, greedy lookahead, eager baselines, transition
                // replays) — whichever tiers its policy enables; outcomes
                // are bit-identical for every combination
                let (outcome, status) = self.run_unit(job, task, ti);
                if let Some(sink) = &self.sink {
                    // enrich the streamed record with the task's eager
                    // baseline — (task, gpu) pairs repeat across every
                    // method of a sweep, so this is almost always a cache
                    // hit; skipped entirely when nothing consumes it
                    let shapes = infer_shapes(&task.graph);
                    let eager_us = Pricer::new(self.session.cost(),
                                               &task.graph, &shapes)
                        .eager_time_us(&task.graph, &shapes, &job.gpu,
                                       library_affinity(&task.id));
                    sink.write_with(
                        &unit_record(ji, job, task, &outcome, eager_us,
                                     &status),
                        self.session.faults().map(|a| a.as_ref()),
                        Some(self.session.fault_stats()),
                        self.max_retries,
                    );
                }
                (ji, outcome)
            });
        if let Some(sink) = &self.sink {
            sink.flush();
        }
        let mut per_job: Vec<Vec<TaskOutcome>> =
            jobs.iter().map(|_| Vec::new()).collect();
        for (ji, outcome) in evaluated {
            per_job[ji].push(outcome);
        }
        jobs.iter()
            .zip(per_job)
            .map(|(job, outcomes)| SuiteResult {
                method: job.method.label(),
                suite: job.tasks.first().map_or("empty", |t| t.suite.label()),
                gpu: job.gpu.name,
                metrics: aggregate(&outcomes),
                outcomes,
            })
            .collect()
    }

    /// Execute one unit under `catch_unwind`, retrying transient-classed
    /// failures with deterministic backoff. The unit is a pure function
    /// of its seeds, so a retry re-runs the identical computation — an
    /// attempt that survives its injected faults produces the same
    /// outcome a fault-free run would.
    fn run_unit(&self, job: &BatchJob, task: &Task, ti: usize)
                -> (TaskOutcome, UnitStatus) {
        let faults = self.session.faults().map(|a| a.as_ref());
        let stats = self.session.fault_stats();
        let fkey = unit_fault_key(&job.method.label(), task.suite.label(),
                                  job.gpu.name, &task.id, job.cfg.seed);
        let mut attempt = 0u32;
        loop {
            set_unit_attempt(attempt);
            let caught = catch_unwind(AssertUnwindSafe(|| {
                if let Some(plan) = faults {
                    plan.raise_unit_panic_if(fkey);
                }
                evaluate_task(&job.method, task, ti as u64, &job.gpu,
                              &job.cfg, self.session)
            }));
            set_unit_attempt(0);
            let payload = match caught {
                Ok(outcome) => {
                    if attempt > 0 {
                        stats.note_recovered();
                    }
                    return (outcome, UnitStatus::Ok);
                }
                Err(payload) => payload,
            };
            let msg = panic_msg(payload.as_ref());
            if classify(payload.as_ref()).is_none() {
                // a real panic: isolate the unit, keep the sweep alive
                stats.note_panicked();
                eprintln!(
                    "[batch] unit ({}, {}, {}, {}) panicked: {msg} — \
                     recorded with status \"panicked\", sweep continues",
                    job.method.label(),
                    task.suite.label(),
                    job.gpu.name,
                    task.id
                );
                return (isolated_outcome(task), UnitStatus::Panicked(msg));
            }
            if (attempt as usize) >= self.max_retries {
                stats.note_exhausted();
                eprintln!(
                    "[batch] unit ({}, {}, {}, {}) gave up after {} \
                     retries: {msg}",
                    job.method.label(),
                    task.suite.label(),
                    job.gpu.name,
                    task.id,
                    self.max_retries
                );
                return (isolated_outcome(task), UnitStatus::Exhausted(msg));
            }
            stats.note_retried();
            std::thread::sleep(std::time::Duration::from_millis(
                backoff_ms(fkey, attempt),
            ));
            attempt += 1;
        }
    }
}

/// The zeroed outcome recorded for a unit that panicked or exhausted its
/// retries: not compiled, not correct, no speedup — it drags aggregate
/// metrics down instead of silently vanishing from them.
fn isolated_outcome(task: &Task) -> TaskOutcome {
    TaskOutcome {
        task_id: task.id.clone(),
        compiled: false,
        correct: false,
        speedup: 0.0,
    }
}

/// Build the jobs for a rectangular roster sweep: one job per
/// ((gpu, tasks) block, method), block-major. Slice [`BatchRunner::run`]'s
/// results as `results[bi * methods.len()..(bi + 1) * methods.len()]` to
/// recover block `bi`'s rows in roster order. Shared by the table benches
/// and the `repro table` subcommand so the two cannot drift. Each block's
/// tasks are cloned once and `Arc`-shared across the whole roster.
pub fn roster_sweep(methods: &[Method], blocks: &[(GpuSpec, Vec<Task>)])
                    -> Vec<BatchJob> {
    let mut jobs = Vec::with_capacity(methods.len() * blocks.len());
    for (gpu, tasks) in blocks {
        let shared = Arc::new(tasks.clone());
        for m in methods {
            jobs.push(BatchJob::shared(m.clone(), gpu.clone(),
                                       Arc::clone(&shared)));
        }
    }
    jobs
}

fn unit_record(ji: usize, job: &BatchJob, task: &Task, o: &TaskOutcome,
               eager_us: f64, status: &UnitStatus) -> Json {
    let mut pairs = vec![
        ("job", Json::from(ji)),
        ("method", Json::from(job.method.label())),
        ("suite", Json::from(task.suite.label())),
        ("gpu", Json::from(job.gpu.name)),
        ("task", Json::from(task.id.clone())),
        ("seed", Json::from(job.cfg.seed as f64)),
        ("status", Json::from(status.label())),
        ("compiled", Json::from(o.compiled)),
        ("correct", Json::from(o.correct)),
        ("speedup", Json::from(o.speedup)),
        ("eager_us", Json::from(eager_us)),
    ];
    if let Some(msg) = status.error() {
        pairs.push(("error", Json::from(msg)));
    }
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{evaluate, MacroKind};
    use crate::microcode::ProfileId;
    use crate::tasks::kernelbench_level;

    fn jobs_small() -> Vec<BatchJob> {
        let tasks = kernelbench_level(1)[..6].to_vec();
        vec![
            BatchJob::new(
                Method::Baseline { profile: ProfileId::GeminiPro25 },
                GpuSpec::a100(),
                tasks.clone(),
            ),
            BatchJob::new(
                Method::Mtmc {
                    macro_kind: MacroKind::GreedyLookahead,
                    micro: ProfileId::GeminiFlash25,
                },
                GpuSpec::v100(),
                tasks,
            ),
        ]
    }

    #[test]
    fn matches_unbatched_evaluate() {
        let jobs = jobs_small();
        let session = Session::default();
        let runner = BatchRunner::new(
            BatchCfg { threads: 4, ..Default::default() },
            &session,
        )
        .unwrap();
        let batched = runner.run(&jobs);
        for (job, got) in jobs.iter().zip(&batched) {
            let direct = evaluate(&job.method, &job.tasks, &job.gpu, &job.cfg);
            assert_eq!(got.metrics, direct.metrics,
                       "job {} diverged from evaluate()", got.method);
            assert_eq!(got.suite, direct.suite);
            assert_eq!(got.gpu, direct.gpu);
        }
    }

    #[test]
    fn sink_streams_one_record_per_unit() {
        let dir = std::env::temp_dir().join("qimeng_batch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("records.jsonl");
        let jobs = jobs_small();
        let n_units: usize = jobs.iter().map(|j| j.tasks.len()).sum();
        let session = Session::default();
        let runner = BatchRunner::new(
            BatchCfg { threads: 3, sink: Some(path.clone()),
                       ..Default::default() },
            &session,
        )
        .unwrap();
        runner.run(&jobs);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), n_units);
        for line in lines {
            let v = Json::parse(line).unwrap();
            assert!(v.get("task").and_then(|j| j.as_str()).is_some());
            assert!(v.get("speedup").and_then(|j| j.as_f64()).is_some());
            assert!(v.get("eager_us").and_then(|j| j.as_f64())
                .is_some_and(|e| e > 0.0));
            // fault-tolerance identity fields: every clean record says so
            assert_eq!(v.get("status").and_then(|j| j.as_str()), Some("ok"));
            assert_eq!(v.get("seed").and_then(|j| j.as_f64()),
                       Some(EvalCfg::default().seed as f64));
            assert!(v.get("error").is_none());
        }
    }

    #[test]
    fn cache_hits_accumulate_across_methods() {
        let dir = std::env::temp_dir().join("qimeng_batch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let jobs = jobs_small();
        let session = Session::default();
        let runner = BatchRunner::new(
            BatchCfg { threads: 2, sink: Some(dir.join("cache_hits.jsonl")),
                       ..Default::default() },
            &session,
        )
        .unwrap();
        runner.run(&jobs);
        let (h1, m1) = session.cost().unwrap().stats();
        // greedy-lookahead pricing alone guarantees warm traffic within
        // the first sweep (the current program is re-priced every step)
        assert!(h1 > 0, "no cache hits in a greedy-lookahead sweep");
        // both jobs share the same 6 tasks but differ in GPU, so the
        // second sweep re-prices only cached (task, gpu) pairs
        runner.run(&jobs);
        let (h2, m2) = session.cost().unwrap().stats();
        assert_eq!(m2, m1, "second sweep must be all hits");
        assert!(h2 >= jobs.iter().map(|j| j.tasks.len()).sum::<usize>());
    }

    #[test]
    fn roster_sweep_block_major_order() {
        let tasks = kernelbench_level(1)[..3].to_vec();
        let methods = vec![
            Method::Baseline { profile: ProfileId::GeminiPro25 },
            Method::Baseline { profile: ProfileId::Gpt4o },
        ];
        let blocks = vec![
            (GpuSpec::a100(), tasks.clone()),
            (GpuSpec::v100(), tasks),
        ];
        let jobs = roster_sweep(&methods, &blocks);
        assert_eq!(jobs.len(), 4);
        assert_eq!(jobs[0].gpu.name, "A100");
        assert_eq!(jobs[1].gpu.name, "A100");
        assert_eq!(jobs[2].gpu.name, "V100");
        assert_eq!(jobs[0].method.label(), jobs[2].method.label());
    }

    fn sample_record(task: &str) -> Json {
        Json::obj(vec![
            ("job", Json::from(0usize)),
            ("method", Json::from("m")),
            ("suite", Json::from("s")),
            ("gpu", Json::from("g")),
            ("task", Json::from(task)),
            ("seed", Json::from(7.0)),
            ("status", Json::from("ok")),
            ("compiled", Json::from(true)),
            ("correct", Json::from(true)),
            ("speedup", Json::from(1.25)),
            ("eager_us", Json::from(10.0)),
        ])
    }

    #[test]
    fn resume_scan_truncates_torn_tail_and_keys_records() {
        let dir = std::env::temp_dir().join("qimeng_batch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("resume_scan.jsonl");
        let torn = format!("{}\n{}\n{{\"method\":\"half",
                           sample_record("t0"), sample_record("t1"));
        std::fs::write(&path, &torn).unwrap();
        let done = resume_scan(&path).unwrap();
        assert_eq!(done.len(), 2);
        assert!(done.contains_key(&sink_key("m", "s", "g", "t0", 7)));
        let o = &done[&sink_key("m", "s", "g", "t1", 7)];
        assert!(o.compiled && o.correct);
        assert_eq!(o.speedup, 1.25);
        // the torn tail is gone from disk
        let healed = std::fs::read_to_string(&path).unwrap();
        assert!(healed.ends_with('\n'));
        assert_eq!(healed.lines().count(), 2);
        // a second scan is a no-op
        assert_eq!(resume_scan(&path).unwrap().len(), 2);
        // a missing file is an empty resume, not an error
        assert!(resume_scan(&dir.join("nope.jsonl")).unwrap().is_empty());
    }

    #[test]
    fn resume_scan_rejects_mid_file_corruption() {
        let dir = std::env::temp_dir().join("qimeng_batch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("resume_corrupt.jsonl");
        let text = format!("not json\n{}\n", sample_record("t0"));
        std::fs::write(&path, &text).unwrap();
        let err = resume_scan(&path).unwrap_err().to_string();
        assert!(err.contains("line 1"), "unexpected error: {err}");
    }

    #[test]
    fn resume_requires_a_sink() {
        let session = Session::default();
        let err = BatchRunner::new(
            BatchCfg { resume: true, ..Default::default() },
            &session,
        )
        .map(|_| ())
        .unwrap_err()
        .to_string();
        assert!(err.contains("--resume"), "unexpected error: {err}");
    }

    #[test]
    fn resume_replays_prefix_and_appends_identical_bytes() {
        let dir = std::env::temp_dir().join("qimeng_batch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("resume_bytes.jsonl");
        let jobs = jobs_small();
        // reference: one uninterrupted single-threaded sweep
        let reference = {
            let session = Session::default();
            let runner = BatchRunner::new(
                BatchCfg { threads: 1, sink: Some(path.clone()),
                           ..Default::default() },
                &session,
            )
            .unwrap();
            let results = runner.run(&jobs);
            (std::fs::read(&path).unwrap(), results)
        };
        // simulate a crash: keep 5 records plus a torn half-line
        let text = String::from_utf8(reference.0.clone()).unwrap();
        let prefix: String =
            text.lines().take(5).map(|l| format!("{l}\n")).collect();
        std::fs::write(&path, format!("{prefix}{{\"job\":0,\"tor")).unwrap();
        // resume with a fresh session: skipped units replay from the
        // sink, the rest re-run — same bytes, same metrics
        let session = Session::default();
        let runner = BatchRunner::new(
            BatchCfg { threads: 1, sink: Some(path.clone()), resume: true,
                       ..Default::default() },
            &session,
        )
        .unwrap();
        let resumed = runner.run(&jobs);
        assert_eq!(std::fs::read(&path).unwrap(), reference.0,
                   "resumed sink must be byte-identical to uninterrupted");
        for (a, b) in reference.1.iter().zip(&resumed) {
            assert_eq!(a.metrics, b.metrics,
                       "resumed metrics diverged for {}", a.method);
        }
    }
}
