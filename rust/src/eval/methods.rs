//! Method definitions: everything a table row can be.

use crate::microcode::ProfileId;
use crate::transform::Action;

/// How Macro-Thinking decisions are made in an MTMC run.
#[derive(Clone, Debug)]
pub enum MacroKind {
    /// The trained policy loaded from a parameter file (the real MTMC);
    /// falls back to `GreedyLookahead` when no parameters are available
    /// (documented in EXPERIMENTS.md — the greedy cost-model lookahead is
    /// the objective the policy converges to).
    LearnedOrGreedy { params_path: Option<std::path::PathBuf> },
    /// One-step cost-model lookahead (converged-policy surrogate).
    GreedyLookahead,
    /// Prompted-LLM proposer within the action space (Table 7 w/o policy
    /// w/ AS): preference ladder + mistake rate.
    Heuristic { label: String, mistake_rate: f64 },
    /// Unconstrained proposer (Table 7 w/o policy w/o AS).
    Freeform { label: String, wildness: f64, mistake_rate: f64 },
    /// Uniform random over valid actions.
    Random,
    /// A fixed plan (used by tests).
    Scripted(Vec<Action>),
}

/// One evaluated method (a table row).
#[derive(Clone, Debug)]
pub enum Method {
    /// Single-pass whole-kernel generation by a baseline LLM profile.
    Baseline { profile: ProfileId },
    /// Full MTMC: stepwise macro-thinking + micro-coding.
    Mtmc { macro_kind: MacroKind, micro: ProfileId },
    /// Table 6 "w/o Hier": MTMC's plan handed to the LLM in one prompt.
    MtmcNoHier { micro: ProfileId },
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::Baseline { profile } => {
                crate::microcode::LlmProfile::get(*profile).name.to_string()
            }
            Method::Mtmc { micro, .. } => format!(
                "{} + Ours",
                crate::microcode::LlmProfile::get(*micro).name
            ),
            Method::MtmcNoHier { micro } => format!(
                "{} w/o Hier",
                crate::microcode::LlmProfile::get(*micro).name
            ),
        }
    }
}

/// The Table 3 method roster (paper order): 10 general/code LLM + agent
/// baselines, two finetuned kernel LLMs, then MTMC on Gemini 2.5 Pro and
/// Flash micro-coders.
pub fn table3_methods(params_path: Option<std::path::PathBuf>) -> Vec<Method> {
    use ProfileId::*;
    let mut v: Vec<Method> = [
        Claude37Sonnet, Claude4Sonnet, O4Mini, Gpt4o, DeepSeekR1, DeepSeekV3,
        LlamaNemotron, Qwen3, QwenCoder32B, GeminiCli, Kevin32B, KernelLlm,
        GeminiPro25, GeminiFlash25,
    ]
    .into_iter()
    .map(|p| Method::Baseline { profile: p })
    .collect();
    v.push(Method::Mtmc {
        macro_kind: MacroKind::LearnedOrGreedy { params_path: params_path.clone() },
        micro: GeminiPro25,
    });
    v.push(Method::Mtmc {
        macro_kind: MacroKind::LearnedOrGreedy { params_path },
        micro: GeminiFlash25,
    });
    v
}

/// The Table 6 roster: (label, method) pairs comparing single-pass
/// ("w/o Hier") against stepwise MTMC for the two micro-coders the paper
/// ablates. Single source of truth for `cargo bench --bench table6` and
/// `repro table 6`.
pub fn table6_variants() -> Vec<(String, Method)> {
    use ProfileId::*;
    let mut v = Vec::new();
    for (name, micro) in [("GF-2.5", GeminiFlash25), ("DS-V3", DeepSeekV3)] {
        v.push((format!("{name} w/o Hier"), Method::MtmcNoHier { micro }));
        v.push((
            format!("{name} + Ours"),
            Method::Mtmc {
                macro_kind: MacroKind::GreedyLookahead,
                micro,
            },
        ));
    }
    v
}

/// The Table 4 roster (TritonBench on A100).
pub fn table4_methods(params_path: Option<std::path::PathBuf>) -> Vec<Method> {
    use ProfileId::*;
    let mut v: Vec<Method> = [
        GeminiPro25, Claude37Sonnet, Claude4Sonnet, O4Mini, Gpt4o,
        DeepSeekR1, DeepSeekV3, QwenCoder32B, KernelLlm, GeminiFlash25,
    ]
    .into_iter()
    .map(|p| Method::Baseline { profile: p })
    .collect();
    v.push(Method::Mtmc {
        macro_kind: MacroKind::LearnedOrGreedy { params_path },
        micro: GeminiFlash25,
    });
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rosters_sized_like_paper() {
        assert_eq!(table3_methods(None).len(), 16);
        assert_eq!(table4_methods(None).len(), 11);
    }

    #[test]
    fn labels_readable() {
        assert_eq!(
            Method::Baseline { profile: ProfileId::Kevin32B }.label(),
            "Kevin-32B"
        );
        assert!(Method::Mtmc {
            macro_kind: MacroKind::GreedyLookahead,
            micro: ProfileId::GeminiPro25
        }
        .label()
        .contains("+ Ours"));
    }
}
