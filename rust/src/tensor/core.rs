//! The dense row-major f32 tensor type.

use crate::util::Rng;

/// Dense row-major f32 tensor with arbitrary rank.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    /// Standard-normal random tensor (deterministic from `rng`).
    pub fn randn(shape: &[usize], rng: &mut Rng) -> Tensor {
        let n = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() as f32).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    /// Uniform [lo, hi) random tensor.
    pub fn rand_uniform(shape: &[usize], lo: f32, hi: f32, rng: &mut Rng) -> Tensor {
        let n = shape.iter().product();
        let data = (0..n).map(|_| lo + (hi - lo) * rng.f32()).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reshape (same element count).
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// Row-major linear index from a multi-index.
    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&ix, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(ix < dim, "index {ix} out of bounds at dim {i}");
            off = off * dim + ix;
        }
        off
    }

    #[inline]
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    #[inline]
    pub fn set(&mut self, idx: &[usize], v: f32) {
        let off = self.offset(idx);
        self.data[off] = v;
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise combine (shapes must match exactly; broadcasting lives
    /// in ops::binary_bcast).
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    /// Max |a - b| between same-shaped tensors.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch in comparison");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative-tolerance closeness (the correctness criterion used by the
    /// eval harness: matches benchmark practice of allclose with atol+rtol).
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data.iter().zip(&other.data).all(|(&a, &b)| {
            // bitwise-equal covers inf==inf (inf-inf is NaN, not 0)
            if a == b || (a.is_nan() && b.is_nan()) {
                return true;
            }
            let tol = atol + rtol * b.abs().max(a.abs());
            (a - b).abs() <= tol
        })
    }

    pub fn iter(&self) -> std::slice::Iter<'_, f32> {
        self.data.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_row_major() {
        let t = Tensor::new(&[2, 3], (0..6).map(|x| x as f32).collect());
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    fn set_and_get() {
        let mut t = Tensor::zeros(&[2, 2, 2]);
        t.set(&[1, 0, 1], 7.0);
        assert_eq!(t.at(&[1, 0, 1]), 7.0);
        assert_eq!(t.data().iter().filter(|&&x| x != 0.0).count(), 1);
    }

    #[test]
    fn allclose_tolerance() {
        let a = Tensor::new(&[2], vec![1.0, 100.0]);
        let b = Tensor::new(&[2], vec![1.0 + 1e-6, 100.0 + 1e-4]);
        assert!(a.allclose(&b, 1e-5, 1e-5));
        let c = Tensor::new(&[2], vec![1.1, 100.0]);
        assert!(!a.allclose(&c, 1e-5, 1e-5));
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        assert_eq!(Tensor::randn(&[4, 4], &mut r1), Tensor::randn(&[4, 4], &mut r2));
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn scalar_rank0() {
        let s = Tensor::scalar(3.5);
        assert_eq!(s.rank(), 0);
        assert_eq!(s.at(&[]), 3.5);
    }
}
