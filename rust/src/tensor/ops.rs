//! Operator kernels over [`Tensor`]: the reference numerics for every op in
//! the task graphs ("PyTorch Eager" semantics in the simulator). All are
//! straightforward, allocation-per-op implementations — *clarity over
//! speed*; the hot paths of the coordinator never run these on large
//! shapes (correctness checks use small verification shapes).

use super::Tensor;

// ------------------------------------------------------------ elementwise

pub fn relu(x: &Tensor) -> Tensor {
    x.map(|v| v.max(0.0))
}

pub fn gelu(x: &Tensor) -> Tensor {
    // tanh approximation (matches PyTorch's default gelu closely enough
    // for 1e-4 tolerances on the verification shapes)
    x.map(|v| {
        0.5 * v * (1.0 + ((0.7978845608 * (v + 0.044715 * v * v * v)) as f32).tanh())
    })
}

pub fn sigmoid(x: &Tensor) -> Tensor {
    x.map(|v| 1.0 / (1.0 + (-v).exp()))
}

pub fn tanh_t(x: &Tensor) -> Tensor {
    x.map(f32::tanh)
}

pub fn exp_t(x: &Tensor) -> Tensor {
    x.map(f32::exp)
}

pub fn scale(x: &Tensor, s: f32) -> Tensor {
    x.map(|v| v * s)
}

/// Broadcast binary op. Supports numpy-style right-aligned broadcasting.
pub fn binary_bcast(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    if a.shape() == b.shape() {
        return a.zip(b, f);
    }
    let rank = a.rank().max(b.rank());
    let pad = |s: &[usize]| -> Vec<usize> {
        let mut v = vec![1; rank - s.len()];
        v.extend_from_slice(s);
        v
    };
    let sa = pad(a.shape());
    let sb = pad(b.shape());
    let mut out_shape = Vec::with_capacity(rank);
    for i in 0..rank {
        let (da, db) = (sa[i], sb[i]);
        assert!(
            da == db || da == 1 || db == 1,
            "broadcast mismatch {:?} vs {:?}",
            a.shape(),
            b.shape()
        );
        out_shape.push(da.max(db));
    }
    let mut out = Tensor::zeros(&out_shape);
    let n = out.len();
    let mut idx = vec![0usize; rank];
    for lin in 0..n {
        // decode multi-index
        let mut rem = lin;
        for d in (0..rank).rev() {
            idx[d] = rem % out_shape[d];
            rem /= out_shape[d];
        }
        let off = |s: &[usize]| -> usize {
            let mut o = 0;
            for d in 0..rank {
                let i = if s[d] == 1 { 0 } else { idx[d] };
                o = o * s[d] + i;
            }
            o
        };
        out.data_mut()[lin] = f(a.data()[off(&sa)], b.data()[off(&sb)]);
    }
    out
}

pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    binary_bcast(a, b, |x, y| x + y)
}

pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    binary_bcast(a, b, |x, y| x - y)
}

pub fn mul(a: &Tensor, b: &Tensor) -> Tensor {
    binary_bcast(a, b, |x, y| x * y)
}

pub fn div(a: &Tensor, b: &Tensor) -> Tensor {
    binary_bcast(a, b, |x, y| x / y)
}

pub fn maximum(a: &Tensor, b: &Tensor) -> Tensor {
    binary_bcast(a, b, f32::max)
}

// --------------------------------------------------------------- matmul

/// 2-D matmul: [m,k] @ [k,n] -> [m,n].
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner-dim mismatch");
    let mut out = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let od = out.data_mut();
    for i in 0..m {
        for p in 0..k {
            let av = ad[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &bd[p * n..(p + 1) * n];
            let orow = &mut od[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// Batched matmul: [b,m,k] @ [b,k,n] -> [b,m,n].
pub fn bmm(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 3);
    assert_eq!(b.rank(), 3);
    let (bs, m, k) = (a.shape()[0], a.shape()[1], a.shape()[2]);
    assert_eq!(b.shape()[0], bs);
    assert_eq!(b.shape()[1], k);
    let n = b.shape()[2];
    let mut out = Tensor::zeros(&[bs, m, n]);
    for bi in 0..bs {
        let asl = Tensor::new(&[m, k], a.data()[bi * m * k..(bi + 1) * m * k].to_vec());
        let bsl = Tensor::new(&[k, n], b.data()[bi * k * n..(bi + 1) * k * n].to_vec());
        let o = matmul(&asl, &bsl);
        out.data_mut()[bi * m * n..(bi + 1) * m * n].copy_from_slice(o.data());
    }
    out
}

/// 2-D transpose.
pub fn transpose2(a: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut out = Tensor::zeros(&[n, m]);
    for i in 0..m {
        for j in 0..n {
            out.data_mut()[j * m + i] = a.data()[i * n + j];
        }
    }
    out
}

// ----------------------------------------------------------------- conv

/// conv2d NCHW: x[n,c,h,w] * w[o,c,kh,kw] -> [n,o,h',w'], stride/pad.
pub fn conv2d(x: &Tensor, w: &Tensor, stride: usize, pad: usize) -> Tensor {
    assert_eq!(x.rank(), 4);
    assert_eq!(w.rank(), 4);
    let (n, c, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (oc, c2, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    assert_eq!(c, c2, "conv channel mismatch");
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (wd + 2 * pad - kw) / stride + 1;
    let mut out = Tensor::zeros(&[n, oc, oh, ow]);
    for ni in 0..n {
        for oi in 0..oc {
            for yi in 0..oh {
                for xi in 0..ow {
                    let mut acc = 0.0f32;
                    for ci in 0..c {
                        for ky in 0..kh {
                            for kx in 0..kw {
                                let sy = yi * stride + ky;
                                let sx = xi * stride + kx;
                                if sy < pad || sx < pad {
                                    continue;
                                }
                                let (sy, sx) = (sy - pad, sx - pad);
                                if sy >= h || sx >= wd {
                                    continue;
                                }
                                acc += x.at(&[ni, ci, sy, sx])
                                    * w.at(&[oi, ci, ky, kx]);
                            }
                        }
                    }
                    out.set(&[ni, oi, yi, xi], acc);
                }
            }
        }
    }
    out
}

/// 2-D max pooling NCHW.
pub fn maxpool2d(x: &Tensor, k: usize, stride: usize) -> Tensor {
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let oh = (h - k) / stride + 1;
    let ow = (w - k) / stride + 1;
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    for ni in 0..n {
        for ci in 0..c {
            for yi in 0..oh {
                for xi in 0..ow {
                    let mut m = f32::NEG_INFINITY;
                    for ky in 0..k {
                        for kx in 0..k {
                            m = m.max(x.at(&[ni, ci, yi * stride + ky, xi * stride + kx]));
                        }
                    }
                    out.set(&[ni, ci, yi, xi], m);
                }
            }
        }
    }
    out
}

/// Global average pool NCHW -> [n, c].
pub fn global_avgpool(x: &Tensor) -> Tensor {
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let mut out = Tensor::zeros(&[n, c]);
    for ni in 0..n {
        for ci in 0..c {
            let mut s = 0.0;
            for yi in 0..h {
                for xi in 0..w {
                    s += x.at(&[ni, ci, yi, xi]);
                }
            }
            out.set(&[ni, ci], s / (h * w) as f32);
        }
    }
    out
}

// ----------------------------------------------------------- reductions

/// Reduce over the last axis. kind: "sum" | "max" | "mean" | "argmax".
pub fn reduce_last(x: &Tensor, kind: &str) -> Tensor {
    let rank = x.rank();
    assert!(rank >= 1);
    let last = x.shape()[rank - 1];
    let outer: usize = x.shape()[..rank - 1].iter().product();
    let mut out = Tensor::zeros(&x.shape()[..rank - 1].to_vec());
    for i in 0..outer {
        let row = &x.data()[i * last..(i + 1) * last];
        let v = match kind {
            "sum" => row.iter().sum::<f32>(),
            "mean" => row.iter().sum::<f32>() / last as f32,
            "max" => row.iter().cloned().fold(f32::NEG_INFINITY, f32::max),
            "argmax" => {
                let mut bi = 0;
                let mut bv = f32::NEG_INFINITY;
                for (j, &val) in row.iter().enumerate() {
                    if val > bv {
                        bv = val;
                        bi = j;
                    }
                }
                bi as f32
            }
            _ => panic!("unknown reduce kind {kind}"),
        };
        out.data_mut()[i] = v;
    }
    out
}

/// Cumulative sum along the last axis.
pub fn cumsum_last(x: &Tensor) -> Tensor {
    let rank = x.rank();
    let last = x.shape()[rank - 1];
    let outer: usize = x.shape()[..rank - 1].iter().product();
    let mut out = x.clone();
    for i in 0..outer {
        let row = &mut out.data_mut()[i * last..(i + 1) * last];
        for j in 1..last {
            row[j] += row[j - 1];
        }
    }
    out
}

/// Numerically-stable softmax over the last axis.
pub fn softmax_last(x: &Tensor) -> Tensor {
    let rank = x.rank();
    let last = x.shape()[rank - 1];
    let outer: usize = x.shape()[..rank - 1].iter().product();
    let mut out = x.clone();
    for i in 0..outer {
        let row = &mut out.data_mut()[i * last..(i + 1) * last];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut s = 0.0;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            s += *v;
        }
        for v in row.iter_mut() {
            *v /= s;
        }
    }
    out
}

/// LayerNorm over the last axis (no affine).
pub fn layernorm_last(x: &Tensor, eps: f32) -> Tensor {
    let rank = x.rank();
    let last = x.shape()[rank - 1];
    let outer: usize = x.shape()[..rank - 1].iter().product();
    let mut out = x.clone();
    for i in 0..outer {
        let row = &mut out.data_mut()[i * last..(i + 1) * last];
        let mean = row.iter().sum::<f32>() / last as f32;
        let var = row.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / last as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for v in row.iter_mut() {
            *v = (*v - mean) * inv;
        }
    }
    out
}

/// BatchNorm (inference) over channel dim of NCHW using given stats.
pub fn batchnorm2d(x: &Tensor, mean: &Tensor, var: &Tensor, eps: f32) -> Tensor {
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    assert_eq!(mean.len(), c);
    assert_eq!(var.len(), c);
    let mut out = x.clone();
    for ni in 0..n {
        for ci in 0..c {
            let inv = 1.0 / (var.data()[ci] + eps).sqrt();
            let mu = mean.data()[ci];
            for yi in 0..h {
                for xi in 0..w {
                    let v = out.at(&[ni, ci, yi, xi]);
                    out.set(&[ni, ci, yi, xi], (v - mu) * inv);
                }
            }
        }
    }
    out
}

// ------------------------------------------------------------ attention

/// Single-head scaled-dot-product attention: q,k,v are [s, d].
pub fn attention(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let d = q.shape()[1] as f32;
    let scores = scale(&matmul(q, &transpose2(k)), 1.0 / d.sqrt());
    let probs = softmax_last(&scores);
    matmul(&probs, v)
}

/// One LSTM cell step. x:[b,i], h:[b,u], c:[b,u], w_ih:[i,4u], w_hh:[u,4u].
/// Gate order: i, f, g, o (PyTorch convention). Returns (h', c').
pub fn lstm_cell(
    x: &Tensor,
    h: &Tensor,
    c: &Tensor,
    w_ih: &Tensor,
    w_hh: &Tensor,
) -> (Tensor, Tensor) {
    let b = x.shape()[0];
    let u = h.shape()[1];
    let gates = add(&matmul(x, w_ih), &matmul(h, w_hh)); // [b, 4u]
    let mut hn = Tensor::zeros(&[b, u]);
    let mut cn = Tensor::zeros(&[b, u]);
    for bi in 0..b {
        for ui in 0..u {
            let ig = 1.0 / (1.0 + (-gates.at(&[bi, ui])).exp());
            let fg = 1.0 / (1.0 + (-gates.at(&[bi, u + ui])).exp());
            let gg = gates.at(&[bi, 2 * u + ui]).tanh();
            let og = 1.0 / (1.0 + (-gates.at(&[bi, 3 * u + ui])).exp());
            let cv = fg * c.at(&[bi, ui]) + ig * gg;
            cn.set(&[bi, ui], cv);
            hn.set(&[bi, ui], og * cv.tanh());
        }
    }
    (hn, cn)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matmul_identity() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let i = Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(matmul(&a, &i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn bmm_matches_per_slice_matmul() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[3, 4, 5], &mut rng);
        let b = Tensor::randn(&[3, 5, 2], &mut rng);
        let c = bmm(&a, &b);
        for bi in 0..3 {
            let asl = Tensor::new(&[4, 5], a.data()[bi * 20..(bi + 1) * 20].to_vec());
            let bsl = Tensor::new(&[5, 2], b.data()[bi * 10..(bi + 1) * 10].to_vec());
            let expect = matmul(&asl, &bsl);
            let got = Tensor::new(&[4, 2], c.data()[bi * 8..(bi + 1) * 8].to_vec());
            assert!(got.allclose(&expect, 1e-6, 1e-6));
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[5, 7], &mut rng);
        assert_eq!(transpose2(&transpose2(&a)), a);
    }

    #[test]
    fn broadcast_add_bias() {
        let x = Tensor::new(&[2, 3], vec![0.; 6]);
        let b = Tensor::new(&[3], vec![1., 2., 3.]);
        let y = add(&x, &b);
        assert_eq!(y.data(), &[1., 2., 3., 1., 2., 3.]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[4, 9], &mut rng);
        let p = softmax_last(&x);
        for i in 0..4 {
            let s: f32 = p.data()[i * 9..(i + 1) * 9].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_stable_on_large_values() {
        let x = Tensor::new(&[1, 3], vec![1e4, -1e4, 0.0]);
        let p = softmax_last(&x);
        assert!(p.data().iter().all(|v| v.is_finite()));
        assert!((p.data()[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn conv2d_known_3x3() {
        // 1x1x3x3 input, 1x1x2x2 all-ones filter, stride 1, no pad
        let x = Tensor::new(&[1, 1, 3, 3], (1..=9).map(|v| v as f32).collect());
        let w = Tensor::full(&[1, 1, 2, 2], 1.0);
        let y = conv2d(&x, &w, 1, 0);
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        assert_eq!(y.data(), &[12., 16., 24., 28.]);
    }

    #[test]
    fn conv2d_padding_keeps_shape() {
        let mut rng = Rng::new(4);
        let x = Tensor::randn(&[1, 2, 5, 5], &mut rng);
        let w = Tensor::randn(&[3, 2, 3, 3], &mut rng);
        let y = conv2d(&x, &w, 1, 1);
        assert_eq!(y.shape(), &[1, 3, 5, 5]);
    }

    #[test]
    fn maxpool_known() {
        let x = Tensor::new(&[1, 1, 2, 2], vec![1., 5., 3., 2.]);
        let y = maxpool2d(&x, 2, 2);
        assert_eq!(y.data(), &[5.0]);
    }

    #[test]
    fn reduce_kinds() {
        let x = Tensor::new(&[2, 3], vec![1., 5., 3., -1., -5., -3.]);
        assert_eq!(reduce_last(&x, "sum").data(), &[9., -9.]);
        assert_eq!(reduce_last(&x, "max").data(), &[5., -1.]);
        assert_eq!(reduce_last(&x, "mean").data(), &[3., -3.]);
        assert_eq!(reduce_last(&x, "argmax").data(), &[1., 0.]);
    }

    #[test]
    fn cumsum_last_axis() {
        let x = Tensor::new(&[2, 3], vec![1., 2., 3., 10., 20., 30.]);
        assert_eq!(cumsum_last(&x).data(), &[1., 3., 6., 10., 30., 60.]);
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&[3, 16], &mut rng);
        let y = layernorm_last(&x, 1e-5);
        for i in 0..3 {
            let row = &y.data()[i * 16..(i + 1) * 16];
            let m: f32 = row.iter().sum::<f32>() / 16.0;
            let v: f32 = row.iter().map(|x| (x - m).powi(2)).sum::<f32>() / 16.0;
            assert!(m.abs() < 1e-5);
            assert!((v - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn attention_uniform_when_scores_equal() {
        // q orthogonal to all k -> scores 0 -> uniform avg of v rows
        let q = Tensor::zeros(&[1, 4]);
        let k = Tensor::new(&[2, 4], vec![1., 0., 0., 0., 0., 1., 0., 0.]);
        let v = Tensor::new(&[2, 4], vec![2., 0., 0., 0., 0., 4., 0., 0.]);
        let o = attention(&q, &k, &v);
        assert!((o.at(&[0, 0]) - 1.0).abs() < 1e-6);
        assert!((o.at(&[0, 1]) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn lstm_cell_gates_behave() {
        // zero inputs and states -> c' = 0.5*tanh(0)*... = i*g = 0.5*0 = 0
        let b = 2;
        let (i, u) = (3, 4);
        let x = Tensor::zeros(&[b, i]);
        let h = Tensor::zeros(&[b, u]);
        let c = Tensor::full(&[b, u], 1.0);
        let w_ih = Tensor::zeros(&[i, 4 * u]);
        let w_hh = Tensor::zeros(&[u, 4 * u]);
        let (hn, cn) = lstm_cell(&x, &h, &c, &w_ih, &w_hh);
        // f gate = sigmoid(0) = 0.5 -> c' = 0.5
        assert!(cn.data().iter().all(|&v| (v - 0.5).abs() < 1e-6));
        // h' = sigmoid(0) * tanh(0.5)
        let expect = 0.5 * 0.5f32.tanh();
        assert!(hn.data().iter().all(|&v| (v - expect).abs() < 1e-6));
    }

    #[test]
    fn global_avgpool_means() {
        let x = Tensor::new(&[1, 2, 2, 2], vec![1., 2., 3., 4., 10., 20., 30., 40.]);
        let y = global_avgpool(&x);
        assert_eq!(y.data(), &[2.5, 25.0]);
    }
}
