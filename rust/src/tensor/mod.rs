//! Dense f32 tensor substrate: the functional executor under the operator
//! graph and the micro-coded kernels. Keeps everything row-major and
//! f32 (the simulator's correctness checks are tolerance-based, so a single
//! dtype suffices; the *performance* dtype story lives in `gpusim`).

mod core;
mod ops;

pub use self::core::Tensor;
pub use self::ops::*;
