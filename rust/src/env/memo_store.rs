//! Disk persistence for the [`EdgeMemo`] transposition table: the
//! process-crossing tier of the memo subsystem.
//!
//! The paper's Macro Thinking stage amortizes exploration over an
//! experience store of optimization trajectories; an in-memory memo only
//! amortizes within one process. This module serializes the memo's
//! `(key → CachedEdge)` entries — including the `Arc<Program>` payloads —
//! so a later `repro eval` / `train-ppo` run warm-starts from everything
//! earlier runs computed (the `--memo-store <path>` flag).
//!
//! ## Layout (v2, `QMMCEDG2`)
//!
//! The store is a **directory**, one segment file per memo shard:
//!
//! ```text
//! <store>/manifest.bin   magic + shard count + capacity
//! <store>/seg_NN.bin     magic + shard index + entry count + records
//! ```
//!
//! Keys are partitioned by [`EdgeMemo::shard_of`], so a shard whose
//! entry set did not change since the last flush (its dirty flag is
//! clear) can be **skipped** — a mostly-replay run rewrites nothing.
//! Every file lands via write-to-temp-then-rename, so a crash at any
//! point leaves each segment either old-complete or new-complete; the
//! previous good store is never truncated in place. A corrupt /
//! truncated / version-mismatched segment degrades only its own shard
//! (logged; the others still warm-start), and the bad segment's shard is
//! re-marked dirty so the next flush overwrites the damaged bytes.
//!
//! Framing is hand-rolled (the workspace allows no serialization deps):
//! an 8-byte magic that doubles as the format version, little-endian
//! fixed-width integers, length-prefixed strings. Floats travel as IEEE
//! bits, so a loaded edge replays **bit-identically** to its
//! freshly-computed twin (guarded by the persistence property in
//! `rust/tests/properties.rs`). Entries are written key-sorted so equal
//! memo contents produce byte-identical segments.
//!
//! Legacy single-file `QMMCEDG1` stores still load: a warm start from a
//! file migrates it in place to the segmented layout (the original file
//! is only removed after the full directory has been written and swapped
//! into place).
//!
//! Loading is strict but the entry points are forgiving:
//! [`load_edge_memo`] rejects bad magic (wrong version), truncation,
//! implausible lengths, unknown tags and trailing bytes with an `Err`;
//! [`warm_start_edge_memo`] turns those into a logged per-segment
//! degrade, never a panic — a corrupt segment costs one shard's
//! recomputation, not the run.

use std::fs::File;
use std::io::{BufReader, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use super::memo::{CachedEdge, EdgeMemo};
use super::reward::StepSignal;
use crate::graph::{Mutation, MutationKind};
use crate::kir::{is_intrinsically_legal, Kernel, LoopOrder, Program,
                 Schedule};
use crate::util::faults::{FaultPlan, FaultSite};

/// Format magic; the trailing digit is the version. Bump it on any layout
/// change — old stores then fail the magic check and cold-start cleanly.
const MAGIC: &[u8; 8] = b"QMMCEDG2";

/// The v1 single-file magic, still recognized for read + migration.
const LEGACY_MAGIC: &[u8; 8] = b"QMMCEDG1";

/// Manifest file name inside a segmented store directory.
const MANIFEST: &str = "manifest.bin";

/// Load-time sanity bounds: a corrupted length prefix must bail early,
/// not drive a multi-gigabyte allocation.
const MAX_ENTRIES: u64 = 10_000_000;
const MAX_SHARDS: usize = 1_024;
const MAX_KERNELS: u32 = 4_096;
const MAX_NODES: u32 = 100_000;
const MAX_MUTATIONS: u32 = 10_000;
const MAX_NAME: u32 = 4_096;

/// What a warm start recovered from disk (returned by
/// [`warm_start_edge_memo`], surfaced in `--stats-json`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WarmStartReport {
    /// Edges loaded into the memo.
    pub edges: usize,
    /// Segment files that parsed cleanly (a legacy file counts as 1).
    pub recovered_segments: usize,
    /// Segment files rejected as corrupt/truncated/mismatched; their
    /// shards cold-start and are re-marked dirty so the next flush heals
    /// the store.
    pub degraded_segments: usize,
    /// Cached programs dropped at load because they are no longer
    /// statically legal under the current verifier (stale entries from an
    /// older binary, or silent corruption that still parses). Their
    /// shards stay dirty so the next flush rewrites them screened.
    pub stale_rejected: usize,
}

/// What a flush wrote (returned by [`flush_edge_memo`], surfaced in
/// `--stats-json`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FlushReport {
    /// Live edges the store represents after the flush (written shards'
    /// entries plus the resident entries of skipped-clean shards).
    pub edges: usize,
    /// Segments rewritten because their shard was dirty.
    pub written_segments: usize,
    /// Segments skipped because their shard was clean since the last
    /// flush/load — the dirty-skip fast path.
    pub skipped_segments: usize,
}

// --- primitive framing -----------------------------------------------

fn w_byte(w: &mut impl Write, v: u8) -> Result<()> {
    w.write_all(&[v])?;
    Ok(())
}

fn w_u32(w: &mut impl Write, v: usize) -> Result<()> {
    let v = u32::try_from(v).context("field exceeds u32 framing")?;
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_f32(w: &mut impl Write, v: f32) -> Result<()> {
    w.write_all(&v.to_bits().to_le_bytes())?;
    Ok(())
}

fn w_f64(w: &mut impl Write, v: f64) -> Result<()> {
    w_u64(w, v.to_bits())
}

fn w_str(w: &mut impl Write, s: &str) -> Result<()> {
    if s.len() as u64 > MAX_NAME as u64 {
        bail!("string field of {} bytes exceeds framing bound", s.len());
    }
    w_u32(w, s.len())?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn r_byte(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b).context("truncated store")?;
    Ok(b[0])
}

fn r_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("truncated store")?;
    Ok(u32::from_le_bytes(b))
}

fn r_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("truncated store")?;
    Ok(u64::from_le_bytes(b))
}

fn r_f32(r: &mut impl Read) -> Result<f32> {
    Ok(f32::from_bits(r_u32(r)?))
}

fn r_f64(r: &mut impl Read) -> Result<f64> {
    Ok(f64::from_bits(r_u64(r)?))
}

fn r_str(r: &mut impl Read) -> Result<String> {
    let len = r_u32(r)?;
    if len > MAX_NAME {
        bail!("string length {len} exceeds framing bound");
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf).context("truncated store")?;
    String::from_utf8(buf).context("non-UTF-8 string field")
}

// --- record framing --------------------------------------------------

fn write_schedule(w: &mut impl Write, s: &Schedule) -> Result<()> {
    match s.block_tile {
        None => w_byte(w, 0)?,
        Some((m, n, k)) => {
            w_byte(w, 1)?;
            w_u32(w, m)?;
            w_u32(w, n)?;
            w_u32(w, k)?;
        }
    }
    match s.reg_tile {
        None => w_byte(w, 0)?,
        Some((m, n)) => {
            w_byte(w, 1)?;
            w_u32(w, m)?;
            w_u32(w, n)?;
        }
    }
    w_u32(w, s.pipeline_depth)?;
    w_byte(w, match s.loop_order {
        LoopOrder::Naive => 0,
        LoopOrder::Coalesced => 1,
        LoopOrder::Blocked => 2,
    })?;
    w_u32(w, s.vector_width)
}

fn read_schedule(r: &mut impl Read) -> Result<Schedule> {
    let block_tile = match r_byte(r)? {
        0 => None,
        1 => Some((
            r_u32(r)? as usize,
            r_u32(r)? as usize,
            r_u32(r)? as usize,
        )),
        t => bail!("bad block-tile tag {t}"),
    };
    let reg_tile = match r_byte(r)? {
        0 => None,
        1 => Some((r_u32(r)? as usize, r_u32(r)? as usize)),
        t => bail!("bad reg-tile tag {t}"),
    };
    let pipeline_depth = r_u32(r)? as usize;
    let loop_order = match r_byte(r)? {
        0 => LoopOrder::Naive,
        1 => LoopOrder::Coalesced,
        2 => LoopOrder::Blocked,
        t => bail!("bad loop-order tag {t}"),
    };
    let vector_width = r_u32(r)? as usize;
    Ok(Schedule { block_tile, reg_tile, pipeline_depth, loop_order, vector_width })
}

fn write_mutation(w: &mut impl Write, m: &Mutation) -> Result<()> {
    w_u32(w, m.node)?;
    match m.kind {
        MutationKind::BoundaryDrop { frac } => {
            w_byte(w, 0)?;
            w_f32(w, frac)
        }
        MutationKind::RaceCorruption { scale } => {
            w_byte(w, 1)?;
            w_f32(w, scale)
        }
        MutationKind::IndexOffset => w_byte(w, 2),
        MutationKind::SkippedOp => w_byte(w, 3),
        MutationKind::BadAccumInit { bias } => {
            w_byte(w, 4)?;
            w_f32(w, bias)
        }
    }
}

fn read_mutation(r: &mut impl Read) -> Result<Mutation> {
    let node = r_u32(r)? as usize;
    let kind = match r_byte(r)? {
        0 => MutationKind::BoundaryDrop { frac: r_f32(r)? },
        1 => MutationKind::RaceCorruption { scale: r_f32(r)? },
        2 => MutationKind::IndexOffset,
        3 => MutationKind::SkippedOp,
        4 => MutationKind::BadAccumInit { bias: r_f32(r)? },
        t => bail!("bad mutation tag {t}"),
    };
    Ok(Mutation { node, kind })
}

fn write_program(w: &mut impl Write, p: &Program) -> Result<()> {
    w_u32(w, p.kernels.len())?;
    for k in &p.kernels {
        w_str(w, &k.name)?;
        w_u32(w, k.nodes.len())?;
        for &n in &k.nodes {
            w_u32(w, n)?;
        }
        write_schedule(w, &k.schedule)?;
    }
    w_u32(w, p.mutations.len())?;
    for m in &p.mutations {
        write_mutation(w, m)?;
    }
    w_byte(w, p.compile_broken as u8)
}

fn read_program(r: &mut impl Read) -> Result<Program> {
    let n_kernels = r_u32(r)?;
    if n_kernels > MAX_KERNELS {
        bail!("implausible kernel count {n_kernels}");
    }
    let mut kernels = Vec::with_capacity(n_kernels as usize);
    for _ in 0..n_kernels {
        let name = r_str(r)?;
        let n_nodes = r_u32(r)?;
        if n_nodes > MAX_NODES {
            bail!("implausible node count {n_nodes}");
        }
        let mut nodes = Vec::with_capacity(n_nodes as usize);
        for _ in 0..n_nodes {
            nodes.push(r_u32(r)? as usize);
        }
        let schedule = read_schedule(r)?;
        kernels.push(Kernel { nodes, schedule, name });
    }
    let n_mutations = r_u32(r)?;
    if n_mutations > MAX_MUTATIONS {
        bail!("implausible mutation count {n_mutations}");
    }
    let mut mutations = Vec::with_capacity(n_mutations as usize);
    for _ in 0..n_mutations {
        mutations.push(read_mutation(r)?);
    }
    let compile_broken = match r_byte(r)? {
        0 => false,
        1 => true,
        t => bail!("bad compile-broken tag {t}"),
    };
    Ok(Program { kernels, mutations, compile_broken })
}

fn write_signal(w: &mut impl Write, s: StepSignal) -> Result<()> {
    match s {
        StepSignal::CompileFail => w_byte(w, 0),
        StepSignal::WrongResult => w_byte(w, 1),
        StepSignal::Rejected => w_byte(w, 2),
        StepSignal::Correct { prev, now } => {
            w_byte(w, 3)?;
            w_f64(w, prev)?;
            w_f64(w, now)
        }
        StepSignal::Stop { best } => {
            w_byte(w, 4)?;
            w_f64(w, best)
        }
    }
}

fn read_signal(r: &mut impl Read) -> Result<StepSignal> {
    Ok(match r_byte(r)? {
        0 => StepSignal::CompileFail,
        1 => StepSignal::WrongResult,
        2 => StepSignal::Rejected,
        3 => StepSignal::Correct { prev: r_f64(r)?, now: r_f64(r)? },
        4 => StepSignal::Stop { best: r_f64(r)? },
        t => bail!("bad signal tag {t}"),
    })
}

fn write_edge(w: &mut impl Write, edge: &CachedEdge) -> Result<()> {
    // `from_disk` is not stored: every loaded edge is a disk edge
    match &edge.program {
        None => w_byte(w, 0)?,
        Some(p) => {
            w_byte(w, 1)?;
            write_program(w, p)?;
        }
    }
    write_signal(w, edge.signal)?;
    w_f64(w, edge.speedup)
}

fn read_edge(r: &mut impl Read) -> Result<CachedEdge> {
    let program = match r_byte(r)? {
        0 => None,
        1 => Some(Arc::new(read_program(r)?)),
        t => bail!("bad edge-program tag {t}"),
    };
    let signal = read_signal(r)?;
    let speedup = r_f64(r)?;
    Ok(CachedEdge { program, signal, speedup, from_disk: true })
}

// --- store layout ----------------------------------------------------

fn segment_name(i: usize) -> String {
    format!("seg_{i:02}.bin")
}

fn segment_path(dir: &Path, i: usize) -> PathBuf {
    dir.join(segment_name(i))
}

fn manifest_path(dir: &Path) -> PathBuf {
    dir.join(MANIFEST)
}

/// `<name><suffix>` next to `path` (temp files, migration staging).
fn sibling(path: &Path, suffix: &str) -> PathBuf {
    let mut name = path
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_default();
    name.push(suffix);
    path.with_file_name(name)
}

/// Write `bytes` to a `.tmp` sibling, fsync, then rename into place:
/// a crash at any point leaves `path` either old-complete or
/// new-complete, never truncated or half-written.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = sibling(path, ".tmp");
    let staged = (|| -> Result<()> {
        let mut f = File::create(&tmp)
            .with_context(|| format!("create temp file {tmp:?}"))?;
        f.write_all(bytes)
            .with_context(|| format!("write temp file {tmp:?}"))?;
        f.sync_all()
            .with_context(|| format!("sync temp file {tmp:?}"))?;
        Ok(())
    })();
    let renamed = staged.and_then(|()| {
        std::fs::rename(&tmp, path)
            .with_context(|| format!("rename {tmp:?} into place"))
    });
    if renamed.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    renamed
}

fn manifest_bytes(shards: usize, capacity: usize) -> Result<Vec<u8>> {
    let mut w = Vec::with_capacity(20);
    w.write_all(MAGIC)?;
    w_u32(&mut w, shards)?;
    w_u64(&mut w, capacity as u64)?;
    Ok(w)
}

/// Strict manifest parse: `(shard_count, capacity)`.
fn read_manifest(path: &Path) -> Result<(usize, u64)> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("read manifest {path:?}"))?;
    let mut r = &bytes[..];
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("manifest too short")?;
    if magic != *MAGIC {
        bail!("{path:?}: not a v2 edge-memo manifest (magic {magic:02x?})");
    }
    let shards = r_u32(&mut r)? as usize;
    if shards == 0 || shards > MAX_SHARDS {
        bail!("{path:?}: implausible shard count {shards}");
    }
    let capacity = r_u64(&mut r)?;
    if !r.is_empty() {
        bail!("{path:?}: trailing bytes after manifest");
    }
    Ok((shards, capacity))
}

/// Serialize one shard's entries as a segment file body (key-sorted, so
/// equal shard contents yield byte-identical segments).
fn segment_bytes(index: usize, mut entries: Vec<(u64, CachedEdge)>) -> Result<Vec<u8>> {
    entries.sort_by_key(|&(k, _)| k);
    let mut w = Vec::new();
    w.write_all(MAGIC)?;
    w_u32(&mut w, index)?;
    w_u64(&mut w, entries.len() as u64)?;
    for (key, edge) in &entries {
        w_u64(&mut w, *key)?;
        write_edge(&mut w, edge)?;
    }
    Ok(w)
}

/// Strict segment parse; `index` must match both the filename and the
/// header, catching segments copied between slots.
fn read_segment(path: &Path, index: usize) -> Result<Vec<(u64, CachedEdge)>> {
    let file = File::open(path)
        .with_context(|| format!("open segment {path:?}"))?;
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)
        .context("segment too short for header")?;
    if magic != *MAGIC {
        bail!("{path:?}: not a v2 edge-memo segment (magic {magic:02x?})");
    }
    let idx = r_u32(&mut r)? as usize;
    if idx != index {
        bail!("{path:?}: header claims shard {idx}, filename says {index}");
    }
    let n = r_u64(&mut r)?;
    if n > MAX_ENTRIES {
        bail!("{path:?}: implausible entry count {n}");
    }
    let mut entries = Vec::with_capacity(n as usize);
    for _ in 0..n {
        let key = r_u64(&mut r)?;
        entries.push((key, read_edge(&mut r)?));
    }
    let mut trail = [0u8; 1];
    if r.read(&mut trail)? != 0 {
        bail!("{path:?}: trailing bytes after {n} entries");
    }
    Ok(entries)
}

/// Rewrite the manifest only when absent or stale — a clean flush must
/// not touch any file.
fn ensure_manifest(memo: &EdgeMemo, dir: &Path) -> Result<()> {
    let want = manifest_bytes(memo.shard_count(), memo.capacity())?;
    let path = manifest_path(dir);
    let fresh = matches!(std::fs::read(&path), Ok(have) if have == want);
    if fresh {
        return Ok(());
    }
    write_atomic(&path, &want)
}

/// The warm-start legality screen: drop entries whose cached program is
/// no longer intrinsically legal under the current verifier (a stale
/// store written by an older binary, or silent corruption that still
/// parses). Programs only ever persist from `Correct` edges, so a live
/// store loses nothing here. Returns the kept entries plus the rejected
/// count.
fn screen_entries(entries: Vec<(u64, CachedEdge)>) -> (Vec<(u64, CachedEdge)>, usize) {
    let before = entries.len();
    let kept: Vec<(u64, CachedEdge)> = entries
        .into_iter()
        .filter(|(_, edge)| match &edge.program {
            Some(p) => is_intrinsically_legal(p),
            None => true,
        })
        .collect();
    let stale = before - kept.len();
    (kept, stale)
}

/// Insert fully-parsed segments into the memo; a shard restored to
/// exactly its on-disk contents is marked clean so the next flush can
/// skip it, while eviction during load or misfiled keys leave the
/// affected shards dirty (the next flush rewrites them compacted —
/// self-healing). With `screen` set, entries failing the warm-start
/// legality screen are dropped and their shards kept dirty, so the next
/// flush rewrites the on-disk segment without them. Returns
/// `(edges installed, stale entries rejected)`.
fn install_segments(
    memo: &EdgeMemo,
    segments: Vec<(usize, Vec<(u64, CachedEdge)>)>,
    screen: bool,
) -> (usize, usize) {
    let mut total = 0;
    let mut stale_total = 0;
    for (i, entries) in segments {
        let (entries, stale) = if screen {
            screen_entries(entries)
        } else {
            (entries, 0)
        };
        stale_total += stale;
        let count = entries.len();
        let mut all_in_shard = true;
        for (key, edge) in entries {
            all_in_shard &= EdgeMemo::shard_of(key) == i;
            memo.insert(key, edge);
        }
        total += count;
        if stale > 0 {
            // the on-disk segment still holds the rejected entries: keep
            // the shard dirty even if it lost *all* its entries (no
            // insert ran to dirty it), so the next flush heals the store
            memo.mark_shard_dirty(i);
        } else if all_in_shard && memo.shard_len(i) == count {
            memo.clear_shard_dirty(i);
        }
    }
    memo.note_disk_loaded(total);
    (total, stale_total)
}

// --- entry points ----------------------------------------------------

/// Write every shard — dirty or not — as a segment file under `path`,
/// plus the manifest. Strict: the first failed write aborts with `Err`
/// (the failed shard re-marked dirty); shards already renamed into place
/// stay valid. Returns the edge count written.
///
/// If `path` is an existing legacy single file, the directory is staged
/// next to it and atomically swapped in (see [`warm_start_edge_memo`]
/// for the migration path).
pub fn save_edge_memo(memo: &EdgeMemo, path: &Path) -> Result<usize> {
    if path.is_file() {
        return replace_legacy_store(memo, path);
    }
    save_segments(memo, path)
}

fn save_segments(memo: &EdgeMemo, dir: &Path) -> Result<usize> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("create edge-memo store {dir:?}"))?;
    ensure_manifest(memo, dir)?;
    let mut total = 0;
    for i in 0..memo.shard_count() {
        let entries = memo.take_shard_for_flush(i);
        let count = entries.len();
        let written = segment_bytes(i, entries)
            .and_then(|bytes| write_atomic(&segment_path(dir, i), &bytes));
        if let Err(e) = written {
            memo.mark_shard_dirty(i);
            return Err(e);
        }
        total += count;
    }
    Ok(total)
}

/// Replace a legacy single-file store at `path` with a segmented
/// directory holding the memo's contents. The directory is fully staged
/// at `<path>.migrate` first; only then is the old file moved aside and
/// the directory renamed into place, so a failure at any step leaves the
/// legacy file intact and loadable.
fn replace_legacy_store(memo: &EdgeMemo, path: &Path) -> Result<usize> {
    let staging = sibling(path, ".migrate");
    if staging.exists() {
        std::fs::remove_dir_all(&staging)
            .with_context(|| format!("clear stale staging dir {staging:?}"))?;
    }
    let total = match save_segments(memo, &staging) {
        Ok(n) => n,
        Err(e) => {
            let _ = std::fs::remove_dir_all(&staging);
            return Err(e);
        }
    };
    let backup = sibling(path, ".legacy");
    std::fs::rename(path, &backup)
        .with_context(|| format!("move legacy store aside to {backup:?}"))?;
    if let Err(e) = std::fs::rename(&staging, path) {
        let _ = std::fs::rename(&backup, path);
        let _ = std::fs::remove_dir_all(&staging);
        return Err(e)
            .with_context(|| format!("swap segmented store into {path:?}"));
    }
    let _ = std::fs::remove_file(&backup);
    Ok(total)
}

/// Load a segmented store (or a legacy v1 file) into `memo`, marking
/// every entry `from_disk`. Strict: bad magic (wrong version),
/// truncation, implausible lengths, unknown tags, trailing bytes and a
/// shard-count mismatch are all `Err`s, and on error the memo is left
/// untouched (every segment is parsed in full before any insert).
/// Missing segment files are empty shards, not errors.
pub fn load_edge_memo(memo: &EdgeMemo, path: &Path) -> Result<usize> {
    if path.is_file() {
        return load_legacy_file(memo, path);
    }
    let (shards, _capacity) = read_manifest(&manifest_path(path))?;
    if shards != memo.shard_count() {
        bail!(
            "{path:?}: store has {shards} shards, this memo has {}",
            memo.shard_count()
        );
    }
    let mut segments = Vec::new();
    for i in 0..shards {
        let sp = segment_path(path, i);
        if !sp.exists() {
            continue;
        }
        segments.push((i, read_segment(&sp, i)?));
    }
    Ok(install_segments(memo, segments, false).0)
}

/// Strict v1 single-file parse (the pre-segmentation format).
fn read_legacy_file(path: &Path) -> Result<Vec<(u64, CachedEdge)>> {
    let file = File::open(path)
        .with_context(|| format!("open edge-memo store {path:?}"))?;
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("store too short for header")?;
    if magic != *LEGACY_MAGIC {
        bail!("{path:?}: not a v1 edge-memo store (magic {magic:02x?})");
    }
    let n = r_u64(&mut r)?;
    if n > MAX_ENTRIES {
        bail!("{path:?}: implausible entry count {n}");
    }
    let mut entries = Vec::with_capacity(n as usize);
    for _ in 0..n {
        let key = r_u64(&mut r)?;
        entries.push((key, read_edge(&mut r)?));
    }
    let mut trail = [0u8; 1];
    if r.read(&mut trail)? != 0 {
        bail!("{path:?}: trailing bytes after {n} entries");
    }
    Ok(entries)
}

/// Strict v1 single-file load.
fn load_legacy_file(memo: &EdgeMemo, path: &Path) -> Result<usize> {
    let entries = read_legacy_file(path)?;
    let loaded = entries.len();
    for (key, edge) in entries {
        memo.insert(key, edge);
    }
    memo.note_disk_loaded(loaded);
    Ok(loaded)
}

/// Best-effort warm start behind the `--memo-store` flag: a missing
/// store is a silent cold start (the first run of a pair); a bad
/// manifest logs and cold-starts; a corrupt / truncated /
/// version-mismatched **segment** degrades only its own shard — the
/// others still load, and the bad shard is re-marked dirty so the next
/// flush overwrites the damaged file. Cached programs are re-screened
/// against the current static verifier; entries no longer legal are
/// dropped (counted in [`WarmStartReport::stale_rejected`]) and healed
/// out of the store by the next flush. A legacy v1 single file is loaded
/// whole and migrated in place to the segmented layout. Never panics,
/// never fails the run.
pub fn warm_start_edge_memo(memo: &EdgeMemo, path: &Path) -> WarmStartReport {
    warm_start_edge_memo_with(memo, path, None)
}

/// [`warm_start_edge_memo`] with an optional [`FaultPlan`]: when the
/// plan fires [`FaultSite::SegmentRead`] for a segment index, that
/// segment takes the degrade path exactly as a corrupt file would —
/// the deterministic chaos stand-in for real I/O failure.
pub fn warm_start_edge_memo_with(memo: &EdgeMemo, path: &Path,
                                 faults: Option<&FaultPlan>)
                                 -> WarmStartReport {
    if !path.exists() {
        return WarmStartReport::default();
    }
    if path.is_file() {
        return warm_start_legacy(memo, path);
    }
    let (shards, _capacity) = match read_manifest(&manifest_path(path)) {
        Ok(m) => m,
        Err(e) => {
            eprintln!(
                "edge-memo: ignoring store {}: {e:#} (cold start)",
                path.display()
            );
            return WarmStartReport::default();
        }
    };
    if shards != memo.shard_count() {
        eprintln!(
            "edge-memo: ignoring store {}: built for {shards} shards, \
             this binary uses {} (cold start)",
            path.display(),
            memo.shard_count()
        );
        return WarmStartReport::default();
    }
    let mut report = WarmStartReport::default();
    let mut good = Vec::new();
    for i in 0..shards {
        let sp = segment_path(path, i);
        if !sp.exists() {
            continue;
        }
        let parsed = if faults.is_some_and(|p| {
            p.fires_at(FaultSite::SegmentRead, i as u64, 0)
        }) {
            Err(anyhow!("injected transient fault (fault plan)"))
        } else {
            read_segment(&sp, i)
        };
        match parsed {
            Ok(entries) => {
                report.recovered_segments += 1;
                good.push((i, entries));
            }
            Err(e) => {
                report.degraded_segments += 1;
                // so the next flush overwrites the damaged bytes
                memo.mark_shard_dirty(i);
                eprintln!(
                    "edge-memo: segment {} degraded: {e:#} (shard cold)",
                    sp.display()
                );
            }
        }
    }
    let (edges, stale) = install_segments(memo, good, true);
    report.edges = edges;
    report.stale_rejected = stale;
    let degraded = if report.degraded_segments > 0 {
        format!(", {} degraded", report.degraded_segments)
    } else {
        String::new()
    };
    let stale = if report.stale_rejected > 0 {
        format!(", {} stale entries rejected", report.stale_rejected)
    } else {
        String::new()
    };
    eprintln!(
        "edge-memo: warm-started {} edges from {} ({} segments{degraded}{stale})",
        report.edges,
        path.display(),
        report.recovered_segments
    );
    report
}

fn warm_start_legacy(memo: &EdgeMemo, path: &Path) -> WarmStartReport {
    let entries = match read_legacy_file(path) {
        Ok(entries) => entries,
        Err(e) => {
            eprintln!(
                "edge-memo: ignoring store {}: {e:#} (cold start)",
                path.display()
            );
            return WarmStartReport::default();
        }
    };
    let (kept, stale) = screen_entries(entries);
    let n = kept.len();
    for (key, edge) in kept {
        memo.insert(key, edge);
    }
    memo.note_disk_loaded(n);
    let stale_note = if stale > 0 {
        format!(", {stale} stale entries rejected")
    } else {
        String::new()
    };
    eprintln!(
        "edge-memo: warm-started {n} edges from {} (legacy store{stale_note})",
        path.display()
    );
    // migration persists the *screened* memo, healing any stale entries
    // out of the store as a side effect
    match replace_legacy_store(memo, path) {
        Ok(_) => eprintln!(
            "edge-memo: migrated legacy store {} to the segmented layout",
            path.display()
        ),
        Err(e) => eprintln!(
            "edge-memo: could not migrate legacy store {}: {e:#} \
             (will retry at flush)",
            path.display()
        ),
    }
    WarmStartReport {
        edges: n,
        recovered_segments: 1,
        degraded_segments: 0,
        stale_rejected: stale,
    }
}

/// Best-effort flush behind the `--memo-store` flag: rewrites **only the
/// dirty segments** (clean shards are skipped untouched — a pure-replay
/// run writes nothing), each via temp-then-rename. A failed segment
/// write logs, re-marks its shard dirty for the next flush, and leaves
/// the prior segment bytes intact; it never fails the run. A `path`
/// still holding a legacy single file gets one forced full segmented
/// save (the deferred migration).
pub fn flush_edge_memo(memo: &EdgeMemo, path: &Path) -> FlushReport {
    flush_edge_memo_with(memo, path, None)
}

/// [`flush_edge_memo`] with an optional [`FaultPlan`]: when the plan
/// fires [`FaultSite::SegmentWrite`] for a dirty segment, that segment
/// takes the failed-write path (shard stays dirty, prior bytes intact)
/// exactly as a real I/O failure would.
pub fn flush_edge_memo_with(memo: &EdgeMemo, path: &Path,
                            faults: Option<&FaultPlan>) -> FlushReport {
    if path.is_file() {
        return match replace_legacy_store(memo, path) {
            Ok(n) => {
                let report = FlushReport {
                    edges: n,
                    written_segments: memo.shard_count(),
                    skipped_segments: 0,
                };
                eprintln!(
                    "edge-memo: persisted {n} edges to {} \
                     ({} segments written, 0 clean; legacy store migrated)",
                    path.display(),
                    report.written_segments
                );
                report
            }
            Err(e) => {
                eprintln!(
                    "edge-memo: failed to persist to {}: {e:#}",
                    path.display()
                );
                FlushReport::default()
            }
        };
    }
    if let Err(e) = std::fs::create_dir_all(path)
        .with_context(|| format!("create edge-memo store {path:?}"))
        .and_then(|()| ensure_manifest(memo, path))
    {
        eprintln!(
            "edge-memo: failed to persist to {}: {e:#}",
            path.display()
        );
        return FlushReport::default();
    }
    let mut report = FlushReport::default();
    for i in 0..memo.shard_count() {
        if !memo.shard_dirty(i) {
            report.skipped_segments += 1;
            report.edges += memo.shard_len(i);
            continue;
        }
        let sp = segment_path(path, i);
        let written = if faults.is_some_and(|p| {
            p.fires_at(FaultSite::SegmentWrite, i as u64, 0)
        }) {
            Err(anyhow!("injected transient fault (fault plan)"))
        } else {
            let entries = memo.take_shard_for_flush(i);
            let count = entries.len();
            segment_bytes(i, entries)
                .and_then(|bytes| write_atomic(&sp, &bytes))
                .map(|()| count)
        };
        match written {
            Ok(count) => {
                report.written_segments += 1;
                report.edges += count;
            }
            Err(e) => {
                memo.mark_shard_dirty(i);
                report.edges += memo.shard_len(i);
                eprintln!(
                    "edge-memo: failed to write segment {}: {e:#} \
                     (prior segment kept, will retry next flush)",
                    sp.display()
                );
            }
        }
    }
    eprintln!(
        "edge-memo: persisted {} edges to {} ({} segments written, {} clean)",
        report.edges,
        path.display(),
        report.written_segments,
        report.skipped_segments
    );
    report
}

// --- store fsck ------------------------------------------------------

/// One segment's line in an [`fsck_store`] report.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SegmentFsck {
    /// Shard index (from the canonical filename).
    pub index: usize,
    /// Entries parsed (0 when corrupt).
    pub entries: usize,
    /// Segment file size in bytes.
    pub bytes: u64,
    /// Parsed cleanly under the strict reader?
    pub ok: bool,
}

/// What `repro store fsck` found (and, with `drop_orphans`, repaired).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FsckReport {
    /// Shard count the manifest declares.
    pub shards: usize,
    /// Capacity the manifest declares.
    pub capacity: u64,
    /// Entries across all clean segments.
    pub entries: usize,
    /// Per-segment occupancy for segment files present on disk,
    /// ascending by index (a missing segment file is just an empty
    /// shard, not damage).
    pub segments: Vec<SegmentFsck>,
    /// Shards with no segment file on disk.
    pub missing_segments: usize,
    /// Segments that failed the strict parse (they cold-start their
    /// shard at warm start and are healed by the next flush).
    pub corrupt_segments: usize,
    /// Files in the store directory that nothing will ever read again:
    /// `seg_NN.bin` outside the manifest's shard range (left behind by
    /// a shard-count change) and stale `*.tmp` staging files from an
    /// interrupted flush. Sorted by name.
    pub orphans: Vec<String>,
    /// True when `drop_orphans` was set and the orphans were deleted.
    pub orphans_removed: bool,
}

/// Integrity + occupancy check of a segmented (`QMMCEDG2`) store: the
/// `repro store fsck` engine. Reads the manifest strictly (a path
/// without a readable v2 manifest cannot be fsck'd — legacy v1 files
/// are migrated by the `--memo-store` warm start, not here), parses
/// every live segment with the same strict reader warm start uses, and
/// lists **orphans**: segment files outside the manifest's shard range
/// plus stale `.tmp` staging files. With `drop_orphans` the orphans
/// are deleted; live segments and the manifest are never touched.
pub fn fsck_store(path: &Path, drop_orphans: bool) -> Result<FsckReport> {
    if !path.is_dir() {
        bail!(
            "{path:?} is not a segmented store directory (legacy v1 \
             single-file stores are migrated by --memo-store warm start, \
             not fsck)"
        );
    }
    let (shards, capacity) = read_manifest(&manifest_path(path))?;
    let mut report = FsckReport { shards, capacity, ..Default::default() };
    for i in 0..shards {
        let sp = segment_path(path, i);
        let Ok(meta) = std::fs::metadata(&sp) else {
            report.missing_segments += 1;
            continue;
        };
        match read_segment(&sp, i) {
            Ok(entries) => {
                report.entries += entries.len();
                report.segments.push(SegmentFsck {
                    index: i,
                    entries: entries.len(),
                    bytes: meta.len(),
                    ok: true,
                });
            }
            Err(e) => {
                report.corrupt_segments += 1;
                report.segments.push(SegmentFsck {
                    index: i,
                    entries: 0,
                    bytes: meta.len(),
                    ok: false,
                });
                eprintln!(
                    "edge-memo: segment {} corrupt: {e:#}",
                    sp.display()
                );
            }
        }
    }
    // anything else in the directory that looks like ours is an orphan
    let live: std::collections::HashSet<String> =
        (0..shards).map(segment_name).collect();
    let listing = std::fs::read_dir(path)
        .with_context(|| format!("list store {path:?}"))?;
    for entry in listing {
        let name = entry?.file_name().to_string_lossy().into_owned();
        let segment_shaped =
            name.starts_with("seg_") && name.ends_with(".bin");
        let stale_tmp = name.ends_with(".tmp");
        if (segment_shaped || stale_tmp) && !live.contains(&name) {
            report.orphans.push(name);
        }
    }
    report.orphans.sort();
    if drop_orphans && !report.orphans.is_empty() {
        for name in &report.orphans {
            std::fs::remove_file(path.join(name))
                .with_context(|| format!("remove orphan {name}"))?;
        }
        report.orphans_removed = true;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fresh store directory path (removed first, so every test starts
    /// cold).
    fn store(name: &str) -> PathBuf {
        let root = std::env::temp_dir().join("qimeng_memo_store_test");
        std::fs::create_dir_all(&root).unwrap();
        let path = root.join(name);
        let _ = std::fs::remove_dir_all(&path);
        let _ = std::fs::remove_file(&path);
        path
    }

    fn cleanup(path: &Path) {
        let _ = std::fs::remove_dir_all(path);
        let _ = std::fs::remove_file(path);
    }

    /// A key that lands in shard `shard` (the memo shards on the high
    /// 16 bits).
    fn key_in(shard: u64, low: u64) -> u64 {
        (shard << 48) | low
    }

    fn small_edge(speedup: f64) -> CachedEdge {
        CachedEdge {
            program: None,
            signal: StepSignal::Rejected,
            speedup,
            from_disk: false,
        }
    }

    /// An edge whose cached program the current verifier rejects
    /// outright (compile-broken AND a zero tile dimension) — the stale
    /// flavour the warm-start screen exists for. Also exercises the
    /// `compile_broken = true` byte in the framing roundtrip.
    fn stale_edge() -> CachedEdge {
        let program = Program {
            kernels: vec![Kernel {
                nodes: vec![2],
                schedule: Schedule {
                    block_tile: Some((0, 64, 32)),
                    ..Schedule::default()
                },
                name: "k0_stale".to_string(),
            }],
            mutations: vec![],
            compile_broken: true,
        };
        CachedEdge {
            program: Some(Arc::new(program)),
            signal: StepSignal::Correct { prev: 1.0, now: 2.0 },
            speedup: 2.0,
            from_disk: false,
        }
    }

    /// One edge of every flavour the stepper produces (all keys land in
    /// shard 0). The program is intrinsically legal — the stepper only
    /// ever persists programs from accepted `Correct` edges, and the
    /// warm-start screen drops anything else.
    fn sample_edges() -> Vec<(u64, CachedEdge)> {
        let program = Program {
            kernels: vec![
                Kernel {
                    nodes: vec![2, 3, 4],
                    schedule: Schedule {
                        block_tile: Some((128, 64, 32)),
                        reg_tile: Some((8, 4)),
                        pipeline_depth: 2,
                        loop_order: LoopOrder::Blocked,
                        vector_width: 4,
                    },
                    name: "k0_matmul".to_string(),
                },
                Kernel {
                    nodes: vec![5],
                    schedule: Schedule::default(),
                    name: "k1_relu".to_string(),
                },
            ],
            mutations: vec![
                Mutation { node: 2, kind: MutationKind::BoundaryDrop { frac: 0.25 } },
                Mutation { node: 3, kind: MutationKind::RaceCorruption { scale: 0.5 } },
                Mutation { node: 4, kind: MutationKind::IndexOffset },
                Mutation { node: 5, kind: MutationKind::SkippedOp },
                Mutation { node: 5, kind: MutationKind::BadAccumInit { bias: 1.5 } },
            ],
            compile_broken: false,
        };
        vec![
            (7, CachedEdge {
                program: Some(Arc::new(program)),
                signal: StepSignal::Correct { prev: 0.1, now: 0.7 },
                speedup: 2.25,
                from_disk: false,
            }),
            (9, CachedEdge {
                program: None,
                signal: StepSignal::Rejected,
                speedup: 1.0,
                from_disk: false,
            }),
            (11, CachedEdge {
                program: None,
                signal: StepSignal::CompileFail,
                speedup: 1.0,
                from_disk: false,
            }),
            (13, CachedEdge {
                program: None,
                signal: StepSignal::WrongResult,
                speedup: 1.0,
                from_disk: false,
            }),
            (15, CachedEdge {
                program: None,
                signal: StepSignal::Stop { best: 3.5 },
                speedup: 3.5,
                from_disk: false,
            }),
        ]
    }

    fn assert_same_edge(a: &CachedEdge, b: &CachedEdge) {
        match (&a.program, &b.program) {
            (None, None) => {}
            (Some(x), Some(y)) => assert_eq!(**x, **y),
            _ => panic!("program presence diverged"),
        }
        assert_eq!(a.signal, b.signal);
        assert_eq!(a.speedup.to_bits(), b.speedup.to_bits());
    }

    /// Hand-rolled v1 single-file writer: the migration fixture.
    fn write_legacy_store(path: &Path, entries: &[(u64, CachedEdge)]) {
        let mut sorted = entries.to_vec();
        sorted.sort_by_key(|&(k, _)| k);
        let mut w = Vec::new();
        w.write_all(LEGACY_MAGIC).unwrap();
        w_u64(&mut w, sorted.len() as u64).unwrap();
        for (key, edge) in &sorted {
            w_u64(&mut w, *key).unwrap();
            write_edge(&mut w, edge).unwrap();
        }
        std::fs::write(path, &w).unwrap();
    }

    fn mtime(path: &Path) -> std::time::SystemTime {
        std::fs::metadata(path).unwrap().modified().unwrap()
    }

    #[test]
    fn roundtrip_preserves_every_edge_flavour() {
        let path = store("roundtrip");
        let memo = EdgeMemo::with_capacity(256);
        for (k, e) in sample_edges() {
            memo.insert(k, e);
        }
        let saved = save_edge_memo(&memo, &path).unwrap();
        assert_eq!(saved, 5);
        assert!(manifest_path(&path).is_file());
        assert!(segment_path(&path, 0).is_file());

        let loaded_memo = EdgeMemo::with_capacity(256);
        let loaded = load_edge_memo(&loaded_memo, &path).unwrap();
        assert_eq!(loaded, 5);
        assert_eq!(loaded_memo.disk_loaded(), 5);
        for (k, original) in sample_edges() {
            let got = loaded_memo.get(k).expect("edge survived the roundtrip");
            assert!(got.from_disk, "loaded edges must be marked from_disk");
            assert_same_edge(&got, &original);
        }
        assert!(loaded_memo.stats().disk_hits > 0);
        cleanup(&path);
    }

    #[test]
    fn save_is_deterministic_for_equal_contents() {
        let (p1, p2) = (store("det1"), store("det2"));
        let a = EdgeMemo::with_capacity(256);
        let b = EdgeMemo::with_capacity(256);
        for (k, e) in sample_edges() {
            a.insert(k, e);
        }
        // reversed insertion order must not change any file's bytes
        for (k, e) in sample_edges().into_iter().rev() {
            b.insert(k, e);
        }
        save_edge_memo(&a, &p1).unwrap();
        save_edge_memo(&b, &p2).unwrap();
        assert_eq!(
            std::fs::read(manifest_path(&p1)).unwrap(),
            std::fs::read(manifest_path(&p2)).unwrap()
        );
        for i in 0..a.shard_count() {
            assert_eq!(
                std::fs::read(segment_path(&p1, i)).unwrap(),
                std::fs::read(segment_path(&p2, i)).unwrap(),
                "segment {i} bytes diverged"
            );
        }
        cleanup(&p1);
        cleanup(&p2);
    }

    #[test]
    fn wrong_version_or_magic_degrades_to_cold() {
        let path = store("wrong_magic");
        std::fs::create_dir_all(&path).unwrap();
        let mut bytes = b"QMMCEDG9".to_vec(); // future version
        bytes.extend_from_slice(&16u32.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        std::fs::write(manifest_path(&path), &bytes).unwrap();
        let memo = EdgeMemo::with_capacity(64);
        assert!(load_edge_memo(&memo, &path).is_err());
        assert_eq!(warm_start_edge_memo(&memo, &path), WarmStartReport::default());
        assert!(memo.is_empty(), "rejected store must leave the memo cold");
        cleanup(&path);
    }

    #[test]
    fn shard_count_mismatch_degrades_to_cold() {
        let path = store("shard_mismatch");
        std::fs::create_dir_all(&path).unwrap();
        std::fs::write(manifest_path(&path), manifest_bytes(8, 64).unwrap()).unwrap();
        let memo = EdgeMemo::with_capacity(64);
        assert!(load_edge_memo(&memo, &path).is_err());
        assert_eq!(warm_start_edge_memo(&memo, &path), WarmStartReport::default());
        assert!(memo.is_empty());
        cleanup(&path);
    }

    #[test]
    fn truncated_segment_degrades_only_its_shard() {
        let path = store("truncated_segment");
        let memo = EdgeMemo::with_capacity(256);
        for low in 1..=3 {
            memo.insert(key_in(3, low), small_edge(low as f64));
            memo.insert(key_in(7, low), small_edge(low as f64 + 0.5));
        }
        save_edge_memo(&memo, &path).unwrap();
        let seg3 = segment_path(&path, 3);
        let bytes = std::fs::read(&seg3).unwrap();
        std::fs::write(&seg3, &bytes[..12]).unwrap();

        // strict load rejects the whole store and leaves the memo untouched
        let strict = EdgeMemo::with_capacity(256);
        assert!(load_edge_memo(&strict, &path).is_err());
        assert!(strict.is_empty());

        // forgiving warm start degrades only shard 3
        let warm = EdgeMemo::with_capacity(256);
        let report = warm_start_edge_memo(&warm, &path);
        assert_eq!(report.degraded_segments, 1);
        assert_eq!(report.recovered_segments, 15);
        assert_eq!(report.edges, 3);
        for low in 1..=3 {
            assert!(warm.get(key_in(3, low)).is_none(), "degraded shard is cold");
            assert!(warm.get(key_in(7, low)).is_some(), "other shards warm");
        }
        // the degraded shard was re-marked dirty: the next flush heals it
        assert!(warm.shard_dirty(3));
        let healed = flush_edge_memo(&warm, &path);
        assert_eq!(healed.written_segments, 1);
        assert_eq!(healed.skipped_segments, 15);
        let again = EdgeMemo::with_capacity(256);
        let report = warm_start_edge_memo(&again, &path);
        assert_eq!(report.degraded_segments, 0);
        assert_eq!(report.recovered_segments, 16);
        assert_eq!(report.edges, 3);
        cleanup(&path);
    }

    #[test]
    fn trailing_garbage_in_segment_degrades_that_shard() {
        let path = store("trailing_segment");
        let memo = EdgeMemo::with_capacity(256);
        memo.insert(key_in(3, 1), small_edge(1.0));
        memo.insert(key_in(7, 1), small_edge(2.0));
        save_edge_memo(&memo, &path).unwrap();
        let seg7 = segment_path(&path, 7);
        let mut bytes = std::fs::read(&seg7).unwrap();
        bytes.push(0xFF);
        std::fs::write(&seg7, &bytes).unwrap();
        let warm = EdgeMemo::with_capacity(256);
        let report = warm_start_edge_memo(&warm, &path);
        assert_eq!(report.degraded_segments, 1);
        assert_eq!(report.edges, 1);
        assert!(warm.get(key_in(3, 1)).is_some());
        assert!(warm.get(key_in(7, 1)).is_none());
        cleanup(&path);
    }

    #[test]
    fn missing_store_is_a_silent_cold_start() {
        let path = store("never_written");
        let memo = EdgeMemo::with_capacity(64);
        assert_eq!(warm_start_edge_memo(&memo, &path), WarmStartReport::default());
        assert!(memo.is_empty());
        assert_eq!(memo.disk_loaded(), 0);
    }

    #[test]
    fn missing_segment_file_is_an_empty_shard() {
        let path = store("missing_segment");
        let memo = EdgeMemo::with_capacity(256);
        memo.insert(key_in(3, 1), small_edge(1.0));
        save_edge_memo(&memo, &path).unwrap();
        std::fs::remove_file(segment_path(&path, 5)).unwrap();
        let strict = EdgeMemo::with_capacity(256);
        assert_eq!(load_edge_memo(&strict, &path).unwrap(), 1);
        let warm = EdgeMemo::with_capacity(256);
        let report = warm_start_edge_memo(&warm, &path);
        assert_eq!(report.recovered_segments, 15);
        assert_eq!(report.degraded_segments, 0);
        assert_eq!(report.edges, 1);
        cleanup(&path);
    }

    #[test]
    fn dirty_skip_flush_rewrites_only_dirty_segments() {
        let path = store("dirty_skip");
        let memo = EdgeMemo::with_capacity(256);
        for low in 1..=3 {
            memo.insert(key_in(1, low), small_edge(low as f64));
            memo.insert(key_in(2, low), small_edge(low as f64 + 0.5));
        }
        save_edge_memo(&memo, &path).unwrap();
        let before: Vec<(PathBuf, Vec<u8>, std::time::SystemTime)> = (0..memo.shard_count())
            .map(|i| segment_path(&path, i))
            .chain([manifest_path(&path)])
            .map(|p| (p.clone(), std::fs::read(&p).unwrap(), mtime(&p)))
            .collect();
        std::thread::sleep(std::time::Duration::from_millis(25));

        // flush over an untouched memo: zero segments written, zero files
        // changed (bytes AND mtimes)
        let clean = flush_edge_memo(&memo, &path);
        assert_eq!(clean.written_segments, 0);
        assert_eq!(clean.skipped_segments, memo.shard_count());
        assert_eq!(clean.edges, 6);
        for (p, bytes, stamp) in &before {
            assert_eq!(&std::fs::read(p).unwrap(), bytes, "{p:?} bytes changed");
            assert_eq!(&mtime(p), stamp, "{p:?} was rewritten");
        }

        // dirty exactly one shard: exactly one segment is rewritten
        memo.insert(key_in(2, 99), small_edge(9.0));
        std::thread::sleep(std::time::Duration::from_millis(25));
        let partial = flush_edge_memo(&memo, &path);
        assert_eq!(partial.written_segments, 1);
        assert_eq!(partial.skipped_segments, memo.shard_count() - 1);
        assert_eq!(partial.edges, 7);
        for (p, bytes, stamp) in &before {
            if *p == segment_path(&path, 2) {
                assert_ne!(&std::fs::read(p).unwrap(), bytes);
            } else {
                assert_eq!(&std::fs::read(p).unwrap(), bytes, "{p:?} bytes changed");
                assert_eq!(&mtime(p), stamp, "{p:?} was rewritten");
            }
        }
        let warm = EdgeMemo::with_capacity(256);
        assert_eq!(warm_start_edge_memo(&warm, &path).edges, 7);
        cleanup(&path);
    }

    #[test]
    fn failed_segment_write_leaves_prior_store_intact() {
        let path = store("failed_flush");
        let memo = EdgeMemo::with_capacity(256);
        memo.insert(key_in(4, 1), small_edge(1.0));
        save_edge_memo(&memo, &path).unwrap();
        let seg4 = segment_path(&path, 4);
        let before = std::fs::read(&seg4).unwrap();

        // block the temp sibling with a directory: File::create fails, so
        // the flush cannot stage the new bytes — the regression scenario
        // where the old code would already have truncated the store
        memo.insert(key_in(4, 2), small_edge(2.0));
        std::fs::create_dir_all(sibling(&seg4, ".tmp")).unwrap();
        let failed = flush_edge_memo(&memo, &path);
        assert_eq!(failed.written_segments, 0);
        assert!(memo.shard_dirty(4), "failed shard must stay dirty for retry");
        assert_eq!(std::fs::read(&seg4).unwrap(), before, "prior segment lost");
        let prior = EdgeMemo::with_capacity(256);
        assert_eq!(load_edge_memo(&prior, &path).unwrap(), 1);
        assert!(prior.get(key_in(4, 1)).is_some());

        // unblock: the retry persists both edges
        std::fs::remove_dir_all(sibling(&seg4, ".tmp")).unwrap();
        let retried = flush_edge_memo(&memo, &path);
        assert_eq!(retried.written_segments, 1);
        let warm = EdgeMemo::with_capacity(256);
        assert_eq!(load_edge_memo(&warm, &path).unwrap(), 2);
        cleanup(&path);
    }

    #[test]
    fn legacy_v1_store_migrates_on_warm_start() {
        let path = store("legacy_migrate");
        write_legacy_store(&path, &sample_edges());
        let memo = EdgeMemo::with_capacity(256);
        let report = warm_start_edge_memo(&memo, &path);
        assert_eq!(report.edges, 5);
        assert_eq!(report.recovered_segments, 1);
        assert_eq!(report.degraded_segments, 0);
        assert!(path.is_dir(), "legacy file replaced by a segmented store");
        assert!(manifest_path(&path).is_file());
        assert!(!sibling(&path, ".legacy").exists());
        for (k, original) in sample_edges() {
            assert_same_edge(&memo.get(k).unwrap(), &original);
        }
        // migration already persisted everything: nothing left to flush
        let clean = flush_edge_memo(&memo, &path);
        assert_eq!(clean.written_segments, 0);
        // and a second process warm-starts from the migrated layout
        let warm = EdgeMemo::with_capacity(256);
        let report = warm_start_edge_memo(&warm, &path);
        assert_eq!(report.edges, 5);
        assert!(report.recovered_segments > 1);
        cleanup(&path);
    }

    #[test]
    fn corrupt_legacy_store_is_left_in_place_cold() {
        let path = store("legacy_corrupt");
        write_legacy_store(&path, &sample_edges());
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 5);
        std::fs::write(&path, &bytes).unwrap();
        let memo = EdgeMemo::with_capacity(256);
        assert_eq!(warm_start_edge_memo(&memo, &path), WarmStartReport::default());
        assert!(memo.is_empty());
        assert!(path.is_file(), "a bad legacy store is not destroyed");
        assert_eq!(std::fs::read(&path).unwrap(), bytes);
        cleanup(&path);
    }

    #[test]
    fn failed_legacy_migration_keeps_file_byte_identical() {
        let path = store("legacy_blocked");
        write_legacy_store(&path, &sample_edges());
        let original = std::fs::read(&path).unwrap();
        // a non-empty directory at the backup path makes the move-aside
        // rename fail mid-migration
        let backup = sibling(&path, ".legacy");
        std::fs::create_dir_all(backup.join("occupied")).unwrap();
        let memo = EdgeMemo::with_capacity(256);
        let report = warm_start_edge_memo(&memo, &path);
        assert_eq!(report.edges, 5, "edges still load even if migration fails");
        assert!(path.is_file(), "failed migration must not consume the store");
        assert_eq!(std::fs::read(&path).unwrap(), original);
        let reload = EdgeMemo::with_capacity(256);
        assert_eq!(load_edge_memo(&reload, &path).unwrap(), 5);
        let _ = std::fs::remove_dir_all(&backup);
        cleanup(&path);
    }

    #[test]
    fn flush_then_warm_start_counts_disk_state() {
        let path = store("flush_warm");
        let memo = EdgeMemo::with_capacity(256);
        for (k, e) in sample_edges() {
            memo.insert(k, e);
        }
        // a fresh store: only the one dirty shard (all sample keys land in
        // shard 0) gets a segment file — clean-empty shards write nothing
        let report = flush_edge_memo(&memo, &path);
        assert_eq!(report.edges, 5);
        assert_eq!(report.written_segments, 1);
        assert_eq!(report.skipped_segments, 15);
        assert!(segment_path(&path, 0).is_file());
        assert!(!segment_path(&path, 1).exists());
        let warm = EdgeMemo::with_capacity(256);
        let report = warm_start_edge_memo(&warm, &path);
        assert_eq!(report.edges, 5);
        assert_eq!(report.recovered_segments, 1);
        assert_eq!(warm.len(), 5);
        assert_eq!(warm.disk_loaded(), 5);
        cleanup(&path);
    }

    #[test]
    fn warm_start_screens_stale_programs() {
        let path = store("stale_screen");
        let memo = EdgeMemo::with_capacity(256);
        for (k, e) in sample_edges() {
            memo.insert(k, e);
        }
        memo.insert(17, stale_edge()); // shard 0, like the sample keys
        save_edge_memo(&memo, &path).unwrap();

        // the strict loader round-trips everything, broken bit included
        let strict = EdgeMemo::with_capacity(256);
        assert_eq!(load_edge_memo(&strict, &path).unwrap(), 6);
        assert_same_edge(&strict.get(17).unwrap(), &stale_edge());

        // warm start screens the stale program out, keeps its shard dirty
        let warm = EdgeMemo::with_capacity(256);
        let report = warm_start_edge_memo(&warm, &path);
        assert_eq!(report.edges, 5);
        assert_eq!(report.stale_rejected, 1);
        assert_eq!(report.degraded_segments, 0);
        assert!(warm.get(17).is_none(), "stale entry must not load");
        for (k, original) in sample_edges() {
            assert_same_edge(&warm.get(k).unwrap(), &original);
        }
        assert!(warm.shard_dirty(0), "screened shard must stay dirty");

        // the next flush heals the store: the stale entry is gone for good
        let healed = flush_edge_memo(&warm, &path);
        assert_eq!(healed.written_segments, 1);
        let again = EdgeMemo::with_capacity(256);
        let report = warm_start_edge_memo(&again, &path);
        assert_eq!(report.edges, 5);
        assert_eq!(report.stale_rejected, 0);
        let reload = EdgeMemo::with_capacity(256);
        assert_eq!(load_edge_memo(&reload, &path).unwrap(), 5);
        cleanup(&path);
    }

    #[test]
    fn legacy_warm_start_screens_and_migrates_clean() {
        let path = store("legacy_stale");
        let mut entries = sample_edges();
        entries.push((17, stale_edge()));
        write_legacy_store(&path, &entries);
        let memo = EdgeMemo::with_capacity(256);
        let report = warm_start_edge_memo(&memo, &path);
        assert_eq!(report.edges, 5);
        assert_eq!(report.stale_rejected, 1);
        assert!(memo.get(17).is_none());
        assert!(path.is_dir(), "migration still runs after screening");
        // the migrated store was written from the screened memo
        let reload = EdgeMemo::with_capacity(256);
        assert_eq!(load_edge_memo(&reload, &path).unwrap(), 5);
        cleanup(&path);
    }

    #[test]
    fn injected_segment_read_faults_degrade_deterministically() {
        let path = store("inject_read");
        let memo = EdgeMemo::with_capacity(256);
        for i in 0..memo.shard_count() {
            memo.insert(key_in(i as u64, 1), small_edge(i as f64 + 1.0));
        }
        save_edge_memo(&memo, &path).unwrap();
        // find a seed whose plan hits at least one segment-read site
        // (P(miss) ≈ (3/4)^16 per seed, so this terminates immediately)
        let (seed, firing) = (0u64..64)
            .find_map(|seed| {
                let plan = FaultPlan::new(seed);
                let firing: Vec<usize> = (0..memo.shard_count())
                    .filter(|&i| {
                        plan.fires_at(FaultSite::SegmentRead, i as u64, 0)
                    })
                    .collect();
                (!firing.is_empty()).then_some((seed, firing))
            })
            .unwrap();
        for _ in 0..2 {
            // the same plan degrades the same shards every time
            let plan = FaultPlan::new(seed);
            let warm = EdgeMemo::with_capacity(256);
            let report = warm_start_edge_memo_with(&warm, &path, Some(&plan));
            assert_eq!(report.degraded_segments, firing.len());
            assert_eq!(report.edges, memo.shard_count() - firing.len());
            for &i in &firing {
                assert!(warm.get(key_in(i as u64, 1)).is_none());
                assert!(warm.shard_dirty(i), "degraded shard stays dirty");
            }
            assert_eq!(plan.injected(FaultSite::SegmentRead), firing.len());
        }
        // without a plan the same store loads whole
        let clean = EdgeMemo::with_capacity(256);
        assert_eq!(warm_start_edge_memo(&clean, &path).edges,
                   memo.shard_count());
        cleanup(&path);
    }

    #[test]
    fn injected_segment_write_faults_keep_prior_bytes_and_retry() {
        let path = store("inject_write");
        let memo = EdgeMemo::with_capacity(256);
        for i in 0..memo.shard_count() {
            memo.insert(key_in(i as u64, 1), small_edge(i as f64 + 1.0));
        }
        save_edge_memo(&memo, &path).unwrap();
        let before: Vec<Vec<u8>> = (0..memo.shard_count())
            .map(|i| std::fs::read(segment_path(&path, i)).unwrap())
            .collect();
        // dirty every shard, then flush under an injecting plan
        for i in 0..memo.shard_count() {
            memo.insert(key_in(i as u64, 2), small_edge(9.0));
        }
        let (seed, firing) = (0u64..64)
            .find_map(|seed| {
                let plan = FaultPlan::new(seed);
                let firing: Vec<usize> = (0..memo.shard_count())
                    .filter(|&i| {
                        plan.fires_at(FaultSite::SegmentWrite, i as u64, 0)
                    })
                    .collect();
                (!firing.is_empty()).then_some((seed, firing))
            })
            .unwrap();
        let plan = FaultPlan::new(seed);
        let faulty = flush_edge_memo_with(&memo, &path, Some(&plan));
        assert_eq!(faulty.written_segments,
                   memo.shard_count() - firing.len());
        for &i in &firing {
            assert!(memo.shard_dirty(i), "failed shard stays dirty for retry");
            assert_eq!(std::fs::read(segment_path(&path, i)).unwrap(),
                       before[i],
                       "prior bytes must survive an injected write fault");
        }
        // a fault-free retry heals every failed shard
        let retried = flush_edge_memo(&memo, &path);
        assert_eq!(retried.written_segments, firing.len());
        let warm = EdgeMemo::with_capacity(256);
        assert_eq!(warm_start_edge_memo(&warm, &path).edges,
                   2 * memo.shard_count());
        cleanup(&path);
    }

    #[test]
    fn fsck_reports_occupancy_and_drops_orphans() {
        let path = store("fsck");
        let memo = EdgeMemo::with_capacity(256);
        for (k, e) in sample_edges() {
            memo.insert(k, e);
        }
        memo.insert(key_in(3, 1), small_edge(1.5));
        save_edge_memo(&memo, &path).unwrap();

        // plant an orphan beyond the shard range and a stale tmp file
        std::fs::write(path.join("seg_99.bin"), b"junk").unwrap();
        std::fs::write(path.join("seg_00.bin.tmp"), b"junk").unwrap();

        let report = fsck_store(&path, false).unwrap();
        assert_eq!(report.shards, memo.shard_count());
        assert_eq!(report.capacity, memo.capacity() as u64);
        assert_eq!(report.entries, memo.len());
        assert_eq!(report.corrupt_segments, 0);
        assert_eq!(report.missing_segments, 0, "full save writes every shard");
        assert_eq!(report.segments.len(), memo.shard_count());
        let seg0 = report.segments.iter().find(|s| s.index == 0).unwrap();
        assert!(seg0.ok && seg0.entries == 5 && seg0.bytes > 20);
        assert_eq!(
            report.orphans,
            vec!["seg_00.bin.tmp".to_string(), "seg_99.bin".to_string()]
        );
        assert!(!report.orphans_removed);
        assert!(path.join("seg_99.bin").exists(),
                "report-only fsck must not delete");

        let report = fsck_store(&path, true).unwrap();
        assert!(report.orphans_removed);
        assert!(!path.join("seg_99.bin").exists());
        assert!(!path.join("seg_00.bin.tmp").exists());
        // live segments untouched: a reload still sees every edge
        let reloaded = EdgeMemo::with_capacity(256);
        assert_eq!(load_edge_memo(&reloaded, &path).unwrap(), memo.len());
        // and a clean store fscks with no findings
        let report = fsck_store(&path, false).unwrap();
        assert!(report.orphans.is_empty());
        cleanup(&path);
    }

    #[test]
    fn fsck_counts_corrupt_segments_without_failing() {
        let path = store("fsck_corrupt");
        let memo = EdgeMemo::with_capacity(256);
        for (k, e) in sample_edges() {
            memo.insert(k, e);
        }
        save_edge_memo(&memo, &path).unwrap();
        // truncate shard 0's segment to its bare header
        let sp = segment_path(&path, 0);
        let bytes = std::fs::read(&sp).unwrap();
        std::fs::write(&sp, &bytes[..20]).unwrap();
        let report = fsck_store(&path, false).unwrap();
        assert_eq!(report.corrupt_segments, 1);
        let seg0 = report.segments.iter().find(|s| s.index == 0).unwrap();
        assert!(!seg0.ok);
        assert_eq!(seg0.entries, 0);
        assert_eq!(report.entries, 0, "all sample keys live in shard 0");
        cleanup(&path);
    }

    #[test]
    fn fsck_rejects_non_store_paths() {
        let path = store("fsck_missing");
        assert!(fsck_store(&path, false).is_err(), "missing store");
        std::fs::create_dir_all(&path).unwrap();
        assert!(fsck_store(&path, false).is_err(), "no manifest");
        cleanup(&path);
    }
}
