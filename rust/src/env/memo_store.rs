//! Disk persistence for the [`EdgeMemo`] transposition table: the
//! process-crossing tier of the memo subsystem.
//!
//! The paper's Macro Thinking stage amortizes exploration over an
//! experience store of optimization trajectories; an in-memory memo only
//! amortizes within one process. This module serializes the memo's
//! `(key → CachedEdge)` entries — including the `Arc<Program>` payloads —
//! to a versioned, self-describing binary file, so a later `repro eval` /
//! `train-ppo` run warm-starts from everything earlier runs computed
//! (the `--memo-store <path>` flag).
//!
//! Framing is hand-rolled (the workspace allows no serialization deps):
//! an 8-byte magic that doubles as the format version, a u64 entry
//! count, then length-prefixed little-endian records. Floats travel as
//! IEEE bits, so a loaded edge replays **bit-identically** to its
//! freshly-computed twin (guarded by the persistence property in
//! `rust/tests/properties.rs`). Entries are written key-sorted so equal
//! memo contents produce byte-identical files.
//!
//! Loading is strict but the entry points are forgiving:
//! [`load_edge_memo`] rejects bad magic (wrong version), truncation,
//! implausible lengths, unknown tags and trailing bytes with an `Err`;
//! [`warm_start_edge_memo`] turns any of those into a logged cold start,
//! never a panic — a corrupt store costs recomputation, not the run.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::memo::{CachedEdge, EdgeMemo};
use super::reward::StepSignal;
use crate::graph::{Mutation, MutationKind};
use crate::kir::{Kernel, LoopOrder, Program, Schedule};

/// Format magic; the trailing digit is the version. Bump it on any layout
/// change — old stores then fail the magic check and cold-start cleanly.
const MAGIC: &[u8; 8] = b"QMMCEDG1";

/// Load-time sanity bounds: a corrupted length prefix must bail early,
/// not drive a multi-gigabyte allocation.
const MAX_ENTRIES: u64 = 10_000_000;
const MAX_KERNELS: u32 = 4_096;
const MAX_NODES: u32 = 100_000;
const MAX_MUTATIONS: u32 = 10_000;
const MAX_NAME: u32 = 4_096;

// --- primitive framing -----------------------------------------------

fn w_byte(w: &mut impl Write, v: u8) -> Result<()> {
    w.write_all(&[v])?;
    Ok(())
}

fn w_u32(w: &mut impl Write, v: usize) -> Result<()> {
    let v = u32::try_from(v).context("field exceeds u32 framing")?;
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_f32(w: &mut impl Write, v: f32) -> Result<()> {
    w.write_all(&v.to_bits().to_le_bytes())?;
    Ok(())
}

fn w_f64(w: &mut impl Write, v: f64) -> Result<()> {
    w_u64(w, v.to_bits())
}

fn w_str(w: &mut impl Write, s: &str) -> Result<()> {
    if s.len() as u64 > MAX_NAME as u64 {
        bail!("string field of {} bytes exceeds framing bound", s.len());
    }
    w_u32(w, s.len())?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn r_byte(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b).context("truncated store")?;
    Ok(b[0])
}

fn r_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("truncated store")?;
    Ok(u32::from_le_bytes(b))
}

fn r_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("truncated store")?;
    Ok(u64::from_le_bytes(b))
}

fn r_f32(r: &mut impl Read) -> Result<f32> {
    Ok(f32::from_bits(r_u32(r)?))
}

fn r_f64(r: &mut impl Read) -> Result<f64> {
    Ok(f64::from_bits(r_u64(r)?))
}

fn r_str(r: &mut impl Read) -> Result<String> {
    let len = r_u32(r)?;
    if len > MAX_NAME {
        bail!("string length {len} exceeds framing bound");
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf).context("truncated store")?;
    String::from_utf8(buf).context("non-UTF-8 string field")
}

// --- record framing --------------------------------------------------

fn write_schedule(w: &mut impl Write, s: &Schedule) -> Result<()> {
    match s.block_tile {
        None => w_byte(w, 0)?,
        Some((m, n, k)) => {
            w_byte(w, 1)?;
            w_u32(w, m)?;
            w_u32(w, n)?;
            w_u32(w, k)?;
        }
    }
    match s.reg_tile {
        None => w_byte(w, 0)?,
        Some((m, n)) => {
            w_byte(w, 1)?;
            w_u32(w, m)?;
            w_u32(w, n)?;
        }
    }
    w_u32(w, s.pipeline_depth)?;
    w_byte(w, match s.loop_order {
        LoopOrder::Naive => 0,
        LoopOrder::Coalesced => 1,
        LoopOrder::Blocked => 2,
    })?;
    w_u32(w, s.vector_width)
}

fn read_schedule(r: &mut impl Read) -> Result<Schedule> {
    let block_tile = match r_byte(r)? {
        0 => None,
        1 => Some((
            r_u32(r)? as usize,
            r_u32(r)? as usize,
            r_u32(r)? as usize,
        )),
        t => bail!("bad block-tile tag {t}"),
    };
    let reg_tile = match r_byte(r)? {
        0 => None,
        1 => Some((r_u32(r)? as usize, r_u32(r)? as usize)),
        t => bail!("bad reg-tile tag {t}"),
    };
    let pipeline_depth = r_u32(r)? as usize;
    let loop_order = match r_byte(r)? {
        0 => LoopOrder::Naive,
        1 => LoopOrder::Coalesced,
        2 => LoopOrder::Blocked,
        t => bail!("bad loop-order tag {t}"),
    };
    let vector_width = r_u32(r)? as usize;
    Ok(Schedule { block_tile, reg_tile, pipeline_depth, loop_order, vector_width })
}

fn write_mutation(w: &mut impl Write, m: &Mutation) -> Result<()> {
    w_u32(w, m.node)?;
    match m.kind {
        MutationKind::BoundaryDrop { frac } => {
            w_byte(w, 0)?;
            w_f32(w, frac)
        }
        MutationKind::RaceCorruption { scale } => {
            w_byte(w, 1)?;
            w_f32(w, scale)
        }
        MutationKind::IndexOffset => w_byte(w, 2),
        MutationKind::SkippedOp => w_byte(w, 3),
        MutationKind::BadAccumInit { bias } => {
            w_byte(w, 4)?;
            w_f32(w, bias)
        }
    }
}

fn read_mutation(r: &mut impl Read) -> Result<Mutation> {
    let node = r_u32(r)? as usize;
    let kind = match r_byte(r)? {
        0 => MutationKind::BoundaryDrop { frac: r_f32(r)? },
        1 => MutationKind::RaceCorruption { scale: r_f32(r)? },
        2 => MutationKind::IndexOffset,
        3 => MutationKind::SkippedOp,
        4 => MutationKind::BadAccumInit { bias: r_f32(r)? },
        t => bail!("bad mutation tag {t}"),
    };
    Ok(Mutation { node, kind })
}

fn write_program(w: &mut impl Write, p: &Program) -> Result<()> {
    w_u32(w, p.kernels.len())?;
    for k in &p.kernels {
        w_str(w, &k.name)?;
        w_u32(w, k.nodes.len())?;
        for &n in &k.nodes {
            w_u32(w, n)?;
        }
        write_schedule(w, &k.schedule)?;
    }
    w_u32(w, p.mutations.len())?;
    for m in &p.mutations {
        write_mutation(w, m)?;
    }
    w_byte(w, p.compile_broken as u8)
}

fn read_program(r: &mut impl Read) -> Result<Program> {
    let n_kernels = r_u32(r)?;
    if n_kernels > MAX_KERNELS {
        bail!("implausible kernel count {n_kernels}");
    }
    let mut kernels = Vec::with_capacity(n_kernels as usize);
    for _ in 0..n_kernels {
        let name = r_str(r)?;
        let n_nodes = r_u32(r)?;
        if n_nodes > MAX_NODES {
            bail!("implausible node count {n_nodes}");
        }
        let mut nodes = Vec::with_capacity(n_nodes as usize);
        for _ in 0..n_nodes {
            nodes.push(r_u32(r)? as usize);
        }
        let schedule = read_schedule(r)?;
        kernels.push(Kernel { nodes, schedule, name });
    }
    let n_mutations = r_u32(r)?;
    if n_mutations > MAX_MUTATIONS {
        bail!("implausible mutation count {n_mutations}");
    }
    let mut mutations = Vec::with_capacity(n_mutations as usize);
    for _ in 0..n_mutations {
        mutations.push(read_mutation(r)?);
    }
    let compile_broken = match r_byte(r)? {
        0 => false,
        1 => true,
        t => bail!("bad compile-broken tag {t}"),
    };
    Ok(Program { kernels, mutations, compile_broken })
}

fn write_signal(w: &mut impl Write, s: StepSignal) -> Result<()> {
    match s {
        StepSignal::CompileFail => w_byte(w, 0),
        StepSignal::WrongResult => w_byte(w, 1),
        StepSignal::Rejected => w_byte(w, 2),
        StepSignal::Correct { prev, now } => {
            w_byte(w, 3)?;
            w_f64(w, prev)?;
            w_f64(w, now)
        }
        StepSignal::Stop { best } => {
            w_byte(w, 4)?;
            w_f64(w, best)
        }
    }
}

fn read_signal(r: &mut impl Read) -> Result<StepSignal> {
    Ok(match r_byte(r)? {
        0 => StepSignal::CompileFail,
        1 => StepSignal::WrongResult,
        2 => StepSignal::Rejected,
        3 => StepSignal::Correct { prev: r_f64(r)?, now: r_f64(r)? },
        4 => StepSignal::Stop { best: r_f64(r)? },
        t => bail!("bad signal tag {t}"),
    })
}

fn write_edge(w: &mut impl Write, edge: &CachedEdge) -> Result<()> {
    // `from_disk` is not stored: every loaded edge is a disk edge
    match &edge.program {
        None => w_byte(w, 0)?,
        Some(p) => {
            w_byte(w, 1)?;
            write_program(w, p)?;
        }
    }
    write_signal(w, edge.signal)?;
    w_f64(w, edge.speedup)
}

fn read_edge(r: &mut impl Read) -> Result<CachedEdge> {
    let program = match r_byte(r)? {
        0 => None,
        1 => Some(Arc::new(read_program(r)?)),
        t => bail!("bad edge-program tag {t}"),
    };
    let signal = read_signal(r)?;
    let speedup = r_f64(r)?;
    Ok(CachedEdge { program, signal, speedup, from_disk: true })
}

// --- entry points ----------------------------------------------------

/// Serialize every resident edge of `memo` to `path` (key-sorted, so
/// equal contents yield byte-identical files). Returns the edge count.
pub fn save_edge_memo(memo: &EdgeMemo, path: &Path) -> Result<usize> {
    let mut entries = memo.entries();
    entries.sort_by_key(|&(k, _)| k);
    let file = File::create(path)
        .with_context(|| format!("create edge-memo store {path:?}"))?;
    let mut w = BufWriter::new(file);
    w.write_all(MAGIC)?;
    w_u64(&mut w, entries.len() as u64)?;
    for (key, edge) in &entries {
        w_u64(&mut w, *key)?;
        write_edge(&mut w, edge)?;
    }
    w.flush()?;
    Ok(entries.len())
}

/// Load a store written by [`save_edge_memo`] into `memo`, marking every
/// entry `from_disk`. Strict: bad magic (wrong version), truncation,
/// implausible lengths, unknown tags and trailing bytes are all `Err`s,
/// and on error the memo is left untouched (entries are parsed in full
/// before any insert).
pub fn load_edge_memo(memo: &EdgeMemo, path: &Path) -> Result<usize> {
    let file = File::open(path)
        .with_context(|| format!("open edge-memo store {path:?}"))?;
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("store too short for header")?;
    if magic != *MAGIC {
        bail!("{path:?}: not a v1 edge-memo store (magic {magic:02x?})");
    }
    let n = r_u64(&mut r)?;
    if n > MAX_ENTRIES {
        bail!("{path:?}: implausible entry count {n}");
    }
    let mut entries = Vec::with_capacity(n as usize);
    for _ in 0..n {
        let key = r_u64(&mut r)?;
        entries.push((key, read_edge(&mut r)?));
    }
    let mut trail = [0u8; 1];
    if r.read(&mut trail)? != 0 {
        bail!("{path:?}: trailing bytes after {n} entries");
    }
    let loaded = entries.len();
    for (key, edge) in entries {
        memo.insert(key, edge);
    }
    memo.note_disk_loaded(loaded);
    Ok(loaded)
}

/// Best-effort warm start behind the `--memo-store` flag: a missing
/// store is a silent cold start (the first run of a pair), a corrupt /
/// truncated / version-mismatched one logs and cold-starts, a good one
/// logs the edge count. Never panics, never fails the run.
pub fn warm_start_edge_memo(memo: &EdgeMemo, path: &Path) -> usize {
    if !path.exists() {
        return 0;
    }
    match load_edge_memo(memo, path) {
        Ok(n) => {
            eprintln!(
                "edge-memo: warm-started {n} edges from {}",
                path.display()
            );
            n
        }
        Err(e) => {
            eprintln!(
                "edge-memo: ignoring store {}: {e:#} (cold start)",
                path.display()
            );
            0
        }
    }
}

/// Best-effort flush behind the `--memo-store` flag: persists the memo,
/// logging instead of failing on I/O errors (a full disk costs the next
/// run its warm start, not this run its results).
pub fn flush_edge_memo(memo: &EdgeMemo, path: &Path) -> usize {
    match save_edge_memo(memo, path) {
        Ok(n) => {
            eprintln!("edge-memo: persisted {n} edges to {}", path.display());
            n
        }
        Err(e) => {
            eprintln!(
                "edge-memo: failed to persist to {}: {e:#}",
                path.display()
            );
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("qimeng_memo_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    /// One edge of every flavour the stepper produces.
    fn sample_edges() -> Vec<(u64, CachedEdge)> {
        let program = Program {
            kernels: vec![
                Kernel {
                    nodes: vec![2, 3, 4],
                    schedule: Schedule {
                        block_tile: Some((128, 64, 32)),
                        reg_tile: Some((8, 4)),
                        pipeline_depth: 2,
                        loop_order: LoopOrder::Blocked,
                        vector_width: 4,
                    },
                    name: "k0_matmul".to_string(),
                },
                Kernel {
                    nodes: vec![5],
                    schedule: Schedule::default(),
                    name: "k1_relu".to_string(),
                },
            ],
            mutations: vec![
                Mutation { node: 2, kind: MutationKind::BoundaryDrop { frac: 0.25 } },
                Mutation { node: 3, kind: MutationKind::RaceCorruption { scale: 0.5 } },
                Mutation { node: 4, kind: MutationKind::IndexOffset },
                Mutation { node: 5, kind: MutationKind::SkippedOp },
                Mutation { node: 5, kind: MutationKind::BadAccumInit { bias: 1.5 } },
            ],
            compile_broken: true,
        };
        vec![
            (7, CachedEdge {
                program: Some(Arc::new(program)),
                signal: StepSignal::Correct { prev: 0.1, now: 0.7 },
                speedup: 2.25,
                from_disk: false,
            }),
            (9, CachedEdge {
                program: None,
                signal: StepSignal::Rejected,
                speedup: 1.0,
                from_disk: false,
            }),
            (11, CachedEdge {
                program: None,
                signal: StepSignal::CompileFail,
                speedup: 1.0,
                from_disk: false,
            }),
            (13, CachedEdge {
                program: None,
                signal: StepSignal::WrongResult,
                speedup: 1.0,
                from_disk: false,
            }),
            (15, CachedEdge {
                program: None,
                signal: StepSignal::Stop { best: 3.5 },
                speedup: 3.5,
                from_disk: false,
            }),
        ]
    }

    fn assert_same_edge(a: &CachedEdge, b: &CachedEdge) {
        match (&a.program, &b.program) {
            (None, None) => {}
            (Some(x), Some(y)) => assert_eq!(**x, **y),
            _ => panic!("program presence diverged"),
        }
        assert_eq!(a.signal, b.signal);
        assert_eq!(a.speedup.to_bits(), b.speedup.to_bits());
    }

    #[test]
    fn roundtrip_preserves_every_edge_flavour() {
        let path = tmp("roundtrip.bin");
        let memo = EdgeMemo::with_capacity(64);
        for (k, e) in sample_edges() {
            memo.insert(k, e);
        }
        let saved = save_edge_memo(&memo, &path).unwrap();
        assert_eq!(saved, 5);

        let loaded_memo = EdgeMemo::with_capacity(64);
        let loaded = load_edge_memo(&loaded_memo, &path).unwrap();
        assert_eq!(loaded, 5);
        assert_eq!(loaded_memo.disk_loaded(), 5);
        for (k, original) in sample_edges() {
            let got = loaded_memo.get(k).expect("edge survived the roundtrip");
            assert!(got.from_disk, "loaded edges must be marked from_disk");
            assert_same_edge(&got, &original);
        }
        assert!(loaded_memo.stats().disk_hits > 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_is_deterministic_for_equal_contents() {
        let (p1, p2) = (tmp("det1.bin"), tmp("det2.bin"));
        let a = EdgeMemo::with_capacity(64);
        let b = EdgeMemo::with_capacity(64);
        for (k, e) in sample_edges() {
            a.insert(k, e);
        }
        // reversed insertion order must not change the bytes
        for (k, e) in sample_edges().into_iter().rev() {
            b.insert(k, e);
        }
        save_edge_memo(&a, &p1).unwrap();
        save_edge_memo(&b, &p2).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p2);
    }

    #[test]
    fn wrong_version_or_magic_degrades_to_cold() {
        let path = tmp("wrong_magic.bin");
        let mut bytes = b"QMMCEDG9".to_vec(); // future version
        bytes.extend_from_slice(&0u64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let memo = EdgeMemo::with_capacity(8);
        assert!(load_edge_memo(&memo, &path).is_err());
        assert_eq!(warm_start_edge_memo(&memo, &path), 0);
        assert!(memo.is_empty(), "rejected store must leave the memo cold");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncated_store_degrades_to_cold() {
        let path = tmp("truncated.bin");
        let memo = EdgeMemo::with_capacity(64);
        for (k, e) in sample_edges() {
            memo.insert(k, e);
        }
        save_edge_memo(&memo, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let cold = EdgeMemo::with_capacity(64);
        assert!(load_edge_memo(&cold, &path).is_err());
        assert_eq!(warm_start_edge_memo(&cold, &path), 0);
        assert!(cold.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn trailing_garbage_degrades_to_cold() {
        let path = tmp("trailing.bin");
        let memo = EdgeMemo::with_capacity(8);
        memo.insert(1, CachedEdge {
            program: None,
            signal: StepSignal::Rejected,
            speedup: 1.0,
            from_disk: false,
        });
        save_edge_memo(&memo, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.push(0xFF);
        std::fs::write(&path, &bytes).unwrap();
        let cold = EdgeMemo::with_capacity(8);
        assert!(load_edge_memo(&cold, &path).is_err());
        assert!(cold.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_count_degrades_to_cold() {
        let path = tmp("bad_count.bin");
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let memo = EdgeMemo::with_capacity(8);
        assert!(load_edge_memo(&memo, &path).is_err());
        assert_eq!(warm_start_edge_memo(&memo, &path), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_store_is_a_silent_cold_start() {
        let path = tmp("never_written.bin");
        let _ = std::fs::remove_file(&path);
        let memo = EdgeMemo::with_capacity(8);
        assert_eq!(warm_start_edge_memo(&memo, &path), 0);
        assert!(memo.is_empty());
        assert_eq!(memo.disk_loaded(), 0);
    }

    #[test]
    fn flush_then_warm_start_counts_disk_state() {
        let path = tmp("flush_warm.bin");
        let memo = EdgeMemo::with_capacity(64);
        for (k, e) in sample_edges() {
            memo.insert(k, e);
        }
        assert_eq!(flush_edge_memo(&memo, &path), 5);
        let warm = EdgeMemo::with_capacity(64);
        assert_eq!(warm_start_edge_memo(&warm, &path), 5);
        assert_eq!(warm.len(), 5);
        assert_eq!(warm.disk_loaded(), 5);
        let _ = std::fs::remove_file(&path);
    }
}
