//! Shared transition memo: a sharded, thread-safe, capacity-bounded
//! transposition table over the env's edge-deterministic transitions.
//!
//! [`OptimEnv`](super::OptimEnv) transitions are fully determined by
//! (task, spec, profile, env config, base seed, state path, action) — the
//! paper's tree-structured environment semantics. [`TreeEnv`](super::TreeEnv)
//! used to keep a private `(node, action) → edge` map per env; promoting
//! it to this shared table lets the whole eval stack — every
//! [`OptimEnv`], greedy runner, and the
//! [`BatchRunner`](crate::eval::BatchRunner)'s method × suite × gpu sweep —
//! replay transitions any worker has already paid for. Methods that run
//! identical episodes (e.g. the greedy surrogate under two macro labels),
//! repeated sweeps, and PPO's revisits all hit the same entries.
//!
//! Keys combine an **edge context** (task id + graph fingerprint + spec +
//! profile + the transition-relevant env-config bits + base seed) with
//! the state `path_hash` and the action, so entries can only alias within
//! one (task, spec, profile, seed-class) — exactly the scope in which
//! transitions are reproducible. A hit replays the stored (program,
//! signal, speedup) onto the live state; because the transition being
//! skipped is deterministic, episode outcomes are bit-identical with the
//! memo on, off, shared, or under eviction pressure (guarded by
//! `prop_edge_memo_episode_bitwise_identical` and `rust/tests/batch.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::reward::StepSignal;
use super::stepper::EnvConfig;
use crate::gpusim::{combine, spec_tag, Fnv, GpuSpec, MemoStats, ShardedMemo};
use crate::kir::Program;
use crate::microcode::LlmProfile;
use crate::tasks::Task;

/// Default total capacity. Edges carry whole programs, so this is kept an
/// order of magnitude below the cost cache's bound; overflow LRU-evicts
/// (recompute, never unbounded memory).
const DEFAULT_MAX_ENTRIES: usize = 200_000;

/// One memoized transition: what applying `action` at the keyed state
/// produced. `program: None` records a failed/rejected step (state
/// unchanged); `speedup` is the post-step speedup (meaningful only when
/// the program moved). The program is `Arc`-wrapped so a table hit
/// clones a refcount, not a multi-kernel program, inside the shard lock
/// (the [`ShardedMemo`] contract: values must be cheap to clone).
/// `from_disk` marks entries warm-started from a persisted store (see
/// [`super::memo_store`]) so hits on them can be surfaced separately —
/// it is deliberately excluded from edge equality: a disk edge replays
/// bit-identically to its freshly-computed twin.
#[derive(Clone, Debug)]
pub struct CachedEdge {
    pub program: Option<Arc<Program>>,
    pub signal: StepSignal,
    pub speedup: f64,
    pub from_disk: bool,
}

/// The shared transition table, plus the disk-tier counters backing the
/// `--memo-store` persistence flow (how many edges were warm-started
/// from a store, and how many lookups those edges have served).
pub struct EdgeMemo {
    edges: ShardedMemo<CachedEdge>,
    disk_loaded: AtomicUsize,
    disk_hits: AtomicUsize,
}

impl Default for EdgeMemo {
    fn default() -> Self {
        Self::new()
    }
}

impl EdgeMemo {
    pub fn new() -> EdgeMemo {
        Self::with_capacity(DEFAULT_MAX_ENTRIES)
    }

    /// A memo bounded to `max_entries` edges (LRU eviction per shard).
    /// Tiny capacities are legitimate — the differential tests run under
    /// eviction pressure to prove outcomes never depend on residency.
    pub fn with_capacity(max_entries: usize) -> EdgeMemo {
        EdgeMemo {
            edges: ShardedMemo::new(max_entries),
            disk_loaded: AtomicUsize::new(0),
            disk_hits: AtomicUsize::new(0),
        }
    }

    pub fn get(&self, key: u64) -> Option<CachedEdge> {
        let hit = self.edges.get(key);
        if matches!(&hit, Some(e) if e.from_disk) {
            self.disk_hits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    pub fn insert(&self, key: u64, edge: CachedEdge) {
        self.edges.insert(key, edge);
    }

    /// Traffic counters (`hits + misses == lookups`; evictions monotone;
    /// `disk_hits` counts hits served by warm-started entries).
    pub fn stats(&self) -> MemoStats {
        MemoStats {
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            ..self.edges.stats()
        }
    }

    pub fn len(&self) -> usize {
        self.edges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }

    /// Residency bound: the most edges the memo keeps live, and so the
    /// most a flush can persist (see [`ShardedMemo::capacity`]).
    pub fn capacity(&self) -> usize {
        self.edges.capacity()
    }

    /// Snapshot every resident `(key, edge)` pair (see
    /// [`ShardedMemo::entries`]); the persistence tier serializes this.
    pub fn entries(&self) -> Vec<(u64, CachedEdge)> {
        self.edges.entries()
    }

    /// Number of shards — one persisted segment file per shard (see
    /// [`super::memo_store`]).
    pub fn shard_count(&self) -> usize {
        self.edges.shard_count()
    }

    /// Which shard/segment a key belongs to (stable across processes).
    pub fn shard_of(key: u64) -> usize {
        ShardedMemo::<CachedEdge>::shard_index(key)
    }

    /// Live entry count of one shard.
    pub fn shard_len(&self, i: usize) -> usize {
        self.edges.shard_len(i)
    }

    /// Snapshot one shard's resident `(key, edge)` pairs.
    pub fn entries_of_shard(&self, i: usize) -> Vec<(u64, CachedEdge)> {
        self.edges.entries_of_shard(i)
    }

    /// Whether shard `i`'s entry set changed since its last flush/load.
    pub fn shard_dirty(&self, i: usize) -> bool {
        self.edges.shard_dirty(i)
    }

    /// Flush handshake: clear shard `i`'s dirty flag and snapshot its
    /// entries under one lock (see [`ShardedMemo::take_shard_for_flush`]).
    pub fn take_shard_for_flush(&self, i: usize) -> Vec<(u64, CachedEdge)> {
        self.edges.take_shard_for_flush(i)
    }

    /// Mark shard `i` clean (a warm start that restored the shard to
    /// exactly its on-disk contents).
    pub fn clear_shard_dirty(&self, i: usize) {
        self.edges.clear_shard_dirty(i)
    }

    /// Re-mark shard `i` dirty (failed segment write: retry next flush).
    pub fn mark_shard_dirty(&self, i: usize) {
        self.edges.mark_shard_dirty(i)
    }

    /// Number of edges warm-started from a persisted store.
    pub fn disk_loaded(&self) -> usize {
        self.disk_loaded.load(Ordering::Relaxed)
    }

    pub(crate) fn note_disk_loaded(&self, n: usize) {
        self.disk_loaded.fetch_add(n, Ordering::Relaxed);
    }
}

impl std::fmt::Debug for EdgeMemo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(
            f,
            "EdgeMemo {{ entries: {}, hits: {}, misses: {}, evictions: {}, \
             disk: {}/{} }}",
            self.len(), s.hits, s.misses, s.evictions,
            s.disk_hits, self.disk_loaded()
        )
    }
}

/// Fingerprint of everything that scopes a transition besides the state
/// and action: the task (id + perf-graph fingerprint — the verif twin is
/// derived from the same id), the GPU spec, the full competence profile
/// (profiles are scaled/perturbed by the harness, so every knob is
/// hashed), the transition-relevant env-config bits (`cuda` changes
/// micro-coding error rates, `verif_trials` changes the correctness
/// check), and the episode's base seed (the seed-class). `max_steps` and
/// reward shaping are deliberately excluded: truncation and rewards are
/// reconstructed at replay time, so envs with different budgets or reward
/// configs still share edges.
pub(crate) fn edge_context(task: &Task, graph_ctx: u64, spec: &GpuSpec,
                           profile: &LlmProfile, cfg: &EnvConfig,
                           base_seed: u64) -> u64 {
    let mut h = Fnv::new();
    h.bytes(task.id.as_bytes());
    h.u64(graph_ctx);
    h.u64(spec_tag(spec));
    h.bytes(profile.name.as_bytes());
    h.f64(profile.atomic_err);
    h.f64(profile.holistic_err);
    h.f64(profile.complexity_exp);
    h.f64(profile.compile_frac);
    h.f64(profile.param_skill);
    h.f64(profile.ambition);
    h.f64(profile.cuda_err_mult);
    h.usize(profile.refine_rounds);
    h.byte(cfg.cuda as u8);
    h.usize(cfg.verif_trials);
    h.u64(base_seed);
    h.finish()
}

/// The full table key of one (state, action) edge under a context.
pub(crate) fn edge_key(ctx: u64, path_hash: u64, action: usize) -> u64 {
    combine(ctx, path_hash, action as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::microcode::ProfileId;

    fn any_task() -> Task {
        crate::tasks::kernelbench_level(1)[0].clone()
    }

    fn ctx_of(task: &Task, seed: u64, cuda: bool) -> u64 {
        let shapes = crate::graph::infer_shapes(&task.graph);
        edge_context(
            task,
            crate::gpusim::graph_fingerprint(&task.graph, &shapes),
            &GpuSpec::a100(),
            &LlmProfile::get(ProfileId::GeminiPro25),
            &EnvConfig { cuda, ..Default::default() },
            seed,
        )
    }

    #[test]
    fn context_scopes_seed_and_language() {
        let t = any_task();
        let base = ctx_of(&t, 7, false);
        assert_eq!(base, ctx_of(&t, 7, false), "context must be stable");
        assert_ne!(base, ctx_of(&t, 8, false), "seed-class must split");
        assert_ne!(base, ctx_of(&t, 7, true), "target language must split");
    }

    #[test]
    fn context_ignores_step_budget_and_rewards() {
        let t = any_task();
        let shapes = crate::graph::infer_shapes(&t.graph);
        let gctx = crate::gpusim::graph_fingerprint(&t.graph, &shapes);
        let profile = LlmProfile::get(ProfileId::GeminiFlash25);
        let spec = GpuSpec::v100();
        let short = EnvConfig { max_steps: 3, ..Default::default() };
        let long = EnvConfig { max_steps: 30, ..Default::default() };
        assert_eq!(
            edge_context(&t, gctx, &spec, &profile, &short, 1),
            edge_context(&t, gctx, &spec, &profile, &long, 1),
            "step budgets share edges (truncation replays outside the memo)"
        );
    }

    #[test]
    fn stats_identity_holds() {
        let memo = EdgeMemo::with_capacity(8);
        let edge = CachedEdge {
            program: None,
            signal: StepSignal::Rejected,
            speedup: 1.0,
            from_disk: false,
        };
        assert!(memo.get(1).is_none());
        memo.insert(1, edge.clone());
        assert!(memo.get(1).is_some());
        memo.insert(1, edge); // same-key reinsert: no eviction bookkeeping
        let s = memo.stats();
        assert_eq!(s.hits + s.misses, s.lookups);
        assert_eq!((s.lookups, s.hits, s.misses, s.evictions), (2, 1, 1, 0));
        assert_eq!(memo.len(), 1);
        assert_eq!(s.disk_hits, 0);
    }

    #[test]
    fn disk_hits_counted_only_for_disk_edges() {
        let memo = EdgeMemo::with_capacity(8);
        let live = CachedEdge {
            program: None,
            signal: StepSignal::Rejected,
            speedup: 1.0,
            from_disk: false,
        };
        let disk = CachedEdge { from_disk: true, ..live.clone() };
        memo.insert(1, live);
        memo.insert(2, disk);
        memo.note_disk_loaded(1);
        memo.get(1);
        memo.get(2);
        memo.get(2);
        let s = memo.stats();
        assert_eq!((s.hits, s.disk_hits), (3, 2));
        assert_eq!(memo.disk_loaded(), 1);
    }
}
