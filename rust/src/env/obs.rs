//! Observation featurizer: the structural stand-in for the paper's
//! LLM-token observation (DESIGN.md substitution table). 64 features over
//! task structure, schedule state, hardware spec, progress and history —
//! everything the Macro-Thinking policy needs to pick (type, region).
//!
//! Must stay in sync with `python/compile/model.py::CONFIG["obs_dim"]`.

use crate::gpusim::{GpuSpec, Pricer};
use crate::graph::{Graph, Op, OpClass};
use crate::kir::Program;
use crate::transform::{ACTION_DIM, NUM_OPT_TYPES};
use crate::kir::MAX_REGIONS;

/// Observation dimension (= L2 model obs_dim).
pub const OBS_DIM: usize = 64;

fn log_norm(x: f64, scale: f64) -> f32 {
    ((x.max(1.0)).ln() / scale) as f32
}

/// Featurize the current environment state.
///
/// `pricer`: the env's pricing handle — the hottest-kernel feature prices
/// every kernel, and routing it through the per-sweep cost memo makes the
/// per-step observation encode a set of cache hits instead of fresh
/// cost-model walks (bit-identical either way);
/// `history`: most-recent-first action indices (up to 4 used);
/// `speedup`/`best_speedup`: current and best-so-far vs eager;
/// `step_frac`: step / max_steps; `mask`: current action validity.
#[allow(clippy::too_many_arguments)]
pub fn featurize(
    g: &Graph,
    shapes: &[Vec<usize>],
    p: &Program,
    spec: &GpuSpec,
    pricer: &Pricer,
    mask: &[bool],
    history: &[usize],
    speedup: f64,
    best_speedup: f64,
    step_frac: f32,
) -> Vec<f32> {
    let mut f = Vec::with_capacity(OBS_DIM);

    // ---- task structure (12)
    let mut class_counts = [0f32; 4];
    let mut hot = [0f32; 6]; // matmul, conv, attention, softmax-ish, lstm, bmm
    let mut flops = 0f64;
    let mut bytes = 0f64;
    for (id, node) in g.nodes.iter().enumerate() {
        match node.op.class() {
            OpClass::Contraction => class_counts[0] += 1.0,
            OpClass::Elementwise => class_counts[1] += 1.0,
            OpClass::Reduction => class_counts[2] += 1.0,
            OpClass::Movement => class_counts[3] += 1.0,
            OpClass::Input => continue,
        }
        match node.op {
            Op::MatMul => hot[0] += 1.0,
            Op::Conv2d { .. } => hot[1] += 1.0,
            Op::Attention => hot[2] += 1.0,
            Op::Softmax | Op::LayerNorm => hot[3] += 1.0,
            Op::LstmCell => hot[4] += 1.0,
            Op::BatchMatMul => hot[5] += 1.0,
            _ => {}
        }
        flops += crate::gpusim::op_flops(g, shapes, id);
        bytes += shapes[id].iter().product::<usize>() as f64 * 4.0;
    }
    let ops = g.op_count().max(1) as f32;
    for c in class_counts {
        f.push(c / ops);
    }
    for h in hot {
        f.push((h / ops).min(1.0));
    }
    f.push(log_norm(flops, 30.0));
    f.push(log_norm(bytes, 25.0));

    // ---- schedule state (10)
    let nk = p.kernels.len().max(1) as f32;
    f.push(nk / ops); // kernels per op (1.0 = unfused)
    f.push(log_norm(p.kernels.len() as f64, 4.0));
    let frac = |pred: &dyn Fn(&crate::kir::Kernel) -> bool| -> f32 {
        p.kernels.iter().filter(|k| pred(*k)).count() as f32 / nk
    };
    f.push(frac(&|k| k.schedule.block_tile.is_some()));
    f.push(frac(&|k| k.schedule.reg_tile.is_some()));
    f.push(frac(&|k| k.schedule.pipeline_depth >= 2));
    f.push(frac(&|k| k.schedule.pipeline_depth >= 3));
    f.push(frac(&|k| k.schedule.loop_order != crate::kir::LoopOrder::Naive));
    f.push(frac(&|k| k.schedule.vector_width > 1));
    f.push(p.mean_sophistication() / 5.0);
    // smem utilisation of the hottest kernel: price each kernel exactly
    // once and take the argmax (last max wins, matching Iterator::max_by)
    let mut hot_kernel: Option<(usize, f64)> = None;
    for (ki, k) in p.kernels.iter().enumerate() {
        let t = pricer.kernel_time_us(k, g, shapes, spec).time_us;
        let better = match hot_kernel {
            None => true,
            Some((_, best)) => t >= best,
        };
        if better {
            hot_kernel = Some((ki, t));
        }
    }
    f.push(hot_kernel.map_or(0.0, |(ki, _)| {
        let k = &p.kernels[ki];
        (k.schedule.smem_bytes() as f32 / spec.smem_bytes() as f32).min(1.0)
    }));

    // ---- hardware (6)
    f.push(spec.sms as f32 / 132.0);
    f.push(spec.smem_per_sm_kb as f32 / 228.0);
    f.push(spec.l2_mb as f32 / 50.0);
    f.push((spec.mem_bw_gbs / 3350.0) as f32);
    f.push((spec.fp32_tflops / 60.0) as f32);
    f.push(spec.supports_async_copy() as u8 as f32);

    // ---- progress (4)
    f.push((speedup.max(0.01).ln() / 3.0) as f32);
    f.push((best_speedup.max(0.01).ln() / 3.0) as f32);
    f.push(step_frac);
    f.push(mask.iter().filter(|&&m| m).count() as f32 / ACTION_DIM as f32);

    // ---- valid actions per opt type (8)
    for t in 0..NUM_OPT_TYPES {
        let n = (0..MAX_REGIONS)
            .filter(|r| mask[t * MAX_REGIONS + r])
            .count();
        f.push(n as f32 / MAX_REGIONS as f32);
    }

    // ---- history: last 4 actions as (type+1)/9, (region+1)/9 (8)
    for i in 0..4 {
        match history.get(i) {
            Some(&a) if a < ACTION_DIM - 1 => {
                f.push((a / MAX_REGIONS + 1) as f32 / 9.0);
                f.push((a % MAX_REGIONS + 1) as f32 / 9.0);
            }
            _ => {
                f.push(0.0);
                f.push(0.0);
            }
        }
    }

    // ---- pad to OBS_DIM
    while f.len() < OBS_DIM {
        f.push(0.0);
    }
    assert_eq!(f.len(), OBS_DIM, "featurizer produced {} dims", f.len());
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;
    use crate::kir::lower_naive;
    use crate::transform::action_mask;

    fn setup() -> (Graph, Vec<Vec<usize>>, Program, GpuSpec) {
        let t = &crate::tasks::kernelbench_level(2)[0];
        let p = lower_naive(&t.graph);
        let shapes = infer_shapes(&t.graph);
        (t.graph.clone(), shapes, p, GpuSpec::a100())
    }

    #[test]
    fn obs_dim_and_bounds() {
        let (g, shapes, p, spec) = setup();
        let pricer = Pricer::new(None, &g, &shapes);
        let mask = action_mask(&p, &g, &shapes, &spec);
        let obs = featurize(&g, &shapes, &p, &spec, &pricer, &mask, &[],
                            1.0, 1.0, 0.0);
        assert_eq!(obs.len(), OBS_DIM);
        for (i, v) in obs.iter().enumerate() {
            assert!(v.is_finite(), "feature {i} not finite");
            assert!((-3.0..=3.0).contains(v), "feature {i} = {v} out of range");
        }
    }

    #[test]
    fn schedule_changes_move_features() {
        let (g, shapes, mut p, spec) = setup();
        let pricer = Pricer::new(None, &g, &shapes);
        let mask = action_mask(&p, &g, &shapes, &spec);
        let before = featurize(&g, &shapes, &p, &spec, &pricer, &mask, &[],
                               1.0, 1.0, 0.0);
        p.kernels[0].schedule.block_tile = Some((64, 64, 32));
        let after = featurize(&g, &shapes, &p, &spec, &pricer, &mask, &[],
                              1.0, 1.0, 0.0);
        assert_ne!(before, after);
    }

    #[test]
    fn hardware_distinguishable() {
        let (g, shapes, p, _) = setup();
        let pricer = Pricer::new(None, &g, &shapes);
        let mask = action_mask(&p, &g, &shapes, &GpuSpec::v100());
        let v = featurize(&g, &shapes, &p, &GpuSpec::v100(), &pricer, &mask,
                          &[], 1.0, 1.0, 0.0);
        let h = featurize(&g, &shapes, &p, &GpuSpec::h100(), &pricer, &mask,
                          &[], 1.0, 1.0, 0.0);
        assert_ne!(v, h);
    }

    #[test]
    fn history_encoded() {
        let (g, shapes, p, spec) = setup();
        let pricer = Pricer::new(None, &g, &shapes);
        let mask = action_mask(&p, &g, &shapes, &spec);
        let none = featurize(&g, &shapes, &p, &spec, &pricer, &mask, &[],
                             1.0, 1.0, 0.0);
        let some = featurize(&g, &shapes, &p, &spec, &pricer, &mask,
                             &[3, 17], 1.0, 1.0, 0.0);
        assert_ne!(none, some);
    }

    #[test]
    fn cached_and_cold_pricer_produce_identical_features() {
        let (g, shapes, p, spec) = setup();
        let cache = crate::gpusim::CostCache::new();
        let cold = Pricer::new(None, &g, &shapes);
        let warm = Pricer::new(Some(&cache), &g, &shapes);
        let mask = action_mask(&p, &g, &shapes, &spec);
        let a = featurize(&g, &shapes, &p, &spec, &cold, &mask, &[],
                          1.2, 1.4, 0.5);
        for _ in 0..2 {
            let b = featurize(&g, &shapes, &p, &spec, &warm, &mask, &[],
                              1.2, 1.4, 0.5);
            assert_eq!(a, b, "observation must not depend on the cache");
        }
        assert!(cache.stats().0 > 0, "second featurize must hit the memo");
    }
}
