//! The Macro-Thinking RL environment.
//!
//! State = (task, current program, history); actions = the 65-way semantic
//! space of [`crate::transform`]; transition = one micro-coding step
//! (transform + competence draw + correctness check + cost delta); reward
//! = the paper's staged rule-based shaping with step-proportional decay.
//!
//! [`tree::TreeEnv`] is the offline tree-structured variant used for PPO
//! (paper §4.2): transitions are memoized per (state-path, action) with
//! deterministic per-edge seeds, so training never waits on fresh
//! micro-coding rollouts for states it has already visited.

mod memo;
mod memo_store;
mod obs;
mod reward;
mod stepper;
mod tree;

pub use memo::{CachedEdge, EdgeMemo};
pub use memo_store::{
    flush_edge_memo, flush_edge_memo_with, fsck_store, load_edge_memo,
    save_edge_memo, warm_start_edge_memo, warm_start_edge_memo_with,
    FlushReport, FsckReport, SegmentFsck, WarmStartReport,
};
pub use obs::{featurize, OBS_DIM};
pub use reward::{shape_reward, RewardCfg, StepSignal};
pub use stepper::{EnvConfig, EnvState, OptimEnv, StepResult};
pub use tree::TreeEnv;
