//! The stepwise optimization environment (one episode = one task).
//!
//! Transitions are **edge-deterministic**: the randomness of a step is
//! seeded by (episode seed, state path, action), so revisiting the same
//! state-action always reproduces the same micro-coding outcome. This is
//! precisely the paper's tree-structured environment semantics — and what
//! makes the whole eval stack memoizable: an [`OptimEnv`] built with an
//! [`EdgeMemo`](super::EdgeMemo) attached replays any transition *any*
//! env sharing the memo has already taken, instead of re-running
//! micro-coding, correctness checks and cost analysis. This is the role
//! the paper's pre-collected 60k trajectories play (§4.2): never paying
//! twice for a transition the tree has already seen.

use std::sync::Arc;

use super::memo::{self, CachedEdge, EdgeMemo};
use super::obs::featurize;
use super::reward::{shape_reward, RewardCfg, StepSignal};
use crate::engine::Session;
use crate::gpusim::{graph_fingerprint, program_fingerprint, CostCache,
                    GpuSpec, Pricer};
use crate::graph::infer_shapes;
use crate::kir::{is_statically_legal, lower_naive, GateStats, Program};
use crate::microcode::{
    check_correct, micro_step_at, CheckOutcome, LlmProfile, StepOutcome,
};
use crate::tasks::Task;
use crate::transform::{decode_action, AnalysisCache, Analyzer, STOP_ACTION};
use crate::util::faults::{FaultPlan, FaultSite};
use crate::util::Rng;

/// Environment configuration.
#[derive(Clone, Debug)]
pub struct EnvConfig {
    pub max_steps: usize,
    pub verif_trials: usize,
    /// Target language is CUDA (Table 5) — higher micro-coding error.
    pub cuda: bool,
    pub reward: RewardCfg,
}

impl Default for EnvConfig {
    fn default() -> Self {
        EnvConfig {
            max_steps: 12,
            verif_trials: 2,
            cuda: false,
            reward: RewardCfg::default(),
        }
    }
}

/// Mutable episode state.
#[derive(Clone, Debug)]
pub struct EnvState {
    pub program: Program,
    pub step: usize,
    pub speedup: f64,
    pub best_speedup: f64,
    pub best_program: Program,
    /// Most-recent-first attempted action indices.
    pub history: Vec<usize>,
    /// Hash of the *successful* action path (tree-node identity).
    pub path_hash: u64,
    /// Cached [`program_fingerprint`] of `program`, refreshed whenever
    /// the program changes (accept/replay) — the mask lookup and the
    /// region lookup within one step share this one hash instead of each
    /// re-fingerprinting the program.
    pub program_fp: u64,
    pub done: bool,
}

/// What a step returned.
#[derive(Clone, Debug)]
pub struct StepResult {
    pub reward: f64,
    pub signal: StepSignal,
    pub done: bool,
}

/// One episode environment over a task.
pub struct OptimEnv<'a> {
    pub task: &'a Task,
    pub spec: GpuSpec,
    pub profile: LlmProfile,
    pub cfg: EnvConfig,
    pub shapes: Vec<Vec<usize>>,
    pub eager_us: f64,
    pub state: EnvState,
    /// Pricing handle: routes `speedup_of`/`eager_us` (and the greedy
    /// lookahead in the harness) through a per-sweep [`CostCache`] when
    /// one is attached; bit-identical to direct pricing either way.
    pub pricer: Pricer<'a>,
    /// Analysis handle: routes region analysis and action masks through a
    /// per-sweep [`AnalysisCache`] when one is attached.
    pub analyzer: Analyzer<'a>,
    /// Shared transition memo; `None` = every step runs live.
    memo: Option<Arc<EdgeMemo>>,
    /// Pre-verif static gate counters; `None` = gate off (the cacheless
    /// reference path, or `--no-static-gate`).
    gate: Option<Arc<GateStats>>,
    /// Scope fingerprint of this env's transitions in the [`EdgeMemo`].
    edge_ctx: u64,
    /// Deterministic fault-injection plan; `None` = injection off. The
    /// only site in the env is the verif-trial flake, which unwinds as a
    /// transient fault for the batch retry loop to absorb.
    faults: Option<Arc<FaultPlan>>,
    pub(crate) base_seed: u64,
}

fn mix(a: u64, b: u64) -> u64 {
    let mut x = a ^ b.wrapping_mul(0x9E3779B97F4A7C15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58476D1CE4E5B9);
    x ^ (x >> 27)
}

impl<'a> OptimEnv<'a> {
    /// A cacheless env — the bit-identical cold reference.
    pub fn new(task: &'a Task, spec: GpuSpec, profile: LlmProfile,
               cfg: EnvConfig, seed: u64) -> OptimEnv<'a> {
        Self::with_parts(task, spec, profile, cfg, seed, None, None, None,
                         None, None)
    }

    /// Build an env wired into a [`Session`]'s memo subsystems. Outcomes
    /// are bit-identical for every cache combination (all three memoize
    /// pure or edge-deterministic computations); only wall-clock differs.
    pub fn with_session(task: &'a Task, spec: GpuSpec, profile: LlmProfile,
                        cfg: EnvConfig, seed: u64,
                        session: &'a Session) -> OptimEnv<'a> {
        Self::with_parts(task, spec, profile, cfg, seed, session.cost(),
                         session.analysis(), session.edges().cloned(),
                         session.gate().cloned(), session.faults().cloned())
    }

    /// The constructor every variant funnels into, taking the memo trio
    /// piecewise (how [`super::TreeEnv`] rebuilds an env over the same
    /// task with its own private edge table).
    pub(crate) fn with_parts(task: &'a Task, spec: GpuSpec,
                             profile: LlmProfile, cfg: EnvConfig, seed: u64,
                             cost: Option<&'a CostCache>,
                             analysis: Option<&'a AnalysisCache>,
                             edges: Option<Arc<EdgeMemo>>,
                             gate: Option<Arc<GateStats>>,
                             faults: Option<Arc<FaultPlan>>) -> OptimEnv<'a> {
        let shapes = infer_shapes(&task.graph);
        let graph_ctx = graph_fingerprint(&task.graph, &shapes);
        let pricer = Pricer::from_ctx(cost, graph_ctx);
        let analyzer = Analyzer::from_ctx(analysis, graph_ctx);
        let edge_ctx = memo::edge_context(task, graph_ctx, &spec, &profile,
                                          &cfg, seed);
        let affinity = crate::gpusim::library_affinity(&task.id);
        let eager_us = pricer.eager_time_us(&task.graph, &shapes, &spec,
                                            affinity);
        let program = lower_naive(&task.graph);
        let speedup = eager_us
            / pricer.program_time_us(&program, &task.graph, &shapes, &spec);
        let state = EnvState {
            best_program: program.clone(),
            program_fp: program_fingerprint(&program),
            program,
            step: 0,
            speedup,
            best_speedup: speedup,
            history: Vec::new(),
            path_hash: mix(seed, 0x517CC1B727220A95),
            done: false,
        };
        OptimEnv { task, spec, profile, cfg, shapes, eager_us, state,
                   pricer, analyzer, memo: edges, gate, edge_ctx, faults,
                   base_seed: seed }
    }

    /// The memo trio (plus the static gate and the fault plan) this env
    /// routes through (used to rebuild an env over the same task, e.g.
    /// [`super::TreeEnv::reset`]).
    #[allow(clippy::type_complexity)]
    pub(crate) fn parts(&self) -> (Option<&'a CostCache>,
                                   Option<&'a AnalysisCache>,
                                   Option<Arc<EdgeMemo>>,
                                   Option<Arc<GateStats>>,
                                   Option<Arc<FaultPlan>>) {
        (self.pricer.cache(), self.analyzer.cache(), self.memo.clone(),
         self.gate.clone(), self.faults.clone())
    }

    /// The shared transition memo, if one is attached.
    pub fn edge_memo(&self) -> Option<&EdgeMemo> {
        self.memo.as_deref()
    }

    /// Validity mask for the current state (through the analysis memo
    /// when one is attached).
    pub fn mask(&self) -> Vec<bool> {
        self.analyzer
            .mask_fp(self.state.program_fp, &self.state.program,
                     &self.task.graph, &self.shapes, &self.spec)
            .as_ref()
            .clone()
    }

    /// Observation vector for the current state.
    pub fn observe(&self, mask: &[bool]) -> Vec<f32> {
        featurize(
            &self.task.graph,
            &self.shapes,
            &self.state.program,
            &self.spec,
            &self.pricer,
            mask,
            &self.state.history,
            self.state.speedup,
            self.state.best_speedup,
            self.state.step as f32 / self.cfg.max_steps as f32,
        )
    }

    /// The deterministic seed of the (current state, action) edge.
    pub fn edge_seed(&self, action: usize) -> u64 {
        mix(mix(self.base_seed, self.state.path_hash), action as u64)
    }

    fn speedup_of(&self, p: &Program) -> f64 {
        self.eager_us
            / self.pricer.program_time_us(p, &self.task.graph, &self.shapes,
                                          &self.spec)
    }

    /// Step the environment. Returns the shaped reward and the raw signal.
    ///
    /// Episodes run exactly `max_steps` attempted actions: the final
    /// budgeted call still attempts its action and then terminates
    /// (truncation is checked *after* the attempt, so no step of the
    /// budget is silently swallowed).
    ///
    /// With an [`EdgeMemo`] attached, a transition the memo has already
    /// seen (from this env, an earlier episode over the same tree, or any
    /// other worker sharing the table) is *replayed* instead of re-run:
    /// the stored (program, signal, speedup) is applied to the live state
    /// and the reward/truncation are recomputed for this step index.
    /// Because transitions are edge-deterministic, the replay is
    /// bit-identical to the live step it stands in for.
    pub fn step(&mut self, action: usize) -> StepResult {
        assert!(!self.state.done, "episode finished");
        let step_idx = self.state.step;
        self.state.step += 1;
        self.state.history.insert(0, action);
        self.state.history.truncate(8);

        if action == STOP_ACTION {
            self.state.done = true;
            let signal = StepSignal::Stop { best: self.state.best_speedup };
            return StepResult {
                reward: shape_reward(&signal, step_idx, &self.cfg.reward),
                signal,
                done: true,
            };
        }

        let key = self
            .memo
            .as_ref()
            .map(|m| (Arc::clone(m),
                      memo::edge_key(self.edge_ctx, self.state.path_hash,
                                     action)));
        if let Some((memo, key)) = &key {
            if let Some(edge) = memo.get(*key) {
                return self.replay(edge, step_idx);
            }
        }
        let signal = self.transition(action);
        if let Some((memo, key)) = &key {
            memo.insert(*key, CachedEdge {
                program: matches!(signal, StepSignal::Correct { .. })
                    .then(|| Arc::new(self.state.program.clone())),
                signal,
                speedup: self.state.speedup,
                from_disk: false,
            });
        }
        self.finish(signal, step_idx)
    }

    /// Run the live transition (micro-coding + verification + pricing),
    /// mutating the state on acceptance. The regions feeding the
    /// transform and the bug-site lookup come from the (possibly cached)
    /// analyzer — one analysis per state instead of several per step.
    fn transition(&mut self, action: usize) -> StepSignal {
        let mut rng = Rng::new(self.edge_seed(action));
        let regions = self.analyzer.regions_fp(
            self.state.program_fp, &self.state.program, &self.task.graph);
        let outcome = micro_step_at(
            &self.state.program,
            &self.task.graph,
            &self.shapes,
            &regions,
            &decode_action(action),
            &self.profile,
            &self.spec,
            self.cfg.cuda,
            &mut rng,
        );
        match outcome {
            StepOutcome::Rejected(_) => StepSignal::Rejected,
            StepOutcome::CompileError => StepSignal::CompileFail,
            StepOutcome::Buggy(p) => {
                if self.statically_rejected(&p) {
                    return StepSignal::WrongResult;
                }
                // injected verif flake: a transient failure where a real
                // harness would hit a flaky trial, keyed by the edge seed
                // so every run schedules it at the same transitions
                if let Some(plan) = &self.faults {
                    plan.raise_if(FaultSite::VerifFlake,
                                  self.edge_seed(action));
                }
                // run the verification harness — a lucky sub-tolerance bug
                // would pass (and deserves to)
                match check_correct(&p, &self.task.verif_graph,
                                    self.cfg.verif_trials,
                                    self.edge_seed(action) ^ 0xC0FFEE) {
                    CheckOutcome::Correct => self.accept(p),
                    _ => StepSignal::WrongResult,
                }
            }
            StepOutcome::Ok(p) => {
                if self.statically_rejected(&p) {
                    return StepSignal::WrongResult;
                }
                self.accept(p)
            }
        }
    }

    /// Tier-1 rejection: if a static gate is attached, verify the
    /// candidate before it reaches dynamic verification. Error-severity
    /// rules are invariants of every transform, so on candidates produced
    /// by legal actions the gate only ever counts a check — it rejects
    /// (skipping the verif trials) only for statically-provable schedule
    /// damage, keeping gated and ungated runs byte-identical (guarded by
    /// `rust/tests/verify.rs`).
    fn statically_rejected(&self, p: &Program) -> bool {
        if let Some(gate) = &self.gate {
            gate.note_check();
            if !is_statically_legal(p, &self.task.graph, &self.shapes,
                                    &self.spec) {
                gate.note_reject();
                return true;
            }
        }
        false
    }

    /// Apply a memoized edge to the live state — the exact state updates
    /// [`OptimEnv::transition`] + [`OptimEnv::accept`] would perform.
    fn replay(&mut self, edge: CachedEdge, step_idx: usize) -> StepResult {
        if let Some(p) = edge.program {
            let action = *self.state.history.first().unwrap();
            self.state.path_hash = mix(self.state.path_hash,
                                       action as u64 + 1);
            self.state.program = (*p).clone();
            self.state.program_fp = program_fingerprint(&self.state.program);
            self.state.speedup = edge.speedup;
            if edge.speedup > self.state.best_speedup {
                self.state.best_speedup = edge.speedup;
                self.state.best_program = self.state.program.clone();
            }
        }
        self.finish(edge.signal, step_idx)
    }

    /// Shape the reward and apply the step-budget truncation rule (shared
    /// by live and replayed steps, so `done` semantics cannot drift).
    fn finish(&mut self, signal: StepSignal, step_idx: usize) -> StepResult {
        let reward = shape_reward(&signal, step_idx, &self.cfg.reward);
        let done = self.state.step >= self.cfg.max_steps;
        if done {
            self.state.done = true;
        }
        StepResult { reward, signal, done }
    }

    fn accept(&mut self, p: Program) -> StepSignal {
        let prev = self.state.speedup;
        let now = self.speedup_of(&p);
        self.state.path_hash = mix(self.state.path_hash,
                                   *self.state.history.first().unwrap() as u64 + 1);
        self.state.program = p;
        self.state.program_fp = program_fingerprint(&self.state.program);
        self.state.speedup = now;
        if now > self.state.best_speedup {
            self.state.best_speedup = now;
            self.state.best_program = self.state.program.clone();
        }
        StepSignal::Correct { prev, now }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::microcode::ProfileId;
    use crate::transform::{encode_action, Action, OptType};

    fn env(_seed: u64) -> (Vec<Task>, GpuSpec) {
        (crate::tasks::kernelbench_level(2)[..3].to_vec(), GpuSpec::a100())
    }

    fn mk<'a>(tasks: &'a [Task], seed: u64) -> OptimEnv<'a> {
        OptimEnv::new(
            &tasks[0],
            GpuSpec::a100(),
            LlmProfile::get(ProfileId::GeminiPro25),
            EnvConfig::default(),
            seed,
        )
    }

    #[test]
    fn episode_terminates_on_stop() {
        let (tasks, _) = env(1);
        let mut e = mk(&tasks, 1);
        let r = e.step(STOP_ACTION);
        assert!(r.done && e.state.done);
    }

    #[test]
    fn episode_truncates_at_max_steps() {
        let (tasks, _) = env(2);
        let mut e = mk(&tasks, 2);
        let mut rng = Rng::new(0);
        for _ in 0..e.cfg.max_steps {
            if e.state.done {
                break;
            }
            let mask = e.mask();
            let valid: Vec<usize> = (0..mask.len()).filter(|&a| mask[a]).collect();
            e.step(*rng.choose(&valid));
        }
        assert!(e.state.done);
    }

    #[test]
    fn episode_attempts_exactly_max_steps_actions() {
        // regression: truncation used to fire *before* the final action
        // was attempted, so episodes got max_steps-1 real attempts
        let (tasks, _) = env(7);
        let mut e = mk(&tasks, 7);
        let mut attempts = 0;
        while !e.state.done {
            // always submit a real (non-Stop) action; even an invalid one
            // is an attempt (the env rejects it)
            let r = e.step(0);
            attempts += 1;
            assert!(
                !matches!(r.signal, StepSignal::Stop { .. }),
                "a non-Stop action must be attempted, not truncated away"
            );
        }
        assert_eq!(attempts, e.cfg.max_steps,
                   "episode budget is max_steps attempted actions");
    }

    #[test]
    fn cached_env_matches_uncached_bitwise() {
        let (tasks, _) = env(8);
        let session = Session::builder()
            .analysis_cache(false)
            .edge_memo(false)
            .build();
        let mut plain = mk(&tasks, 11);
        let mut cached = OptimEnv::with_session(
            &tasks[0],
            GpuSpec::a100(),
            LlmProfile::get(ProfileId::GeminiPro25),
            EnvConfig::default(),
            11,
            &session,
        );
        assert!(session.cost().is_some() && session.edges().is_none());
        assert_eq!(plain.eager_us.to_bits(), cached.eager_us.to_bits());
        while !plain.state.done {
            let mask = plain.mask();
            let a = (0..mask.len()).find(|&a| mask[a]).unwrap();
            let r1 = plain.step(a);
            let r2 = cached.step(a);
            assert_eq!(r1.reward.to_bits(), r2.reward.to_bits());
            assert_eq!(plain.state.speedup.to_bits(),
                       cached.state.speedup.to_bits());
        }
        assert!(cached.state.done);
        assert_eq!(plain.state.best_speedup.to_bits(),
                   cached.state.best_speedup.to_bits());
    }

    #[test]
    fn fully_cached_env_matches_plain_bitwise() {
        // all three memo subsystems attached at once, and a second
        // episode replayed over the warm edge memo
        let (tasks, _) = env(12);
        let session = Session::default();
        for pass in 0..2 {
            let mut plain = mk(&tasks, 21);
            let mut cached = OptimEnv::with_session(
                &tasks[0],
                GpuSpec::a100(),
                LlmProfile::get(ProfileId::GeminiPro25),
                EnvConfig::default(),
                21,
                &session,
            );
            while !plain.state.done {
                let mask = plain.mask();
                assert_eq!(mask, cached.mask(), "masks diverged");
                let a = (0..mask.len()).find(|&a| mask[a]).unwrap();
                let r1 = plain.step(a);
                let r2 = cached.step(a);
                assert_eq!(r1.reward.to_bits(), r2.reward.to_bits());
                assert_eq!(r1.done, r2.done);
                assert_eq!(plain.state.speedup.to_bits(),
                           cached.state.speedup.to_bits());
            }
            assert!(cached.state.done);
            assert_eq!(plain.state.best_program, cached.state.best_program);
            if pass == 1 {
                let s = session.edges().unwrap().stats();
                assert!(s.hits > 0, "second episode must replay from memo");
            }
        }
    }

    #[test]
    fn cached_program_fp_tracks_program() {
        // regression: the mask lookup and the edge-memo/region lookups of
        // one step used to each re-fingerprint the program; the cached
        // fingerprint must stay in sync through live steps AND replays
        let (tasks, _) = env(9);
        let session = Session::builder()
            .cost_cache(false)
            .analysis_cache(false)
            .build();
        for _ in 0..2 {
            let mut e = OptimEnv::with_session(
                &tasks[0],
                GpuSpec::a100(),
                LlmProfile::get(ProfileId::GeminiPro25),
                EnvConfig::default(),
                13,
                &session,
            );
            assert_eq!(e.state.program_fp,
                       program_fingerprint(&e.state.program));
            while !e.state.done {
                let mask = e.mask();
                let a = (0..mask.len()).find(|&a| mask[a]).unwrap();
                e.step(a);
                assert_eq!(e.state.program_fp,
                           program_fingerprint(&e.state.program),
                           "fingerprint cache went stale");
            }
        }
        assert!(session.edges().unwrap().stats().hits > 0,
                "second pass must exercise replay");
    }

    #[test]
    fn good_actions_improve_speedup() {
        let (tasks, _) = env(3);
        let mut e = mk(&tasks, 3);
        let start = e.state.speedup;
        // tile the hot kernel (region 0 = contraction anchor), retrying
        // seeds to dodge competence noise
        for seed in 0..20 {
            let mut e2 = mk(&tasks, seed);
            let a = encode_action(&Action { opt: OptType::TileShared, region: 0 });
            let r = e2.step(a);
            if matches!(r.signal, StepSignal::Correct { .. }) {
                assert!(e2.state.speedup > start * 1.5,
                        "tiling should help a matmul-anchored task");
                return;
            }
        }
        panic!("no successful tiling in 20 seeds at ~3.5% error rate");
    }

    #[test]
    fn edge_determinism() {
        let (tasks, _) = env(4);
        let mut e1 = mk(&tasks, 42);
        let mut e2 = mk(&tasks, 42);
        let a = encode_action(&Action { opt: OptType::TileShared, region: 0 });
        let r1 = e1.step(a);
        let r2 = e2.step(a);
        assert_eq!(format!("{:?}", r1.signal), format!("{:?}", r2.signal));
        assert_eq!(e1.state.program, e2.state.program);
    }

    #[test]
    fn different_seeds_different_trees() {
        let (tasks, _) = env(5);
        let e1 = mk(&tasks, 1);
        let e2 = mk(&tasks, 2);
        let a = encode_action(&Action { opt: OptType::TileShared, region: 0 });
        assert_ne!(e1.edge_seed(a), e2.edge_seed(a));
    }

    #[test]
    fn failed_step_preserves_state() {
        let (tasks, _) = env(6);
        // a profile that always produces compile errors
        // atomic_step_err caps at 0.9, so scan seeds for a failing edge
        let mut profile = LlmProfile::get(ProfileId::Gpt4o);
        profile.atomic_err = 1.0;
        profile.compile_frac = 1.0;
        let a = encode_action(&Action { opt: OptType::TileShared, region: 0 });
        for seed in 0..32 {
            let mut e = OptimEnv::new(&tasks[0], GpuSpec::a100(),
                                      profile.clone(), EnvConfig::default(),
                                      seed);
            let before = e.state.program.clone();
            let r = e.step(a);
            if r.signal == StepSignal::CompileFail {
                assert_eq!(e.state.program, before);
                assert!(r.reward < 0.0);
                return;
            }
        }
        panic!("no compile failure in 32 seeds at p=0.9");
    }
}
