//! Tree-structured offline environment (paper §4.2 "Environment").
//!
//! Because [`OptimEnv`] transitions are edge-deterministic, the set of
//! reachable states per task forms a tree keyed by the successful action
//! path. `TreeEnv` memoizes every priced edge — (tree-node, action) →
//! (outcome program, signal, speedup) — so PPO's repeated visits replay
//! from the cache instead of re-running micro-coding, correctness checks
//! and cost analysis. This is the role the paper's pre-collected 60k
//! trajectories play: decoupling policy optimization from generation
//! latency.

use super::reward::{shape_reward, StepSignal};
use super::stepper::{EnvConfig, OptimEnv, StepResult};
use crate::gpusim::{CostCache, GpuSpec};
use crate::kir::Program;
use crate::microcode::LlmProfile;
use crate::tasks::Task;
use crate::transform::STOP_ACTION;
use std::collections::HashMap;

#[derive(Clone, Debug)]
struct CachedEdge {
    program: Option<Program>, // None = state unchanged (fail/reject)
    signal: StepSignal,
    speedup: f64,
}

/// Memoizing wrapper around [`OptimEnv`].
pub struct TreeEnv<'a> {
    pub env: OptimEnv<'a>,
    cache: HashMap<(u64, usize), CachedEdge>,
    /// cache statistics: (hits, misses)
    pub stats: (usize, usize),
    max_entries: usize,
}

impl<'a> TreeEnv<'a> {
    pub fn new(task: &'a Task, spec: GpuSpec, profile: LlmProfile,
               cfg: EnvConfig, seed: u64) -> TreeEnv<'a> {
        Self::with_cache(task, spec, profile, cfg, seed, None)
    }

    /// Like [`TreeEnv::new`], pricing the wrapped env through a shared
    /// [`CostCache`] (complementary caches: the edge memo here replays
    /// whole transitions, the cost cache de-duplicates kernel pricing).
    pub fn with_cache(task: &'a Task, spec: GpuSpec, profile: LlmProfile,
                      cfg: EnvConfig, seed: u64,
                      cost_cache: Option<&'a CostCache>) -> TreeEnv<'a> {
        TreeEnv {
            env: OptimEnv::with_cache(task, spec, profile, cfg, seed,
                                      cost_cache),
            cache: HashMap::new(),
            stats: (0, 0),
            max_entries: 200_000,
        }
    }

    /// Reset to a fresh episode over the same tree (same seed => same
    /// tree; the cache stays warm).
    pub fn reset(&mut self) {
        let task = self.env.task;
        let spec = self.env.spec.clone();
        let profile = self.env.profile.clone();
        let cfg = self.env.cfg.clone();
        let base = self.env.base_seed;
        let cost_cache = self.env.pricer.cache();
        self.env = OptimEnv::with_cache(task, spec, profile, cfg, base,
                                        cost_cache);
    }

    /// Step with memoization.
    pub fn step(&mut self, action: usize) -> StepResult {
        let step_idx = self.env.state.step;
        // Bypass the edge cache for Stop and for the final budgeted step:
        // both terminate the episode (`done = true`), and cached replays
        // never set `done` — consistent with `OptimEnv::step` attempting
        // (not truncating) the final action.
        if action == STOP_ACTION
            || self.env.state.step + 1 >= self.env.cfg.max_steps
        {
            return self.env.step(action);
        }
        let key = (self.env.state.path_hash, action);
        if let Some(edge) = self.cache.get(&key).cloned() {
            self.stats.0 += 1;
            // replay the cached transition onto the live state
            self.env.state.step += 1;
            self.env.state.history.insert(0, action);
            self.env.state.history.truncate(8);
            if let Some(p) = edge.program {
                self.env.state.path_hash = path_mix(self.env.state.path_hash,
                                                    action as u64 + 1);
                self.env.state.program = p;
                self.env.state.speedup = edge.speedup;
                if edge.speedup > self.env.state.best_speedup {
                    self.env.state.best_speedup = edge.speedup;
                    self.env.state.best_program = self.env.state.program.clone();
                }
            }
            let reward = shape_reward(&edge.signal, step_idx, &self.env.cfg.reward);
            return StepResult { reward, signal: edge.signal, done: false };
        }
        self.stats.1 += 1;
        let key_state = self.env.state.path_hash;
        let result = self.env.step(action);
        if self.cache.len() < self.max_entries {
            let program = match result.signal {
                StepSignal::Correct { .. } => Some(self.env.state.program.clone()),
                _ => None,
            };
            self.cache.insert(
                (key_state, action),
                CachedEdge {
                    program,
                    signal: result.signal,
                    speedup: self.env.state.speedup,
                },
            );
        }
        result
    }

    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }
}

/// Same mixing as OptimEnv::accept uses for path hashes.
fn path_mix(a: u64, b: u64) -> u64 {
    let mut x = a ^ b.wrapping_mul(0x9E3779B97F4A7C15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58476D1CE4E5B9);
    x ^ (x >> 27)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::microcode::ProfileId;
    use crate::util::Rng;

    fn run_episode(env: &mut TreeEnv, seed: u64) -> (f64, Vec<StepSignal>) {
        let mut rng = Rng::new(seed);
        let mut signals = Vec::new();
        let mut total = 0.0;
        while !env.env.state.done {
            let mask = env.env.mask();
            let valid: Vec<usize> = (0..mask.len()).filter(|&a| mask[a]).collect();
            let a = *rng.choose(&valid);
            let r = env.step(a);
            total += r.reward;
            signals.push(r.signal);
        }
        (total, signals)
    }

    #[test]
    fn cache_warms_and_hits_on_replay() {
        let tasks = crate::tasks::kernelbench_level(2)[..1].to_vec();
        let mut env = TreeEnv::new(
            &tasks[0],
            GpuSpec::a100(),
            LlmProfile::get(ProfileId::GeminiPro25),
            EnvConfig::default(),
            7,
        );
        let (_r1, s1) = run_episode(&mut env, 1);
        let misses_after_first = env.stats.1;
        env.reset();
        let (_r2, s2) = run_episode(&mut env, 1); // same action stream
        assert_eq!(
            format!("{s1:?}"),
            format!("{s2:?}"),
            "replay of the same action stream must match"
        );
        assert!(env.stats.0 > 0, "no cache hits on replay");
        assert_eq!(env.stats.1, misses_after_first, "replay caused misses");
    }

    #[test]
    fn cached_and_uncached_paths_agree() {
        let tasks = crate::tasks::kernelbench_level(2)[1..2].to_vec();
        let mk = || TreeEnv::new(
            &tasks[0],
            GpuSpec::h100(),
            LlmProfile::get(ProfileId::GeminiFlash25),
            EnvConfig::default(),
            13,
        );
        let mut warm = mk();
        run_episode(&mut warm, 5);
        warm.reset();
        let (r_warm, s_warm) = run_episode(&mut warm, 9);
        let mut cold = mk();
        let (r_cold, s_cold) = run_episode(&mut cold, 9);
        assert_eq!(format!("{s_warm:?}"), format!("{s_cold:?}"));
        assert!((r_warm - r_cold).abs() < 1e-9);
    }
}
