//! Tree-structured offline environment (paper §4.2 "Environment").
//!
//! Because [`OptimEnv`] transitions are edge-deterministic, the set of
//! reachable states per task forms a tree keyed by the successful action
//! path. `TreeEnv` memoizes every priced edge — (tree-node, action) →
//! (outcome program, signal, speedup) — so PPO's repeated visits replay
//! from the cache instead of re-running micro-coding, correctness checks
//! and cost analysis. This is the role the paper's pre-collected 60k
//! trajectories play: decoupling policy optimization from generation
//! latency.
//!
//! The memoization itself lives in the shared [`EdgeMemo`] transposition
//! table (the [`OptimEnv`] consults it on every step); `TreeEnv` is the
//! ownership pattern — one table per tree, kept warm across
//! [`TreeEnv::reset`] — while the batched evaluator shares one table
//! across a whole sweep instead.

use std::sync::Arc;

use super::memo::EdgeMemo;
use super::stepper::{EnvConfig, OptimEnv, StepResult};
use crate::engine::Session;
use crate::gpusim::{GpuSpec, MemoStats};
use crate::microcode::LlmProfile;
use crate::tasks::Task;

/// Memoizing wrapper around [`OptimEnv`].
pub struct TreeEnv<'a> {
    pub env: OptimEnv<'a>,
}

impl<'a> TreeEnv<'a> {
    /// A self-contained tree: no pricing/analysis memos, one fresh
    /// private transition table (the classic TreeEnv behavior).
    pub fn new(task: &'a Task, spec: GpuSpec, profile: LlmProfile,
               cfg: EnvConfig, seed: u64) -> TreeEnv<'a> {
        TreeEnv {
            env: OptimEnv::with_parts(task, spec, profile, cfg, seed, None,
                                      None, Some(Arc::new(EdgeMemo::new())),
                                      None, None),
        }
    }

    /// A tree wired into a [`Session`]'s memo subsystems. The wrapped env
    /// routes pricing/analysis through the session's caches, and every
    /// tree built over the session pools transitions in its shared
    /// [`EdgeMemo`]; when the session runs with the edge memo disabled,
    /// the tree falls back to a fresh private table (a TreeEnv is
    /// memoizing by definition).
    pub fn with_session(task: &'a Task, spec: GpuSpec, profile: LlmProfile,
                        cfg: EnvConfig, seed: u64,
                        session: &'a Session) -> TreeEnv<'a> {
        let edges = session
            .edges()
            .cloned()
            .unwrap_or_else(|| Arc::new(EdgeMemo::new()));
        TreeEnv {
            env: OptimEnv::with_parts(task, spec, profile, cfg, seed,
                                      session.cost(), session.analysis(),
                                      Some(edges), session.gate().cloned(),
                                      session.faults().cloned()),
        }
    }

    /// Reset to a fresh episode over the same tree (same seed => same
    /// tree; the memo stays warm).
    pub fn reset(&mut self) {
        let task = self.env.task;
        let spec = self.env.spec.clone();
        let profile = self.env.profile.clone();
        let cfg = self.env.cfg.clone();
        let base = self.env.base_seed;
        let (cost, analysis, edges, gate, faults) = self.env.parts();
        self.env = OptimEnv::with_parts(task, spec, profile, cfg, base,
                                        cost, analysis, edges, gate, faults);
    }

    /// Step with memoization (delegates to the memo-wired env).
    pub fn step(&mut self, action: usize) -> StepResult {
        self.env.step(action)
    }

    /// This tree's transition table.
    pub fn memo(&self) -> &EdgeMemo {
        self.env.edge_memo().expect("TreeEnv always carries an edge memo")
    }

    /// (hits, misses) of the transition table.
    pub fn stats(&self) -> (usize, usize) {
        let MemoStats { hits, misses, .. } = self.memo().stats();
        (hits, misses)
    }

    pub fn cache_len(&self) -> usize {
        self.memo().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::StepSignal;
    use crate::microcode::ProfileId;
    use crate::util::Rng;

    fn run_episode(env: &mut TreeEnv, seed: u64) -> (f64, Vec<StepSignal>) {
        let mut rng = Rng::new(seed);
        let mut signals = Vec::new();
        let mut total = 0.0;
        while !env.env.state.done {
            let mask = env.env.mask();
            let valid: Vec<usize> = (0..mask.len()).filter(|&a| mask[a]).collect();
            let a = *rng.choose(&valid);
            let r = env.step(a);
            total += r.reward;
            signals.push(r.signal);
        }
        (total, signals)
    }

    #[test]
    fn cache_warms_and_hits_on_replay() {
        let tasks = crate::tasks::kernelbench_level(2)[..1].to_vec();
        let mut env = TreeEnv::new(
            &tasks[0],
            GpuSpec::a100(),
            LlmProfile::get(ProfileId::GeminiPro25),
            EnvConfig::default(),
            7,
        );
        let (_r1, s1) = run_episode(&mut env, 1);
        let misses_after_first = env.stats().1;
        env.reset();
        let (_r2, s2) = run_episode(&mut env, 1); // same action stream
        assert_eq!(
            format!("{s1:?}"),
            format!("{s2:?}"),
            "replay of the same action stream must match"
        );
        assert!(env.stats().0 > 0, "no cache hits on replay");
        assert_eq!(env.stats().1, misses_after_first, "replay caused misses");
    }

    #[test]
    fn cached_and_uncached_paths_agree() {
        let tasks = crate::tasks::kernelbench_level(2)[1..2].to_vec();
        let mk = || TreeEnv::new(
            &tasks[0],
            GpuSpec::h100(),
            LlmProfile::get(ProfileId::GeminiFlash25),
            EnvConfig::default(),
            13,
        );
        let mut warm = mk();
        run_episode(&mut warm, 5);
        warm.reset();
        let (r_warm, s_warm) = run_episode(&mut warm, 9);
        let mut cold = mk();
        let (r_cold, s_cold) = run_episode(&mut cold, 9);
        assert_eq!(format!("{s_warm:?}"), format!("{s_cold:?}"));
        assert!((r_warm - r_cold).abs() < 1e-9);
    }

    #[test]
    fn two_trees_pool_transitions_through_a_shared_memo() {
        // same (task, spec, profile, seed): the second tree replays the
        // first tree's episode entirely from the shared table
        let tasks = crate::tasks::kernelbench_level(2)[..1].to_vec();
        let session = Session::builder()
            .cost_cache(false)
            .analysis_cache(false)
            .build();
        let mk = || TreeEnv::with_session(
            &tasks[0],
            GpuSpec::a100(),
            LlmProfile::get(ProfileId::GeminiFlash25),
            EnvConfig::default(),
            31,
            &session,
        );
        let mut first = mk();
        let (r1, s1) = run_episode(&mut first, 3);
        let shared = session.edges().unwrap();
        let misses_after_first = shared.stats().misses;
        let mut second = mk();
        let (r2, s2) = run_episode(&mut second, 3);
        assert_eq!(format!("{s1:?}"), format!("{s2:?}"));
        assert_eq!(r1.to_bits(), r2.to_bits());
        assert_eq!(shared.stats().misses, misses_after_first,
                   "second tree must not recompute shared edges");
        assert!(shared.stats().hits > 0);
    }
}
