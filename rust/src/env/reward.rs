//! Rule-based staged reward shaping (paper §4.2): compile success →
//! correct execution → performance improvement, with progressive rewards,
//! decaying penalties, and a step-proportional decay that damps degenerate
//! looping.

/// What happened in the step, as seen by the reward function.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepSignal {
    /// Generated code failed to compile.
    CompileFail,
    /// Compiled but produced wrong numbers.
    WrongResult,
    /// The transform layer rejected the action (invalid proposal).
    Rejected,
    /// Correct step; log-speedup moved from `prev` to `now`.
    Correct { prev: f64, now: f64 },
    /// Terminal Stop with the episode's best speedup.
    Stop { best: f64 },
}

/// Reward shaping constants.
#[derive(Clone, Debug)]
pub struct RewardCfg {
    pub compile_fail_pen: f64,
    pub wrong_result_pen: f64,
    pub rejected_pen: f64,
    pub improve_scale: f64,
    pub step_cost: f64,
    pub stop_bonus_scale: f64,
    /// Per-step decay d_t = max(floor, 1 - rate * t).
    pub decay_rate: f64,
    pub decay_floor: f64,
}

impl Default for RewardCfg {
    fn default() -> Self {
        RewardCfg {
            compile_fail_pen: -0.6,
            wrong_result_pen: -0.3,
            rejected_pen: -0.2,
            improve_scale: 2.0,
            step_cost: -0.01,
            stop_bonus_scale: 0.5,
            decay_rate: 0.08,
            decay_floor: 0.3,
        }
    }
}

impl RewardCfg {
    pub fn decay(&self, step: usize) -> f64 {
        (1.0 - self.decay_rate * step as f64).max(self.decay_floor)
    }
}

/// Shape the reward for a step at index `step` (0-based).
///
/// Positive rewards decay with step (discouraging aimless long episodes);
/// penalties are *divided* by the decay (early mistakes are cheap
/// exploration, late mistakes on an already-good kernel are costly — the
/// paper's "penalties decrease gradually" is relative to the growing
/// positive signal).
pub fn shape_reward(signal: &StepSignal, step: usize, cfg: &RewardCfg) -> f64 {
    let d = cfg.decay(step);
    match signal {
        StepSignal::CompileFail => cfg.compile_fail_pen * d,
        StepSignal::WrongResult => cfg.wrong_result_pen * d,
        StepSignal::Rejected => cfg.rejected_pen * d,
        StepSignal::Correct { prev, now } => {
            let dlog = now.max(1e-3).ln() - prev.max(1e-3).ln();
            d * (cfg.improve_scale * dlog) + cfg.step_cost
        }
        StepSignal::Stop { best } => {
            cfg.stop_bonus_scale * best.max(1e-3).ln()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staged_ordering() {
        // compile fail < wrong result < no-progress correct step
        let cfg = RewardCfg::default();
        let cf = shape_reward(&StepSignal::CompileFail, 0, &cfg);
        let wr = shape_reward(&StepSignal::WrongResult, 0, &cfg);
        let ok = shape_reward(&StepSignal::Correct { prev: 1.0, now: 1.0 }, 0, &cfg);
        assert!(cf < wr && wr < ok);
    }

    #[test]
    fn improvement_rewarded_regression_punished() {
        let cfg = RewardCfg::default();
        let up = shape_reward(&StepSignal::Correct { prev: 1.0, now: 1.5 }, 0, &cfg);
        let down = shape_reward(&StepSignal::Correct { prev: 1.0, now: 0.7 }, 0, &cfg);
        assert!(up > 0.0);
        assert!(down < 0.0);
    }

    #[test]
    fn decay_damps_late_rewards() {
        let cfg = RewardCfg::default();
        let early = shape_reward(&StepSignal::Correct { prev: 1.0, now: 2.0 }, 0, &cfg);
        let late = shape_reward(&StepSignal::Correct { prev: 1.0, now: 2.0 }, 10, &cfg);
        assert!(late < early);
        assert!(late > 0.0, "decay floors out, never flips sign");
    }

    #[test]
    fn stop_bonus_scales_with_quality() {
        let cfg = RewardCfg::default();
        let good = shape_reward(&StepSignal::Stop { best: 2.0 }, 5, &cfg);
        let bad = shape_reward(&StepSignal::Stop { best: 0.5 }, 5, &cfg);
        assert!(good > 0.0);
        assert!(bad < 0.0, "stopping on a slow kernel is penalised");
    }

    #[test]
    fn decay_floor_respected() {
        let cfg = RewardCfg::default();
        assert_eq!(cfg.decay(1000), cfg.decay_floor);
        assert_eq!(cfg.decay(0), 1.0);
    }
}
