//! LLM competence profiles.
//!
//! Each baseline model from the paper's Tables 3-4 is a parameter vector
//! of the same generation process; the constants below are the
//! *calibration* knobs (documented per DESIGN.md's substitution table) and
//! were fitted so the emergent per-level accuracies/speedups land in the
//! paper's bands. They are inputs to a generative process — accuracy is
//! still measured by executing what the process produces.

/// Stable identifier for each simulated model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ProfileId {
    GeminiPro25,
    GeminiFlash25,
    Claude37Sonnet,
    Claude4Sonnet,
    O4Mini,
    Gpt4o,
    DeepSeekR1,
    DeepSeekV3,
    LlamaNemotron,
    Qwen3,
    QwenCoder32B,
    GeminiCli,
    Kevin32B,
    KernelLlm,
}

/// Competence parameters of one model.
#[derive(Clone, Debug)]
pub struct LlmProfile {
    pub id: ProfileId,
    pub name: &'static str,
    /// Probability an *atomic, in-context-guided* optimization step is
    /// implemented incorrectly (the MTMC regime). Small for strong models.
    pub atomic_err: f64,
    /// Base per-decision error rate in *single-pass whole-kernel*
    /// generation (the baseline regime); compounds over every decision.
    pub holistic_err: f64,
    /// Exponent on task op-count: error growth with kernel complexity.
    pub complexity_exp: f64,
    /// Of the errors, fraction that are compile errors (rest are silent
    /// numeric bugs).
    pub compile_frac: f64,
    /// Parameter-choice skill in [0,1] (tile sizes, stage counts).
    pub param_skill: f64,
    /// How many optimizations the model *attempts* in single-pass mode
    /// (ambition; finetuned kernel models attempt more).
    pub ambition: f64,
    /// Multiplier on all error rates when the target language is CUDA
    /// (sparser corpus, more footguns) vs Triton.
    pub cuda_err_mult: f64,
    /// Self-refinement rounds (Kevin-32B's multi-turn RL, Gemini CLI's
    /// agentic retry): failed generations are retried this many times.
    pub refine_rounds: usize,
}

impl LlmProfile {
    pub fn get(id: ProfileId) -> LlmProfile {
        use ProfileId::*;
        match id {
            GeminiPro25 => LlmProfile {
                id, name: "Gemini 2.5 Pro",
                atomic_err: 0.035, holistic_err: 0.16, complexity_exp: 0.22,
                compile_frac: 0.45, param_skill: 0.85, ambition: 2.6,
                cuda_err_mult: 1.6, refine_rounds: 0,
            },
            GeminiFlash25 => LlmProfile {
                id, name: "Gemini 2.5 Flash",
                atomic_err: 0.055, holistic_err: 0.235, complexity_exp: 0.18,
                compile_frac: 0.5, param_skill: 0.75, ambition: 2.3,
                cuda_err_mult: 1.8, refine_rounds: 0,
            },
            Claude37Sonnet => LlmProfile {
                id, name: "Claude-3.7-Sonnet",
                atomic_err: 0.10, holistic_err: 0.44, complexity_exp: 0.25,
                compile_frac: 0.55, param_skill: 0.6, ambition: 2.0,
                cuda_err_mult: 1.8, refine_rounds: 0,
            },
            Claude4Sonnet => LlmProfile {
                id, name: "Claude-4-Sonnet",
                atomic_err: 0.06, holistic_err: 0.30, complexity_exp: 0.25,
                compile_frac: 0.5, param_skill: 0.8, ambition: 2.4,
                cuda_err_mult: 1.6, refine_rounds: 0,
            },
            O4Mini => LlmProfile {
                id, name: "OpenAI o4-mini",
                atomic_err: 0.07, holistic_err: 0.31, complexity_exp: 0.22,
                compile_frac: 0.5, param_skill: 0.75, ambition: 2.3,
                cuda_err_mult: 1.7, refine_rounds: 0,
            },
            Gpt4o => LlmProfile {
                id, name: "GPT-4o",
                atomic_err: 0.16, holistic_err: 0.62, complexity_exp: 0.30,
                compile_frac: 0.6, param_skill: 0.45, ambition: 1.6,
                cuda_err_mult: 2.0, refine_rounds: 0,
            },
            DeepSeekR1 => LlmProfile {
                id, name: "DeepSeek-R1",
                atomic_err: 0.06, holistic_err: 0.25, complexity_exp: 0.15,
                compile_frac: 0.45, param_skill: 0.78, ambition: 2.4,
                cuda_err_mult: 1.7, refine_rounds: 0,
            },
            DeepSeekV3 => LlmProfile {
                id, name: "DeepSeek-V3",
                atomic_err: 0.105, holistic_err: 0.45, complexity_exp: 0.63,
                compile_frac: 0.55, param_skill: 0.62, ambition: 2.0,
                cuda_err_mult: 1.9, refine_rounds: 0,
            },
            LlamaNemotron => LlmProfile {
                id, name: "Llama-3.1-Nemotron",
                atomic_err: 0.22, holistic_err: 0.72, complexity_exp: 0.30,
                compile_frac: 0.65, param_skill: 0.35, ambition: 1.4,
                cuda_err_mult: 2.2, refine_rounds: 0,
            },
            Qwen3 => LlmProfile {
                id, name: "Qwen3-235B-A22B",
                atomic_err: 0.07, holistic_err: 0.29, complexity_exp: 0.28,
                compile_frac: 0.5, param_skill: 0.7, ambition: 2.2,
                cuda_err_mult: 1.8, refine_rounds: 0,
            },
            QwenCoder32B => LlmProfile {
                id, name: "Qwen2.5-Coder-32B",
                atomic_err: 0.20, holistic_err: 0.73, complexity_exp: 0.50,
                compile_frac: 0.6, param_skill: 0.4, ambition: 1.5,
                cuda_err_mult: 1.9, refine_rounds: 0,
            },
            GeminiCli => LlmProfile {
                id, name: "Gemini CLI",
                atomic_err: 0.06, holistic_err: 0.37, complexity_exp: 0.20,
                compile_frac: 0.5, param_skill: 0.72, ambition: 2.3,
                cuda_err_mult: 1.7, refine_rounds: 1,
            },
            Kevin32B => LlmProfile {
                id, name: "Kevin-32B",
                // finetuned: high correctness from multi-turn RL against
                // the compiler, but conservative schedules (low ambition,
                // modest param skill) => accuracy without speed
                atomic_err: 0.08, holistic_err: 0.62, complexity_exp: 0.05,
                compile_frac: 0.75, param_skill: 0.45, ambition: 1.1,
                cuda_err_mult: 1.2, refine_rounds: 3,
            },
            KernelLlm => LlmProfile {
                id, name: "KernelLLM",
                // small finetuned model: middling on its training
                // distribution (KernelBench-like), collapses off it —
                // the generalization cliff is modelled in eval::baselines
                // via ood_err_mult.
                atomic_err: 0.15, holistic_err: 0.44, complexity_exp: 0.18,
                compile_frac: 0.55, param_skill: 0.45, ambition: 1.5,
                cuda_err_mult: 2.5, refine_rounds: 0,
            },
        }
    }

    /// All profiles in the paper's table order.
    pub fn all() -> Vec<LlmProfile> {
        use ProfileId::*;
        [Claude37Sonnet, Claude4Sonnet, O4Mini, Gpt4o, DeepSeekR1,
         DeepSeekV3, LlamaNemotron, Qwen3, QwenCoder32B, GeminiCli,
         Kevin32B, KernelLlm, GeminiPro25, GeminiFlash25]
            .into_iter()
            .map(LlmProfile::get)
            .collect()
    }

    /// A copy with all error rates scaled by `mult` (suite-difficulty and
    /// out-of-distribution adjustments applied by the eval harness).
    pub fn scaled(&self, mult: f64) -> LlmProfile {
        LlmProfile {
            atomic_err: (self.atomic_err * mult).min(0.95),
            holistic_err: (self.holistic_err * mult).min(0.95),
            ..self.clone()
        }
    }

    /// Error probability of one atomic micro-coding step for an action of
    /// the given implementation complexity on a task with `op_count` ops.
    pub fn atomic_step_err(&self, action_complexity: f64, op_count: usize,
                           cuda: bool) -> f64 {
        let base = self.atomic_err
            * action_complexity
            * (op_count as f64).powf(self.complexity_exp * 0.3);
        let lang = if cuda { self.cuda_err_mult } else { 1.0 };
        (base * lang).min(0.9)
    }

    /// Error probability of deciding+implementing `k` optimizations at
    /// once on a task with `op_count` ops (single-pass mode). Compounds.
    pub fn holistic_err_total(&self, k: usize, op_count: usize,
                              cuda: bool) -> f64 {
        let per = self.holistic_err
            * (op_count as f64).powf(self.complexity_exp)
            * if cuda { self.cuda_err_mult } else { 1.0 };
        let per = per.min(0.95);
        1.0 - (1.0 - per).powi(k.max(1) as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomic_much_safer_than_holistic() {
        for p in LlmProfile::all() {
            let atomic = p.atomic_step_err(1.3, 3, false);
            let holistic = p.holistic_err_total(3, 3, false);
            assert!(
                atomic < holistic,
                "{}: atomic {atomic:.3} !< holistic {holistic:.3}",
                p.name
            );
        }
    }

    #[test]
    fn complexity_increases_error() {
        let p = LlmProfile::get(ProfileId::Gpt4o);
        assert!(p.holistic_err_total(2, 20, false) > p.holistic_err_total(2, 2, false));
        assert!(p.atomic_step_err(2.0, 5, false) > p.atomic_step_err(0.8, 5, false));
    }

    #[test]
    fn cuda_is_harder() {
        let p = LlmProfile::get(ProfileId::GeminiPro25);
        assert!(p.holistic_err_total(2, 4, true) > p.holistic_err_total(2, 4, false));
    }

    #[test]
    fn probabilities_bounded() {
        for p in LlmProfile::all() {
            for k in [1, 3, 8] {
                for ops in [1, 5, 40] {
                    let e = p.holistic_err_total(k, ops, true);
                    assert!((0.0..=1.0).contains(&e));
                }
            }
        }
    }

    #[test]
    fn strong_models_ranked_above_weak() {
        let strong = LlmProfile::get(ProfileId::GeminiPro25);
        let weak = LlmProfile::get(ProfileId::QwenCoder32B);
        assert!(strong.holistic_err < weak.holistic_err);
        assert!(strong.param_skill > weak.param_skill);
    }
}
