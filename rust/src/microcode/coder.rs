//! One atomic micro-coding step: the MTMC inference-pipeline inner loop.
//!
//! Given the current program and a semantic action, the engine (1) applies
//! the schedule transform with profile-skill parameters, then (2) draws
//! from the competence model whether the *implementation* of that change
//! is faulty — a compile error (program unusable this step) or an
//! executable semantic bug injected at the transformed node.

use super::profiles::LlmProfile;
use crate::graph::{Graph, Mutation, MutationKind};
use crate::kir::{analyze_regions, Program, Region, RegionKind};
use crate::transform::{apply_action_with, Action, TransformError};
use crate::util::Rng;

/// Outcome of one micro-coding step.
#[derive(Clone, Debug)]
pub enum StepOutcome {
    /// Transform applied, implementation correct.
    Ok(Program),
    /// Transform applied but the implementation carries a silent bug
    /// (mutation already attached to the program).
    Buggy(Program),
    /// The generated code does not compile; the program state is the
    /// previous one (callers decide whether to retry).
    CompileError,
    /// The action was semantically invalid for this state (the transform
    /// layer rejected it).
    Rejected(TransformError),
}

/// The graph node a buggy implementation of `action` perturbs: a node of
/// the kernel the region denotes.
fn bug_site(p: &Program, regions: &[Region], action: &Action)
            -> Option<usize> {
    let region = regions.get(action.region)?;
    let k = match region.kind {
        RegionKind::Kernel { kernel } => kernel,
        RegionKind::FusionEdge { consumer, .. } => consumer,
    };
    p.kernels.get(k).map(|k| *k.nodes.last().unwrap())
}

/// Draw the concrete bug a faulty implementation introduces; tied to the
/// action type (tiling bugs are boundary bugs, pipeline bugs are races...).
pub(crate) fn draw_bug(action: &Action, rng: &mut Rng) -> MutationKind {
    use crate::transform::OptType::*;
    match action.opt {
        TileShared | TileReg => MutationKind::BoundaryDrop {
            frac: 0.05 + 0.2 * rng.f32(),
        },
        PipelineDouble | PipelineAsync => MutationKind::RaceCorruption {
            scale: 0.05 + 0.4 * rng.f32(),
        },
        FuseProducer | FuseEpilogue => {
            if rng.bool(0.5) {
                MutationKind::SkippedOp
            } else {
                MutationKind::BadAccumInit { bias: 0.1 + rng.f32() }
            }
        }
        Reorder => MutationKind::IndexOffset,
        Vectorize => MutationKind::BoundaryDrop { frac: 0.02 + 0.1 * rng.f32() },
    }
}

/// Execute one micro-coding step.
///
/// `cuda`: target language is CUDA (Table 5 ablation) — higher error rates.
#[allow(clippy::too_many_arguments)]
pub fn micro_step(
    p: &Program,
    g: &Graph,
    shapes: &[Vec<usize>],
    action: &Action,
    profile: &LlmProfile,
    spec: &crate::gpusim::GpuSpec,
    cuda: bool,
    rng: &mut Rng,
) -> StepOutcome {
    micro_step_at(p, g, shapes, &analyze_regions(p, g), action, profile,
                  spec, cuda, rng)
}

/// [`micro_step`] over already-analyzed regions of `p` — the hot-path
/// variant the env uses so one (cached) region analysis serves the
/// transform application *and* the bug-site lookup. RNG draws are
/// identical to [`micro_step`], so outcomes are bit-for-bit the same.
#[allow(clippy::too_many_arguments)]
pub fn micro_step_at(
    p: &Program,
    g: &Graph,
    shapes: &[Vec<usize>],
    regions: &[Region],
    action: &Action,
    profile: &LlmProfile,
    spec: &crate::gpusim::GpuSpec,
    cuda: bool,
    rng: &mut Rng,
) -> StepOutcome {
    // parameter skill with per-step jitter: even strong models sometimes
    // pick a mediocre tile
    let quality = (profile.param_skill as f32
        + 0.25 * (rng.f32() - 0.5))
        .clamp(0.05, 1.0);
    let next = match apply_action_with(p, g, shapes, regions, action, spec,
                                       quality) {
        Ok(next) => next,
        Err(e) => return StepOutcome::Rejected(e),
    };
    let err_p = profile.atomic_step_err(
        action.opt.implementation_complexity(),
        g.op_count(),
        cuda,
    );
    if rng.bool(err_p) {
        if rng.bool(profile.compile_frac) {
            StepOutcome::CompileError
        } else {
            let mut buggy = next;
            if let Some(site) = bug_site(p, regions, action) {
                buggy.mutations.push(Mutation {
                    node: site,
                    kind: draw_bug(action, rng),
                });
            }
            StepOutcome::Buggy(buggy)
        }
    } else {
        StepOutcome::Ok(next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::GpuSpec;
    use crate::graph::Op;
    use crate::kir::lower_naive;
    use crate::microcode::profiles::ProfileId;
    use crate::transform::{apply_action, OptType};

    fn setup() -> (Graph, Vec<Vec<usize>>, Program) {
        let mut g = Graph::new("t");
        let x = g.input("x", &[1024, 1024]);
        let w = g.weight("w", &[1024, 1024]);
        let mm = g.op(Op::MatMul, &[x, w]);
        let r = g.op(Op::Relu, &[mm]);
        g.mark_output(r);
        let shapes = crate::graph::infer_shapes(&g);
        let p = lower_naive(&g);
        (g, shapes, p)
    }

    #[test]
    fn strong_model_mostly_succeeds_on_atomic_steps() {
        let (g, shapes, p) = setup();
        let profile = LlmProfile::get(ProfileId::GeminiPro25);
        let spec = GpuSpec::a100();
        let action = Action { opt: OptType::TileShared, region: 0 };
        let mut rng = Rng::new(7);
        let mut ok = 0;
        let n = 300;
        for _ in 0..n {
            match micro_step(&p, &g, &shapes, &action, &profile, &spec, false, &mut rng) {
                StepOutcome::Ok(next) => {
                    assert!(next.kernels[0].schedule.block_tile.is_some());
                    ok += 1;
                }
                StepOutcome::Buggy(b) => assert!(!b.mutations.is_empty()),
                StepOutcome::CompileError => {}
                StepOutcome::Rejected(e) => panic!("unexpected reject: {e}"),
            }
        }
        assert!(ok as f64 / n as f64 > 0.9, "ok rate {}", ok as f64 / n as f64);
    }

    #[test]
    fn weak_model_fails_more() {
        let (g, shapes, p) = setup();
        let spec = GpuSpec::a100();
        let action = Action { opt: OptType::PipelineDouble, region: 0 };
        // must tile first for pipeline to be legal
        let tiled = apply_action(&p, &g, &shapes,
                                 &Action { opt: OptType::TileShared, region: 0 },
                                 &spec, 1.0).unwrap();
        let count_fail = |id: ProfileId| -> usize {
            let profile = LlmProfile::get(id);
            let mut rng = Rng::new(11);
            (0..400)
                .filter(|_| {
                    !matches!(
                        micro_step(&tiled, &g, &shapes, &action, &profile,
                                   &spec, false, &mut rng),
                        StepOutcome::Ok(_)
                    )
                })
                .count()
        };
        let strong = count_fail(ProfileId::GeminiPro25);
        let weak = count_fail(ProfileId::QwenCoder32B);
        assert!(weak > strong * 2, "weak {weak} vs strong {strong}");
    }

    #[test]
    fn rejected_actions_do_not_consume_luck() {
        let (g, shapes, p) = setup();
        let profile = LlmProfile::get(ProfileId::GeminiPro25);
        let spec = GpuSpec::a100();
        // vectorize before reorder is invalid on a naive kernel
        let action = Action { opt: OptType::Vectorize, region: 0 };
        let mut rng = Rng::new(3);
        match micro_step(&p, &g, &shapes, &action, &profile, &spec, false, &mut rng) {
            StepOutcome::Rejected(_) => {}
            other => panic!("expected rejection, got {other:?}"),
        }
    }

    #[test]
    fn bugs_attach_to_transformed_kernel() {
        let (g, shapes, p) = setup();
        let profile = LlmProfile {
            atomic_err: 1.0,      // always err
            compile_frac: 0.0,    // always a silent bug
            ..LlmProfile::get(ProfileId::Gpt4o)
        };
        let spec = GpuSpec::a100();
        let action = Action { opt: OptType::TileShared, region: 0 };
        let mut rng = Rng::new(5);
        // atomic_step_err caps at 0.9, so draw until the error fires
        for _ in 0..64 {
            match micro_step(&p, &g, &shapes, &action, &profile, &spec, false,
                             &mut rng) {
                StepOutcome::Buggy(b) => {
                    assert_eq!(b.mutations.len(), 1);
                    assert!(matches!(b.mutations[0].kind,
                                     MutationKind::BoundaryDrop { .. }));
                    return;
                }
                StepOutcome::Ok(_) => continue,
                other => panic!("expected ok/buggy, got {other:?}"),
            }
        }
        panic!("no buggy outcome in 64 draws at p=0.9");
    }
}
