//! Single-pass whole-kernel generation: the regime every *baseline* LLM
//! operates in (and the "w/o Hier" ablation of Table 6). The model decides
//! and implements all its optimizations in one shot — so implementation
//! errors compound over every simultaneous decision
//! ([`LlmProfile::holistic_err_total`]), which is precisely the failure
//! mode MTMC's stepwise decomposition removes.

use super::profiles::LlmProfile;
use crate::gpusim::GpuSpec;
use crate::graph::{Graph, Mutation};
use crate::kir::{lower_naive, Program};
use crate::transform::{
    action_mask, apply_action, decode_action, Action, STOP_ACTION,
};
use crate::util::Rng;

/// How the single pass decides what to attempt.
#[derive(Clone, Debug)]
pub enum SinglePassMode {
    /// The model freely picks `~ambition` optimizations (baseline LLMs).
    Freeform,
    /// A fixed action plan is handed over in one prompt (Table 6 "w/o
    /// Hier": MTMC's plan without the stepwise implementation loop).
    AllActionsAtOnce(Vec<Action>),
}

/// Output of a single-pass generation.
#[derive(Clone, Debug)]
pub enum SinglePassOutcome {
    Generated(Program),
    CompileError,
}

/// Sample up to `k` valid actions greedily from the current mask.
fn sample_plan(g: &Graph, shapes: &[Vec<usize>], spec: &GpuSpec, k: usize,
               quality: f32, rng: &mut Rng) -> (Program, usize) {
    let mut p = lower_naive(g);
    let mut applied = 0;
    for _ in 0..k {
        let mask = action_mask(&p, g, shapes, spec);
        let valid: Vec<usize> = (0..STOP_ACTION).filter(|&a| mask[a]).collect();
        if valid.is_empty() {
            break;
        }
        // weight choices toward high-impact types proportionally to skill:
        // skilled models know tiling/fusion matter most
        let weights: Vec<f64> = valid
            .iter()
            .map(|&a| {
                let act = decode_action(a);
                let impact = match act.opt {
                    crate::transform::OptType::TileShared => 3.0,
                    crate::transform::OptType::FuseEpilogue => 2.5,
                    crate::transform::OptType::TileReg => 2.0,
                    crate::transform::OptType::Reorder => 1.8,
                    crate::transform::OptType::FuseProducer => 1.5,
                    crate::transform::OptType::PipelineDouble => 1.4,
                    crate::transform::OptType::PipelineAsync => 1.2,
                    crate::transform::OptType::Vectorize => 1.0,
                };
                1.0 + (impact - 1.0) * quality as f64
            })
            .collect();
        let pick = valid[rng.weighted(&weights)];
        match apply_action(&p, g, shapes, &decode_action(pick), spec, quality) {
            Ok(next) => {
                p = next;
                applied += 1;
            }
            Err(_) => continue,
        }
    }
    (p, applied)
}

/// Run one single-pass generation.
pub fn single_pass_generate(
    g: &Graph,
    shapes: &[Vec<usize>],
    profile: &LlmProfile,
    spec: &GpuSpec,
    mode: &SinglePassMode,
    cuda: bool,
    rng: &mut Rng,
) -> SinglePassOutcome {
    let rounds = 1 + profile.refine_rounds;
    for round in 0..rounds {
        // refinement backs off ambition (simpler code on retry)
        let backoff = 1.0 - 0.25 * round as f64;
        let quality = (profile.param_skill as f32 + 0.2 * (rng.f32() - 0.5))
            .clamp(0.05, 1.0);
        let (program, attempted) = match mode {
            SinglePassMode::Freeform => {
                let k = ((profile.ambition * backoff) + rng.f64() - 0.5)
                    .round()
                    .clamp(1.0, 6.0) as usize;
                sample_plan(g, shapes, spec, k, quality, rng)
            }
            SinglePassMode::AllActionsAtOnce(plan) => {
                let mut p = lower_naive(g);
                let mut applied = 0;
                for a in plan {
                    if let Ok(next) = apply_action(&p, g, shapes, a, spec, quality) {
                        p = next;
                        applied += 1;
                    }
                }
                (p, applied)
            }
        };
        let err_p = profile.holistic_err_total(attempted.max(1), g.op_count(), cuda);
        if !rng.bool(err_p) {
            return SinglePassOutcome::Generated(program);
        }
        if rng.bool(profile.compile_frac) {
            // compile error: retry if the profile self-refines
            continue;
        }
        // silent bug(s): attach to 1-2 random kernels and return — the
        // model believes it succeeded
        let mut buggy = program;
        let n_bugs = 1 + rng.below(2);
        for _ in 0..n_bugs {
            let ki = rng.below(buggy.kernels.len());
            let site = *buggy.kernels[ki].nodes.last().unwrap();
            let fake_action = Action {
                opt: crate::transform::OptType::TileShared,
                region: 0,
            };
            buggy.mutations.push(Mutation {
                node: site,
                kind: super::coder::draw_bug(&fake_action, rng),
            });
        }
        return SinglePassOutcome::Generated(buggy);
    }
    SinglePassOutcome::CompileError
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;
    use crate::microcode::check::{check_correct, CheckOutcome};
    use crate::microcode::profiles::ProfileId;

    fn fused_task() -> (Graph, Graph) {
        let build = |dims: (usize, usize)| {
            let (m, n) = dims;
            let mut g = Graph::new("t");
            let x = g.input("x", &[m, n]);
            let w = g.weight("w", &[n, n]);
            let b = g.weight("b", &[n]);
            let mm = g.op(Op::MatMul, &[x, w]);
            let ba = g.op(Op::BiasAdd, &[mm, b]);
            let r = g.op(Op::Relu, &[ba]);
            g.mark_output(r);
            g
        };
        (build((1024, 1024)), build((12, 8)))
    }

    #[test]
    fn single_pass_produces_valid_or_compile_error() {
        let (g, _) = fused_task();
        let shapes = crate::graph::infer_shapes(&g);
        let profile = LlmProfile::get(ProfileId::DeepSeekV3);
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            match single_pass_generate(&g, &shapes, &profile, &GpuSpec::a100(),
                                       &SinglePassMode::Freeform, false, &mut rng) {
                SinglePassOutcome::Generated(p) => p.validate(&g).unwrap(),
                SinglePassOutcome::CompileError => {}
            }
        }
    }

    #[test]
    fn accuracy_gap_between_strong_and_weak() {
        let (g, verif) = fused_task();
        let shapes = crate::graph::infer_shapes(&g);
        let spec = GpuSpec::a100();
        let acc = |id: ProfileId, seed: u64| -> f64 {
            let profile = LlmProfile::get(id);
            let mut rng = Rng::new(seed);
            let n = 120;
            let mut ok = 0;
            for i in 0..n {
                if let SinglePassOutcome::Generated(p) = single_pass_generate(
                    &g, &shapes, &profile, &spec, &SinglePassMode::Freeform,
                    false, &mut rng,
                ) {
                    if check_correct(&p, &verif, 2, i as u64) == CheckOutcome::Correct {
                        ok += 1;
                    }
                }
            }
            ok as f64 / n as f64
        };
        let strong = acc(ProfileId::GeminiPro25, 3);
        let weak = acc(ProfileId::QwenCoder32B, 3);
        assert!(strong > weak + 0.2, "strong {strong:.2} vs weak {weak:.2}");
    }

    #[test]
    fn refinement_rounds_lift_compile_rate() {
        let (g, _) = fused_task();
        let shapes = crate::graph::infer_shapes(&g);
        let spec = GpuSpec::a100();
        let compile_rate = |refines: usize| -> f64 {
            let mut profile = LlmProfile::get(ProfileId::Gpt4o);
            profile.refine_rounds = refines;
            let mut rng = Rng::new(17);
            let n = 200;
            (0..n)
                .filter(|_| {
                    matches!(
                        single_pass_generate(&g, &shapes, &profile, &spec,
                                             &SinglePassMode::Freeform, false,
                                             &mut rng),
                        SinglePassOutcome::Generated(_)
                    )
                })
                .count() as f64
                / n as f64
        };
        assert!(compile_rate(3) > compile_rate(0));
    }
}
