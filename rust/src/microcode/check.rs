//! Correctness measurement: run the (possibly mutated) program semantics
//! on the verification graph against the clean reference, exactly how the
//! benchmarks check generated kernels (random inputs + allclose).

use crate::graph::{eval_graph, eval_graph_with_mutations, Graph};
use crate::kir::Program;
use crate::tensor::Tensor;
use crate::util::Rng;

pub const VERIF_RTOL: f32 = 1e-3;
pub const VERIF_ATOL: f32 = 1e-3;

/// Result of a correctness check.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CheckOutcome {
    /// Did not compile — "call" failure in TritonBench terms.
    CompileFail,
    /// Ran but produced wrong numbers — "execute" failure.
    WrongResult,
    /// Correct.
    Correct,
}

/// Draw deterministic verification inputs for a graph.
pub fn verif_inputs(g: &Graph, rng: &mut Rng) -> Vec<Tensor> {
    g.input_ids()
        .iter()
        .map(|&id| {
            let shape = g.nodes[id].input_shape.as_ref().unwrap();
            Tensor::randn(shape, rng)
        })
        .collect()
}

/// Check a program against the clean reference on `trials` random input
/// draws (benchmarks use several trials to catch data-dependent bugs).
pub fn check_correct(p: &Program, verif_graph: &Graph, trials: usize,
                     seed: u64) -> CheckOutcome {
    if p.compile_broken {
        return CheckOutcome::CompileFail;
    }
    let mut rng = Rng::new(seed);
    for _ in 0..trials.max(1) {
        let inputs = verif_inputs(verif_graph, &mut rng);
        let clean = eval_graph(verif_graph, &inputs);
        let got = eval_graph_with_mutations(verif_graph, &inputs, &p.mutations);
        for (c, g_) in clean.iter().zip(&got) {
            if !g_.allclose(c, VERIF_RTOL, VERIF_ATOL) {
                return CheckOutcome::WrongResult;
            }
        }
    }
    CheckOutcome::Correct
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Mutation, MutationKind, Op};
    use crate::kir::lower_naive;

    fn demo() -> Graph {
        let mut g = Graph::new("t");
        let x = g.input("x", &[6, 8]);
        let w = g.weight("w", &[8, 4]);
        let mm = g.op(Op::MatMul, &[x, w]);
        let r = g.op(Op::Relu, &[mm]);
        g.mark_output(r);
        g
    }

    #[test]
    fn clean_program_is_correct() {
        let g = demo();
        let p = lower_naive(&g);
        assert_eq!(check_correct(&p, &g, 3, 42), CheckOutcome::Correct);
    }

    #[test]
    fn mutated_program_detected() {
        let g = demo();
        let mut p = lower_naive(&g);
        p.mutations.push(Mutation {
            node: 2,
            kind: MutationKind::RaceCorruption { scale: 0.5 },
        });
        assert_eq!(check_correct(&p, &g, 3, 42), CheckOutcome::WrongResult);
    }

    #[test]
    fn compile_broken_detected_first() {
        let g = demo();
        let mut p = lower_naive(&g);
        p.compile_broken = true;
        assert_eq!(check_correct(&p, &g, 3, 42), CheckOutcome::CompileFail);
    }

    #[test]
    fn check_is_deterministic_in_seed() {
        let g = demo();
        let mut p = lower_naive(&g);
        p.mutations.push(Mutation {
            node: 3,
            kind: MutationKind::BoundaryDrop { frac: 0.3 },
        });
        assert_eq!(check_correct(&p, &g, 2, 1), check_correct(&p, &g, 2, 1));
    }

    #[test]
    fn tiny_boundary_bug_still_caught() {
        // a 2% boundary drop on a small tensor must still flip at least
        // one element beyond tolerance in 3 trials
        let g = demo();
        let mut p = lower_naive(&g);
        p.mutations.push(Mutation {
            node: 3,
            kind: MutationKind::BoundaryDrop { frac: 0.05 },
        });
        assert_eq!(check_correct(&p, &g, 3, 9), CheckOutcome::WrongResult);
    }
}
