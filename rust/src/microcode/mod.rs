//! Micro-Coding engine: turns semantic optimization actions into concrete
//! schedule changes, through a per-LLM **competence model** that reproduces
//! the failure distribution the benchmarks measure (compile errors,
//! silent numeric bugs, suboptimal parameter choices).
//!
//! The engine *actually applies* the transformation ([`crate::transform`])
//! and *actually injects* executable semantic bugs
//! ([`crate::graph::Mutation`]) — correctness is then measured by running
//! the mutated verif graph against the clean one ([`check`]), never
//! assumed. This is the documented substitution for calling a live LLM
//! (DESIGN.md): the distribution of outcomes is calibrated per model, but
//! every outcome is a real program with a real (in)correctness.

mod profiles;
mod coder;
mod check;
mod singlepass;

pub use check::{check_correct, CheckOutcome, VERIF_ATOL, VERIF_RTOL};
pub use coder::{micro_step, micro_step_at, StepOutcome};
pub use profiles::{LlmProfile, ProfileId};
pub use singlepass::{single_pass_generate, SinglePassMode, SinglePassOutcome};
