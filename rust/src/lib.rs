//! # qimeng-mtmc
//!
//! Reproduction of **QiMeng-Kernel: Macro-Thinking Micro-Coding (MTMC)**
//! (AAAI 2026) as a three-layer rust + JAX + Pallas system.
//!
//! - **Layer 3 (this crate)** — the MTMC coordinator: kernel IR and
//!   schedule transforms ([`kir`], [`transform`]), AST/dataflow region
//!   analysis, the Micro-Coding engine with per-LLM competence models
//!   ([`microcode`]), the analytic GPU simulator ([`gpusim`]), the
//!   tree-structured RL environment ([`env`], [`dataset`]), the PPO
//!   orchestrator ([`train`]), and the benchmark harness regenerating every
//!   paper table ([`eval`], [`report`]).
//! - **Layer 2** — the Macro-Thinking policy network (JAX, AOT-lowered to
//!   HLO text; loaded by [`runtime`] through PJRT).
//! - **Layer 1** — Pallas kernels inside the L2 model (fused linear layers,
//!   masked softmax head).
//!
//! Python never runs on the request path: the macro-thinking loop calls
//! the compiled artifacts through [`runtime::PjrtRuntime`].
//!
//! See `DESIGN.md` for the system inventory, the per-experiment index and
//! the substitution table (simulated GPUs / LLMs per the repro policy).

pub mod util;
pub mod testkit;
pub mod tensor;
pub mod graph;
pub mod tasks;
pub mod kir;
pub mod transform;
pub mod gpusim;
pub mod microcode;
pub mod env;
pub mod engine;
pub mod dataset;
pub mod runtime;
pub mod policy;
pub mod train;
pub mod eval;
pub mod report;

/// Crate-wide result alias (library errors are `thiserror` enums per
/// module; binaries use `anyhow`).
pub type Result<T> = anyhow::Result<T>;

/// Well-known repository paths.
pub mod paths {
    use std::path::PathBuf;

    /// The AOT artifact directory: `$QIMENG_ARTIFACTS` if set, else
    /// `<crate root>/artifacts` (where `make artifacts` writes).
    pub fn artifacts_dir() -> PathBuf {
        std::env::var("QIMENG_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| {
                std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
            })
    }

    /// Default location for trained policy parameters.
    pub fn default_policy_path() -> PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("data")
            .join("policy.bin")
    }
}
