//! Candidate code-region analysis (paper §4.2): the action space is
//! (optimization type × code region), where regions come from data-flow +
//! AST analysis of the current program. We expose at most [`MAX_REGIONS`]
//! slots; the policy's action mask hides empty slots.
//!
//! Region slots are ordered deterministically: kernel regions first (by
//! kernel index), then fusion-edge regions (by producer index). This
//! keeps the action space stable across a trajectory so the policy can
//! learn positional semantics.

use super::ir::Program;
use crate::graph::{Graph, OpClass};

/// Maximum region slots exposed to the policy (action space = 8 opt types
/// x MAX_REGIONS + Stop).
pub const MAX_REGIONS: usize = 8;

/// What a region denotes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegionKind {
    /// A whole kernel (its dominant loop nest) — target of tiling,
    /// pipelining, reordering, vectorizing.
    Kernel { kernel: usize },
    /// A fusible producer->consumer kernel edge — target of fusion.
    FusionEdge { producer: usize, consumer: usize },
}

/// One candidate region with a human-readable description (the "lines 15
/// to 20" part of the paper's action example). `PartialEq` so the
/// differential tests can compare cached against freshly-analyzed
/// regions field-for-field.
#[derive(Clone, Debug, PartialEq)]
pub struct Region {
    pub kind: RegionKind,
    pub describe: String,
}

/// Compute the candidate regions of a program.
///
/// Kernel regions are emitted for every kernel whose anchor is worth
/// scheduling (everything except pure movement). Fusion edges are emitted
/// for adjacent kernels where (a) the producer's sole consumer is the
/// consumer kernel and (b) the consumer is epilogue-fusible or the
/// producer is elementwise (producer fusion).
pub fn analyze_regions(p: &Program, g: &Graph) -> Vec<Region> {
    let mut out = Vec::new();
    // kernel regions, hottest first: contraction anchors, then reductions,
    // then elementwise — keeps slot 0 pointing at the hot loop nest.
    let mut order: Vec<usize> = (0..p.kernels.len()).collect();
    let rank = |ki: usize| -> usize {
        match g.nodes[p.kernels[ki].anchor(g)].op.class() {
            OpClass::Contraction => 0,
            OpClass::Reduction => 1,
            OpClass::Elementwise => 2,
            _ => 3,
        }
    };
    order.sort_by_key(|&ki| (rank(ki), ki));
    for &ki in &order {
        if out.len() >= MAX_REGIONS {
            break;
        }
        let k = &p.kernels[ki];
        // movement-anchored kernels stay schedulable too: loop order and
        // vector width are exactly what a transpose kernel tunes
        out.push(Region {
            kind: RegionKind::Kernel { kernel: ki },
            describe: format!(
                "kernel `{}` (ops {})",
                k.name,
                k.nodes
                    .iter()
                    .map(|&n| g.nodes[n].op.mnemonic())
                    .collect::<Vec<_>>()
                    .join("+")
            ),
        });
    }
    // fusion edges
    let consumers = g.consumers();
    for (pi, pk) in p.kernels.iter().enumerate() {
        if out.len() >= MAX_REGIONS {
            break;
        }
        // kernel outputs = nodes whose consumers are outside the kernel
        let last = *pk.nodes.last().unwrap();
        let outside: Vec<usize> = consumers[last]
            .iter()
            .copied()
            .filter(|c| !pk.nodes.contains(c))
            .collect();
        if outside.is_empty() {
            continue;
        }
        // single consuming kernel?
        let mut ckis: Vec<usize> = outside
            .iter()
            .filter_map(|&c| p.kernel_of(c))
            .collect();
        ckis.sort();
        ckis.dedup();
        if ckis.len() != 1 {
            continue;
        }
        let ci = ckis[0];
        if ci == pi {
            continue;
        }
        // graph outputs must stay materialized: if the producer's last
        // node is a graph output, fusing would still need the write-out;
        // allow it (epilogue keeps the store) — no constraint here.
        let ck = &p.kernels[ci];
        let consumer_first_op = &g.nodes[ck.nodes[0]].op;
        let producer_anchor_cls = g.nodes[pk.anchor(g)].op.class();
        let fusible = consumer_first_op.fusible_as_epilogue()
            || producer_anchor_cls == OpClass::Elementwise;
        if !fusible {
            continue;
        }
        out.push(Region {
            kind: RegionKind::FusionEdge { producer: pi, consumer: ci },
            describe: format!(
                "edge `{}` -> `{}`",
                pk.name, p.kernels[ci].name
            ),
        });
    }
    out.truncate(MAX_REGIONS);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, Op};
    use crate::kir::lower_naive;

    fn gemm_bias_relu() -> Graph {
        let mut g = Graph::new("t");
        let x = g.input("x", &[64, 64]);
        let w = g.weight("w", &[64, 64]);
        let b = g.weight("b", &[64]);
        let mm = g.op(Op::MatMul, &[x, w]);
        let ba = g.op(Op::BiasAdd, &[mm, b]);
        let r = g.op(Op::Relu, &[ba]);
        g.mark_output(r);
        g
    }

    #[test]
    fn regions_include_kernels_and_edges() {
        let g = gemm_bias_relu();
        let p = lower_naive(&g);
        let regions = analyze_regions(&p, &g);
        let kernels = regions
            .iter()
            .filter(|r| matches!(r.kind, RegionKind::Kernel { .. }))
            .count();
        let edges = regions
            .iter()
            .filter(|r| matches!(r.kind, RegionKind::FusionEdge { .. }))
            .count();
        assert_eq!(kernels, 3);
        assert_eq!(edges, 2, "matmul->bias and bias->relu edges");
    }

    #[test]
    fn contraction_kernel_ranked_first() {
        let g = gemm_bias_relu();
        let p = lower_naive(&g);
        let regions = analyze_regions(&p, &g);
        match regions[0].kind {
            RegionKind::Kernel { kernel } => {
                assert!(p.kernels[kernel].name.contains("matmul"))
            }
            _ => panic!("first region should be the matmul kernel"),
        }
    }

    #[test]
    fn bounded_by_max_regions() {
        // L3 networks have tens of kernels; regions must stay <= 8
        for t in crate::tasks::kernelbench_level(3).iter().take(5) {
            let p = lower_naive(&t.graph);
            let r = analyze_regions(&p, &t.graph);
            assert!(r.len() <= MAX_REGIONS);
            assert!(!r.is_empty());
        }
    }

    #[test]
    fn no_edge_when_consumer_not_fusible() {
        // matmul -> matmul edge is not an epilogue fusion candidate
        let mut g = Graph::new("t");
        let x = g.input("x", &[32, 32]);
        let w1 = g.weight("w1", &[32, 32]);
        let w2 = g.weight("w2", &[32, 32]);
        let m1 = g.op(Op::MatMul, &[x, w1]);
        let m2 = g.op(Op::MatMul, &[m1, w2]);
        g.mark_output(m2);
        let p = lower_naive(&g);
        let regions = analyze_regions(&p, &g);
        assert!(regions
            .iter()
            .all(|r| !matches!(r.kind, RegionKind::FusionEdge { .. })));
    }
}
