//! Static schedule verifier: the first of the three rejection tiers
//! (static verify → dynamic verif trials → perf pricing). Checks a
//! `(Program, Graph, GpuSpec)` triple for schedule legality *without*
//! running anything: tile/extent coverage, vector-width compatibility
//! with the innermost loop, reorder role constraints, pipeline staging,
//! shared-memory and register budgets, and write-set races between
//! fused nodes.
//!
//! Severity semantics: `Error` rules are invariants every transform in
//! `transform/` preserves — they never fire on programs reachable from
//! `lower_naive` via legal actions, so the pre-verif gate in
//! `OptimEnv::transition` is behaviour-neutral on the normal eval path
//! (guarded by `rust/tests/verify.rs`). `Warning` rules flag
//! performance-hostile but correct schedules (tile overhang, remainder
//! iterations, vector width vs. odd extents) and only show up in
//! `repro lint` output.

use super::ir::{LoopOrder, Program};
use super::loops::{loop_nest, LoopKind};
use crate::gpusim::GpuSpec;
use crate::graph::{Graph, OpClass};
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Architectural per-thread register file limit (all three simulated
/// parts: 255 usable registers per thread).
const MAX_REGS_PER_THREAD: usize = 255;
/// Accumulator/address scratch the renderer needs beyond the register
/// tile itself.
const REG_SCRATCH: usize = 32;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Suspicious but legal: the schedule runs correctly, just not well.
    Warning,
    /// Statically illegal: the schedule cannot be lowered to correct
    /// code. Transforms must never produce these.
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Warning => write!(f, "warning"),
            Severity::Error => write!(f, "error"),
        }
    }
}

/// Which invariant a diagnostic comes from. The kebab-case `name()` is
/// stable output — `repro lint --json` and tests match on it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rule {
    /// Program shape: node ids in range, `Program::validate` holds, not
    /// compile-broken.
    Structure,
    /// A loop tile of zero iterations.
    TileZero,
    /// Tile larger than the extent it splits.
    TileExceedsExtent,
    /// Extent not divisible by its tile (remainder iterations).
    TileRemainder,
    /// Vector width outside {1, 2, 4, 8}.
    VectorWidth,
    /// Vector loads on a naive (uncoalesced) loop order.
    VectorOrder,
    /// Vector width incompatible with the innermost loop extent/role.
    VectorExtent,
    /// Loop order inconsistent with the tiling state.
    ReorderRole,
    /// Pipeline depth outside what the schedule/spec can stage.
    PipelineStaging,
    /// Shared-memory estimate over the GpuSpec budget.
    SmemBudget,
    /// Register estimate over the per-thread architectural limit.
    RegBudget,
    /// Fused nodes whose write sets alias across a parallel axis.
    RaceOverlap,
    /// Epilogue reduction split across block tiles of the parallel axis.
    RaceSplitReduction,
}

impl Rule {
    pub fn name(&self) -> &'static str {
        match self {
            Rule::Structure => "structure",
            Rule::TileZero => "tile-zero",
            Rule::TileExceedsExtent => "tile-exceeds-extent",
            Rule::TileRemainder => "tile-remainder",
            Rule::VectorWidth => "vector-width",
            Rule::VectorOrder => "vector-order",
            Rule::VectorExtent => "vector-extent",
            Rule::ReorderRole => "reorder-role",
            Rule::PipelineStaging => "pipeline-staging",
            Rule::SmemBudget => "smem-budget",
            Rule::RegBudget => "reg-budget",
            Rule::RaceOverlap => "race-overlap",
            Rule::RaceSplitReduction => "race-split-reduction",
        }
    }
}

/// One finding: which rule, which kernel (None = whole program), how
/// bad, and a human-readable message.
#[derive(Clone, Debug, PartialEq)]
pub struct Diagnostic {
    pub rule: Rule,
    pub kernel: Option<usize>,
    pub severity: Severity,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.kernel {
            Some(k) => write!(
                f,
                "{}[{}] kernel {}: {}",
                self.severity,
                self.rule.name(),
                k,
                self.msg
            ),
            None => write!(f, "{}[{}] {}", self.severity, self.rule.name(), self.msg),
        }
    }
}

/// Statically verify a scheduled program. Never panics, whatever the
/// input: structural damage (out-of-range node ids, validate failures)
/// is reported as `Structure` errors and cuts the analysis short
/// instead of indexing past the graph.
pub fn verify(
    p: &Program,
    g: &Graph,
    shapes: &[Vec<usize>],
    spec: &GpuSpec,
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    // Tier 0: bounds. `Program::validate`, `Kernel::anchor` and
    // `loop_nest` all index `g.nodes[n]` unchecked, so nothing below is
    // safe until every node id is in range.
    if shapes.len() < g.nodes.len() {
        diags.push(Diagnostic {
            rule: Rule::Structure,
            kernel: None,
            severity: Severity::Error,
            msg: format!(
                "shape table has {} entries for a graph of {} nodes",
                shapes.len(),
                g.nodes.len()
            ),
        });
        return diags;
    }
    for (ki, k) in p.kernels.iter().enumerate() {
        for &n in &k.nodes {
            if n >= g.nodes.len() {
                diags.push(Diagnostic {
                    rule: Rule::Structure,
                    kernel: Some(ki),
                    severity: Severity::Error,
                    msg: format!(
                        "references node {n}, but the graph has {} nodes",
                        g.nodes.len()
                    ),
                });
            }
        }
    }
    if !diags.is_empty() {
        return diags;
    }
    if let Err(e) = p.validate(g) {
        diags.push(Diagnostic {
            rule: Rule::Structure,
            kernel: None,
            severity: Severity::Error,
            msg: e,
        });
        return diags;
    }
    if p.compile_broken {
        diags.push(Diagnostic {
            rule: Rule::Structure,
            kernel: None,
            severity: Severity::Error,
            msg: "program is compile-broken (last micro-coding step failed)"
                .into(),
        });
    }
    for (ki, k) in p.kernels.iter().enumerate() {
        check_kernel(&mut diags, ki, k, g, shapes, spec);
    }
    diags
}

/// Graph-free subset of [`verify`]: the invariants checkable from the
/// `Program` alone, with no graph, shape table, or GPU spec in hand.
/// This is the screen the memo-store warm start applies to programs
/// deserialized from disk — cached edges are keyed by opaque context
/// hashes, so the full `(Program, Graph, GpuSpec)` triple is not
/// reconstructible there. Every check below mirrors an Error-severity
/// rule of the full verifier (or a `Program::validate` invariant), so a
/// program this function rejects could never have been produced by the
/// transform menu: it is stale or corrupt store content, and dropping
/// it forces a clean recomputation instead of replaying damage.
pub fn verify_intrinsic(p: &Program) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    if p.compile_broken {
        diags.push(Diagnostic {
            rule: Rule::Structure,
            kernel: None,
            severity: Severity::Error,
            msg: "program is compile-broken (last micro-coding step failed)"
                .into(),
        });
    }
    for (ki, k) in p.kernels.iter().enumerate() {
        let mut push = |rule, msg| {
            diags.push(Diagnostic {
                rule,
                kernel: Some(ki),
                severity: Severity::Error,
                msg,
            });
        };
        if k.nodes.is_empty() {
            push(Rule::Structure, "kernel is empty".into());
        }
        if k.nodes.windows(2).any(|w| w[0] >= w[1]) {
            push(Rule::Structure, "kernel nodes not topo-sorted".into());
        }
        let sched = &k.schedule;
        if let Some((m, n, kk)) = sched.block_tile {
            if m == 0 || n == 0 || kk == 0 {
                push(
                    Rule::TileZero,
                    format!("block tile {m}x{n}x{kk} has a zero dimension"),
                );
            }
        }
        let w = sched.vector_width;
        if !matches!(w, 1 | 2 | 4 | 8) {
            push(
                Rule::VectorWidth,
                format!("vector width {w} is not one of 1/2/4/8"),
            );
        } else if w > 1 && sched.loop_order == LoopOrder::Naive {
            push(
                Rule::VectorOrder,
                format!("vector width {w} on a naive loop order"),
            );
        }
        let depth = sched.pipeline_depth;
        if depth == 0 || depth > 4 {
            push(
                Rule::PipelineStaging,
                format!("pipeline depth {depth} outside 1..=4"),
            );
        } else if depth > 1 && sched.block_tile.is_none() {
            push(
                Rule::PipelineStaging,
                "pipelined without a block tile (nothing to stage)".into(),
            );
        }
        if let Some((rm, rn)) = sched.reg_tile {
            if rm == 0 || rn == 0 {
                push(
                    Rule::RegBudget,
                    format!("register tile {rm}x{rn} has a zero dimension"),
                );
            } else if rm * rn + rm + rn + REG_SCRATCH > MAX_REGS_PER_THREAD {
                push(
                    Rule::RegBudget,
                    format!(
                        "register tile {rm}x{rn} is over the \
                         {MAX_REGS_PER_THREAD}-register limit"
                    ),
                );
            }
        }
    }
    diags
}

/// True iff [`verify_intrinsic`] reports no Error-severity diagnostic —
/// the predicate the memo-store warm start applies to cached programs.
pub fn is_intrinsically_legal(p: &Program) -> bool {
    !has_errors(&verify_intrinsic(p))
}

/// True iff `verify` reports no Error-severity diagnostic. This is the
/// predicate the pre-verif gate in `OptimEnv::transition` applies.
pub fn is_statically_legal(
    p: &Program,
    g: &Graph,
    shapes: &[Vec<usize>],
    spec: &GpuSpec,
) -> bool {
    !has_errors(&verify(p, g, shapes, spec))
}

/// Any Error-severity diagnostic in the batch?
pub fn has_errors(diags: &[Diagnostic]) -> bool {
    diags.iter().any(|d| d.severity == Severity::Error)
}

fn check_kernel(
    diags: &mut Vec<Diagnostic>,
    ki: usize,
    k: &super::ir::Kernel,
    g: &Graph,
    shapes: &[Vec<usize>],
    spec: &GpuSpec,
) {
    let sched = &k.schedule;
    let anchor = k.anchor(g);
    let anchor_cls = g.nodes[anchor].op.class();
    let nest = loop_nest(k, g, shapes);
    let mut push = |rule, severity, msg| {
        diags.push(Diagnostic { rule, kernel: Some(ki), severity, msg });
    };

    // --- tiles vs. loop extents -------------------------------------
    for l in &nest {
        if let Some(t) = l.tile {
            if t == 0 {
                push(
                    Rule::TileZero,
                    Severity::Error,
                    format!("loop `{}` tiled by zero", l.var),
                );
            } else if t > l.extent {
                push(
                    Rule::TileExceedsExtent,
                    Severity::Warning,
                    format!(
                        "tile {} on loop `{}` exceeds its extent {}",
                        t, l.var, l.extent
                    ),
                );
            } else if l.extent % t != 0 {
                push(
                    Rule::TileRemainder,
                    Severity::Warning,
                    format!(
                        "loop `{}` extent {} is not a multiple of tile {} \
                         (remainder iterations)",
                        l.var, l.extent, t
                    ),
                );
            }
        }
    }

    // --- vector width vs. innermost loop -----------------------------
    let w = sched.vector_width;
    if !matches!(w, 1 | 2 | 4 | 8) {
        push(
            Rule::VectorWidth,
            Severity::Error,
            format!("vector width {w} is not one of 1/2/4/8"),
        );
    } else if w > 1 {
        if sched.loop_order == LoopOrder::Naive {
            push(
                Rule::VectorOrder,
                Severity::Error,
                format!(
                    "vector width {w} on a naive loop order: vector loads \
                     need contiguous (coalesced or blocked) accesses"
                ),
            );
        }
        if let Some(inner) = nest.last() {
            if inner.kind == LoopKind::Window {
                push(
                    Rule::VectorExtent,
                    Severity::Warning,
                    format!(
                        "vector width {} across window loop `{}` \
                         (extent {}): window taps are strided",
                        w, inner.var, inner.extent
                    ),
                );
            } else if w > inner.extent || inner.extent % w != 0 {
                push(
                    Rule::VectorExtent,
                    Severity::Warning,
                    format!(
                        "vector width {} does not divide innermost loop \
                         `{}` extent {}",
                        w, inner.var, inner.extent
                    ),
                );
            }
        }
    }

    // --- loop order vs. tiling state ---------------------------------
    match sched.loop_order {
        LoopOrder::Blocked if sched.block_tile.is_none() => push(
            Rule::ReorderRole,
            Severity::Warning,
            "blocked loop order without a block tile (no tiles to be \
             block-major over)"
                .into(),
        ),
        LoopOrder::Coalesced if sched.block_tile.is_some() => push(
            Rule::ReorderRole,
            Severity::Warning,
            "coalesced loop order on a tiled kernel discards tile-major \
             locality"
                .into(),
        ),
        _ => {}
    }

    // --- pipeline staging --------------------------------------------
    let depth = sched.pipeline_depth;
    if depth == 0 {
        push(
            Rule::PipelineStaging,
            Severity::Error,
            "pipeline depth 0 (1 means unpipelined)".into(),
        );
    } else if depth > 4 {
        push(
            Rule::PipelineStaging,
            Severity::Error,
            format!("pipeline depth {depth} exceeds the 4-stage maximum"),
        );
    } else if depth >= 3 && !spec.supports_async_copy() {
        push(
            Rule::PipelineStaging,
            Severity::Error,
            format!(
                "pipeline depth {} needs cp.async-style staging, which {} \
                 does not support",
                depth, spec.name
            ),
        );
    }

    // --- shared memory budget ----------------------------------------
    let smem = sched.smem_bytes();
    if smem > spec.smem_bytes() {
        push(
            Rule::SmemBudget,
            Severity::Error,
            format!(
                "schedule stages {} B of shared memory; {} has {} B per SM",
                smem,
                spec.name,
                spec.smem_bytes()
            ),
        );
    }

    // --- register budget ----------------------------------------------
    if let Some((rm, rn)) = sched.reg_tile {
        if rm == 0 || rn == 0 {
            push(
                Rule::RegBudget,
                Severity::Error,
                format!("register tile {rm}x{rn} has a zero dimension"),
            );
        } else {
            // accumulator tile + one operand fragment per axis + scratch
            let est = rm * rn + rm + rn + REG_SCRATCH;
            if est > MAX_REGS_PER_THREAD {
                push(
                    Rule::RegBudget,
                    Severity::Error,
                    format!(
                        "register tile {rm}x{rn} needs ~{est} registers per \
                         thread, over the {MAX_REGS_PER_THREAD} limit"
                    ),
                );
            }
        }
        match sched.block_tile {
            None => push(
                Rule::RegBudget,
                Severity::Warning,
                "register tile without a block tile (nothing to subdivide)"
                    .into(),
            ),
            Some((bm, bn, _)) if rm > bm || rn > bn => push(
                Rule::RegBudget,
                Severity::Warning,
                format!(
                    "register tile {rm}x{rn} exceeds its block tile \
                     {bm}x{bn}"
                ),
            ),
            _ => {}
        }
    }

    // --- write-set races between fused nodes --------------------------
    // Two contraction nodes in one kernel accumulate into distinct
    // outputs from the same grid: their write sets alias across the
    // parallel axes. Same for a reduction fused anywhere but as the
    // anchor or a recognised epilogue.
    let contractions = k
        .nodes
        .iter()
        .filter(|&&n| g.nodes[n].op.class() == OpClass::Contraction)
        .count();
    if contractions > 1 {
        push(
            Rule::RaceOverlap,
            Severity::Error,
            format!(
                "fuses {contractions} contraction nodes; their accumulator \
                 write sets alias across the parallel grid"
            ),
        );
    }
    for &n in &k.nodes {
        if n == anchor {
            continue;
        }
        let op = &g.nodes[n].op;
        if op.class() == OpClass::Reduction && !op.fusible_as_epilogue() {
            push(
                Rule::RaceOverlap,
                Severity::Error,
                format!(
                    "non-epilogue reduction `{}` (node {}) fused off-anchor \
                     writes across the parallel axis",
                    op.mnemonic(),
                    n
                ),
            );
        }
    }
    // An epilogue reduction inside a tiled contraction kernel reduces
    // over an axis the block tile splits: each block holds only a
    // partial, and the partials alias the same output row.
    if anchor_cls == OpClass::Contraction {
        if let Some((_, bn, _)) = sched.block_tile {
            for &n in &k.nodes {
                if n == anchor || g.nodes[n].op.class() != OpClass::Reduction
                {
                    continue;
                }
                let node = &g.nodes[n];
                let reduced = node
                    .inputs
                    .first()
                    .and_then(|&i| shapes[i].last().copied())
                    .unwrap_or(1);
                if bn < reduced {
                    push(
                        Rule::RaceSplitReduction,
                        Severity::Warning,
                        format!(
                            "epilogue reduction `{}` (node {}) reduces {} \
                             elements split across {}-wide block tiles: \
                             blocks hold partial results",
                            node.op.mnemonic(),
                            n,
                            reduced,
                            bn
                        ),
                    );
                }
            }
        }
    }
}

/// Shared counters for the pre-verif static gate: how many candidate
/// programs were checked, and how many were rejected before paying for
/// dynamic verif trials. Owned by `engine::Session`, read by the
/// `StatsRegistry`.
#[derive(Debug, Default)]
pub struct GateStats {
    checks: AtomicUsize,
    rejects: AtomicUsize,
}

impl GateStats {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn note_check(&self) {
        self.checks.fetch_add(1, Ordering::Relaxed);
    }
    pub fn note_reject(&self) {
        self.rejects.fetch_add(1, Ordering::Relaxed);
    }
    pub fn checks(&self) -> usize {
        self.checks.load(Ordering::Relaxed)
    }
    pub fn rejects(&self) -> usize {
        self.rejects.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{infer_shapes, Graph, Op};
    use crate::kir::{lower_naive, Kernel, Schedule};

    fn gemm_relu() -> (Graph, Vec<Vec<usize>>) {
        let mut g = Graph::new("t");
        let x = g.input("x", &[128, 128]);
        let w = g.weight("w", &[128, 128]);
        let mm = g.op(Op::MatMul, &[x, w]);
        let r = g.op(Op::Relu, &[mm]);
        g.mark_output(r);
        let s = infer_shapes(&g);
        (g, s)
    }

    fn rules(diags: &[Diagnostic]) -> Vec<Rule> {
        diags.iter().map(|d| d.rule).collect()
    }

    #[test]
    fn naive_lowering_is_clean() {
        let (g, s) = gemm_relu();
        let p = lower_naive(&g);
        let diags = verify(&p, &g, &s, &crate::gpusim::GpuSpec::a100());
        assert!(diags.is_empty(), "{diags:?}");
        assert!(is_statically_legal(&p, &g, &s, &crate::gpusim::GpuSpec::a100()));
    }

    #[test]
    fn whole_corpus_is_clean_under_naive_lowering() {
        for spec in crate::gpusim::GpuSpec::all() {
            for t in crate::tasks::kernelbench_level(1).iter().take(8) {
                let shapes = infer_shapes(&t.graph);
                let p = lower_naive(&t.graph);
                let diags = verify(&p, &t.graph, &shapes, &spec);
                assert!(diags.is_empty(), "{}: {diags:?}", t.id);
            }
        }
    }

    #[test]
    fn out_of_range_node_is_reported_not_panicked() {
        let (g, s) = gemm_relu();
        let mut p = lower_naive(&g);
        p.kernels[0].nodes.push(99);
        let diags = verify(&p, &g, &s, &crate::gpusim::GpuSpec::a100());
        assert_eq!(rules(&diags), vec![Rule::Structure]);
        assert!(has_errors(&diags));
    }

    #[test]
    fn validate_failure_is_wrapped() {
        let (g, s) = gemm_relu();
        let mut p = lower_naive(&g);
        p.kernels[0].nodes.clear();
        let diags = verify(&p, &g, &s, &crate::gpusim::GpuSpec::a100());
        assert_eq!(rules(&diags), vec![Rule::Structure]);
        assert!(diags[0].msg.contains("empty"));
    }

    #[test]
    fn compile_broken_is_an_error() {
        let (g, s) = gemm_relu();
        let mut p = lower_naive(&g);
        p.compile_broken = true;
        assert!(!is_statically_legal(&p, &g, &s, &crate::gpusim::GpuSpec::a100()));
    }

    #[test]
    fn tile_overhang_and_remainder_warn_but_stay_legal() {
        let (g, s) = gemm_relu();
        let mut p = lower_naive(&g);
        // 96 does not divide 128; 256 exceeds it
        p.kernels[0].schedule.block_tile = Some((256, 96, 32));
        let spec = crate::gpusim::GpuSpec::a100();
        let diags = verify(&p, &g, &s, &spec);
        assert!(rules(&diags).contains(&Rule::TileExceedsExtent));
        assert!(rules(&diags).contains(&Rule::TileRemainder));
        assert!(!has_errors(&diags));
        assert!(is_statically_legal(&p, &g, &s, &spec));
    }

    #[test]
    fn zero_tile_is_an_error() {
        let (g, s) = gemm_relu();
        let mut p = lower_naive(&g);
        p.kernels[0].schedule.block_tile = Some((0, 64, 32));
        let diags = verify(&p, &g, &s, &crate::gpusim::GpuSpec::a100());
        assert!(rules(&diags).contains(&Rule::TileZero));
        assert!(has_errors(&diags));
    }

    #[test]
    fn vector_on_naive_order_is_an_error() {
        let (g, s) = gemm_relu();
        let mut p = lower_naive(&g);
        p.kernels[1].schedule.vector_width = 4;
        let diags = verify(&p, &g, &s, &crate::gpusim::GpuSpec::a100());
        assert_eq!(rules(&diags), vec![Rule::VectorOrder]);
        assert!(has_errors(&diags));
    }

    #[test]
    fn vector_width_must_be_pow2_le8() {
        let (g, s) = gemm_relu();
        let mut p = lower_naive(&g);
        p.kernels[1].schedule.vector_width = 3;
        let diags = verify(&p, &g, &s, &crate::gpusim::GpuSpec::a100());
        assert_eq!(rules(&diags), vec![Rule::VectorWidth]);
    }

    #[test]
    fn vector_vs_odd_extent_warns() {
        let mut g = Graph::new("t");
        let x = g.input("x", &[4, 9]);
        let r = g.op(Op::Relu, &[x]);
        g.mark_output(r);
        let s = infer_shapes(&g);
        let mut p = lower_naive(&g);
        p.kernels[0].schedule.loop_order = crate::kir::LoopOrder::Coalesced;
        p.kernels[0].schedule.vector_width = 2;
        let diags = verify(&p, &g, &s, &crate::gpusim::GpuSpec::a100());
        assert_eq!(rules(&diags), vec![Rule::VectorExtent]);
        assert!(!has_errors(&diags));
    }

    #[test]
    fn reorder_role_mismatches_warn() {
        let (g, s) = gemm_relu();
        let mut p = lower_naive(&g);
        p.kernels[0].schedule.loop_order = crate::kir::LoopOrder::Blocked;
        p.kernels[1].schedule.loop_order = crate::kir::LoopOrder::Coalesced;
        p.kernels[1].schedule.block_tile = Some((64, 64, 1));
        let diags = verify(&p, &g, &s, &crate::gpusim::GpuSpec::a100());
        assert_eq!(
            rules(&diags),
            vec![Rule::ReorderRole, Rule::ReorderRole]
        );
        assert!(!has_errors(&diags));
    }

    #[test]
    fn deep_pipeline_on_volta_is_an_error() {
        let (g, s) = gemm_relu();
        let mut p = lower_naive(&g);
        p.kernels[0].schedule.block_tile = Some((64, 64, 16));
        p.kernels[0].schedule.pipeline_depth = 3;
        let v100 = crate::gpusim::GpuSpec::v100();
        assert!(!v100.supports_async_copy());
        let diags = verify(&p, &g, &s, &v100);
        assert!(rules(&diags).contains(&Rule::PipelineStaging));
        assert!(has_errors(&diags));
        // same depth is fine on Ampere
        let diags = verify(&p, &g, &s, &crate::gpusim::GpuSpec::a100());
        assert!(!has_errors(&diags), "{diags:?}");
    }

    #[test]
    fn smem_over_budget_is_an_error() {
        let (g, s) = gemm_relu();
        let mut p = lower_naive(&g);
        // (512*128 + 128*512) * 4 * 4 = 2 MiB — over every spec
        p.kernels[0].schedule.block_tile = Some((512, 512, 128));
        p.kernels[0].schedule.pipeline_depth = 4;
        let diags = verify(&p, &g, &s, &crate::gpusim::GpuSpec::h100());
        assert!(rules(&diags).contains(&Rule::SmemBudget));
        assert!(has_errors(&diags));
    }

    #[test]
    fn register_tile_over_budget_is_an_error() {
        let (g, s) = gemm_relu();
        let mut p = lower_naive(&g);
        p.kernels[0].schedule.block_tile = Some((64, 64, 16));
        p.kernels[0].schedule.reg_tile = Some((16, 16));
        let diags = verify(&p, &g, &s, &crate::gpusim::GpuSpec::a100());
        assert!(rules(&diags).contains(&Rule::RegBudget));
        assert!(has_errors(&diags));
        // the largest tile the transform menu hands out stays legal
        p.kernels[0].schedule.reg_tile = Some((8, 8));
        assert!(is_statically_legal(&p, &g, &s, &crate::gpusim::GpuSpec::a100()));
    }

    #[test]
    fn two_fused_contractions_race() {
        let mut g = Graph::new("t");
        let x = g.input("x", &[64, 64]);
        let w1 = g.weight("w1", &[64, 64]);
        let w2 = g.weight("w2", &[64, 64]);
        let mm1 = g.op(Op::MatMul, &[x, w1]);
        let mm2 = g.op(Op::MatMul, &[mm1, w2]);
        g.mark_output(mm2);
        let s = infer_shapes(&g);
        let p = Program {
            kernels: vec![Kernel {
                nodes: vec![mm1, mm2],
                schedule: Schedule::default(),
                name: "fused".into(),
            }],
            mutations: Vec::new(),
            compile_broken: false,
        };
        let diags = verify(&p, &g, &s, &crate::gpusim::GpuSpec::a100());
        assert!(rules(&diags).contains(&Rule::RaceOverlap));
        assert!(has_errors(&diags));
    }

    #[test]
    fn non_epilogue_reduction_off_anchor_races() {
        let mut g = Graph::new("t");
        let x = g.input("x", &[64, 64]);
        let w = g.weight("w", &[64, 64]);
        let mm = g.op(Op::MatMul, &[x, w]);
        let cs = g.op(Op::CumSum, &[mm]);
        g.mark_output(cs);
        let s = infer_shapes(&g);
        let p = Program {
            kernels: vec![Kernel {
                nodes: vec![mm, cs],
                schedule: Schedule::default(),
                name: "fused".into(),
            }],
            mutations: Vec::new(),
            compile_broken: false,
        };
        let diags = verify(&p, &g, &s, &crate::gpusim::GpuSpec::a100());
        assert!(rules(&diags).contains(&Rule::RaceOverlap));
        assert!(has_errors(&diags));
    }

    #[test]
    fn split_epilogue_reduction_warns() {
        let mut g = Graph::new("t");
        let x = g.input("x", &[128, 128]);
        let w = g.weight("w", &[128, 128]);
        let mm = g.op(Op::MatMul, &[x, w]);
        let sm = g.op(Op::Softmax, &[mm]);
        g.mark_output(sm);
        let s = infer_shapes(&g);
        let p = Program {
            kernels: vec![Kernel {
                nodes: vec![mm, sm],
                schedule: Schedule {
                    block_tile: Some((128, 64, 32)),
                    ..Default::default()
                },
                name: "fused".into(),
            }],
            mutations: Vec::new(),
            compile_broken: false,
        };
        let diags = verify(&p, &g, &s, &crate::gpusim::GpuSpec::a100());
        assert!(rules(&diags).contains(&Rule::RaceSplitReduction));
        assert!(!has_errors(&diags));
        // a block tile covering the whole reduced axis is silent
        let mut p2 = p.clone();
        p2.kernels[0].schedule.block_tile = Some((128, 128, 32));
        let diags = verify(&p2, &g, &s, &crate::gpusim::GpuSpec::a100());
        assert!(!rules(&diags).contains(&Rule::RaceSplitReduction));
    }

    /// The warm-start screen must be a *subset* of the full verifier:
    /// any program the full verifier accepts (against its real graph,
    /// shapes and spec) must pass the graph-free intrinsic check too —
    /// otherwise warm start would drop legitimately cached programs.
    #[test]
    fn intrinsic_is_a_subset_of_full_verify() {
        let (g, s) = gemm_relu();
        let spec = crate::gpusim::GpuSpec::a100();
        let mut variants = vec![lower_naive(&g)];
        let mut tiled = lower_naive(&g);
        tiled.kernels[0].schedule.block_tile = Some((64, 64, 32));
        tiled.kernels[0].schedule.reg_tile = Some((8, 8));
        tiled.kernels[0].schedule.pipeline_depth = 2;
        tiled.kernels[0].schedule.loop_order = crate::kir::LoopOrder::Blocked;
        tiled.kernels[0].schedule.vector_width = 4;
        variants.push(tiled);
        for p in &variants {
            assert!(is_statically_legal(p, &g, &s, &spec));
            assert!(is_intrinsically_legal(p), "{:?}", verify_intrinsic(p));
        }
    }

    #[test]
    fn intrinsic_rejects_structural_and_schedule_damage() {
        let (g, _) = gemm_relu();
        let base = lower_naive(&g);
        assert!(is_intrinsically_legal(&base));

        let mut p = base.clone();
        p.compile_broken = true;
        assert!(!is_intrinsically_legal(&p));

        let mut p = base.clone();
        p.kernels[0].schedule.vector_width = 4; // naive order
        assert!(!is_intrinsically_legal(&p));

        let mut p = base.clone();
        p.kernels[0].schedule.block_tile = Some((0, 64, 32));
        assert!(!is_intrinsically_legal(&p));

        let mut p = base.clone();
        p.kernels[0].schedule.block_tile = Some((64, 64, 32));
        p.kernels[0].schedule.reg_tile = Some((16, 16));
        assert!(!is_intrinsically_legal(&p));

        let mut p = base.clone();
        p.kernels[0].schedule.pipeline_depth = 5;
        assert!(!is_intrinsically_legal(&p));

        let mut p = base;
        p.kernels[0].nodes.clear();
        assert!(!is_intrinsically_legal(&p));
    }

    #[test]
    fn gate_stats_count() {
        let gs = GateStats::new();
        gs.note_check();
        gs.note_check();
        gs.note_reject();
        assert_eq!(gs.checks(), 2);
        assert_eq!(gs.rejects(), 1);
    }
}
