//! Naive lowering: one kernel per non-input graph node, default schedule.
//! This is the "unoptimized reference code" MTMC starts from — what a
//! straightforward Triton translation of the PyTorch module looks like
//! before any optimization action is applied.

use super::ir::{Kernel, Program, Schedule};
use crate::graph::{Graph, Op};

/// Fallible lowering for untrusted graphs (e.g. `repro lint` sweeping a
/// corpus): validates the graph first and reports what is wrong instead
/// of letting downstream passes index past a malformed node list.
pub fn lower_checked(g: &Graph) -> Result<Program, String> {
    g.validate()
        .map_err(|e| format!("graph `{}` is malformed: {e}", g.name))?;
    let p = lower_naive(g);
    p.validate(g)
        .map_err(|e| format!("naive lowering of `{}` is invalid: {e}", g.name))?;
    Ok(p)
}

/// Lower a graph to the naive one-op-per-kernel program.
pub fn lower_naive(g: &Graph) -> Program {
    let mut kernels = Vec::new();
    for (id, node) in g.nodes.iter().enumerate() {
        if matches!(node.op, Op::Input) {
            continue;
        }
        kernels.push(Kernel {
            nodes: vec![id],
            schedule: Schedule::default(),
            name: format!("k{}_{}", kernels.len(), node.op.mnemonic()),
        });
    }
    Program { kernels, mutations: Vec::new(), compile_broken: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;

    #[test]
    fn naive_lowering_covers_graph() {
        let mut g = Graph::new("t");
        let x = g.input("x", &[4, 8]);
        let w = g.weight("w", &[8, 4]);
        let mm = g.op(Op::MatMul, &[x, w]);
        let r = g.op(Op::Relu, &[mm]);
        g.mark_output(r);
        let p = lower_naive(&g);
        assert_eq!(p.kernels.len(), 2);
        p.validate(&g).unwrap();
        assert_eq!(p.kernel_of(mm), Some(0));
        assert_eq!(p.kernel_of(r), Some(1));
        assert!(p.kernel_of(x).is_none());
    }

    #[test]
    fn naive_lowering_all_suites() {
        for t in crate::tasks::kernelbench_level(3).iter().take(10) {
            let p = lower_naive(&t.graph);
            p.validate(&t.graph).unwrap_or_else(|e| panic!("{}: {e}", t.id));
            assert_eq!(p.kernels.len(), t.graph.op_count());
        }
    }
}
