//! Kernel IR: the schedule-carrying representation the semantic actions
//! operate on. A [`Program`] partitions a task graph into fused
//! [`Kernel`]s, each carrying a [`Schedule`] (tiles, pipeline depth, loop
//! order, vector width). `regions` derives the candidate *code regions*
//! (paper §4.2: "determined based on the data flow and AST analysis") the
//! Macro-Thinking action space indexes into, `printer` renders
//! pseudo-Triton/CUDA text for inspection and the Table 5 language
//! ablation, and `verify` is the static legality tier — schedule/race
//! diagnostics consumed by `repro lint` and the pre-verif gate.

mod ir;
mod lower;
mod loops;
mod regions;
mod printer;
mod verify;

pub use ir::{Kernel, LoopOrder, Program, Schedule};
pub use loops::{loop_nest, Loop, LoopKind};
pub use lower::{lower_checked, lower_naive};
pub use printer::{render, TargetLang};
pub use regions::{analyze_regions, Region, RegionKind, MAX_REGIONS};
pub use verify::{
    has_errors, is_intrinsically_legal, is_statically_legal, verify,
    verify_intrinsic, Diagnostic, GateStats, Rule, Severity,
};
