//! Loop-nest derivation: the "AST" view of a kernel that region analysis
//! and the pretty-printer consume. The nest is derived from the anchor
//! op's iteration space plus the schedule's tiling decisions.

use super::ir::Kernel;
use crate::graph::{Graph, Op};

/// Role of a loop in the nest (drives reorder/vectorize validity and the
/// coalescing model).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoopKind {
    /// Parallel (grid) dimension.
    Parallel,
    /// Reduction dimension.
    Reduction,
    /// Spatial window (conv kernel window).
    Window,
}

/// One loop of the nest, outermost first.
#[derive(Clone, Debug, PartialEq)]
pub struct Loop {
    pub var: String,
    pub extent: usize,
    pub kind: LoopKind,
    /// Tile size if this loop has been split by the schedule.
    pub tile: Option<usize>,
}

/// Derive the loop nest of a kernel from its anchor op and schedule.
pub fn loop_nest(kernel: &Kernel, g: &Graph, shapes: &[Vec<usize>]) -> Vec<Loop> {
    let anchor = kernel.anchor(g);
    let node = &g.nodes[anchor];
    let out = &shapes[anchor];
    let (bt, _rt) = (kernel.schedule.block_tile, kernel.schedule.reg_tile);
    let mk = |var: &str, extent: usize, kind: LoopKind, tile: Option<usize>| Loop {
        var: var.to_string(),
        extent,
        kind,
        tile,
    };
    match &node.op {
        Op::MatMul => {
            let a = &shapes[node.inputs[0]];
            let b = &shapes[node.inputs[1]];
            vec![
                mk("m", a[0], LoopKind::Parallel, bt.map(|t| t.0)),
                mk("n", b[1], LoopKind::Parallel, bt.map(|t| t.1)),
                mk("k", a[1], LoopKind::Reduction, bt.map(|t| t.2)),
            ]
        }
        Op::BatchMatMul => {
            let a = &shapes[node.inputs[0]];
            let b = &shapes[node.inputs[1]];
            vec![
                mk("b", a[0], LoopKind::Parallel, None),
                mk("m", a[1], LoopKind::Parallel, bt.map(|t| t.0)),
                mk("n", b[2], LoopKind::Parallel, bt.map(|t| t.1)),
                mk("k", a[2], LoopKind::Reduction, bt.map(|t| t.2)),
            ]
        }
        Op::Conv2d { .. } => {
            let x = &shapes[node.inputs[0]];
            let w = &shapes[node.inputs[1]];
            vec![
                mk("n", out[0], LoopKind::Parallel, None),
                mk("f", out[1], LoopKind::Parallel, bt.map(|t| t.0)),
                mk("y", out[2], LoopKind::Parallel, bt.map(|t| t.1)),
                mk("x", out[3], LoopKind::Parallel, None),
                mk("c", x[1], LoopKind::Reduction, bt.map(|t| t.2)),
                mk("ky", w[2], LoopKind::Window, None),
                mk("kx", w[3], LoopKind::Window, None),
            ]
        }
        Op::Attention => {
            let q = &shapes[node.inputs[0]];
            let k = &shapes[node.inputs[1]];
            vec![
                mk("sq", q[0], LoopKind::Parallel, bt.map(|t| t.0)),
                mk("sk", k[0], LoopKind::Reduction, bt.map(|t| t.1)),
                mk("d", q[1], LoopKind::Reduction, bt.map(|t| t.2)),
            ]
        }
        Op::LstmCell => {
            let x = &shapes[node.inputs[0]];
            let h = &shapes[node.inputs[1]];
            vec![
                mk("b", x[0], LoopKind::Parallel, bt.map(|t| t.0)),
                mk("u", h[1] * 4, LoopKind::Parallel, bt.map(|t| t.1)),
                mk("k", x[1] + h[1], LoopKind::Reduction, bt.map(|t| t.2)),
            ]
        }
        // reductions / normalisations: rows parallel, last axis reduced
        Op::Softmax | Op::LayerNorm | Op::ReduceSum | Op::ReduceMax
        | Op::ReduceMean | Op::ArgMax | Op::CumSum => {
            let x = &shapes[node.inputs[0]];
            let rows: usize = x[..x.len() - 1].iter().product();
            vec![
                mk("row", rows.max(1), LoopKind::Parallel, bt.map(|t| t.0)),
                mk("col", *x.last().unwrap(), LoopKind::Reduction, bt.map(|t| t.1)),
            ]
        }
        Op::MaxPool2d { .. } | Op::GlobalAvgPool | Op::BatchNorm2d => {
            let x = &shapes[node.inputs[0]];
            vec![
                mk("nc", x[0] * x[1], LoopKind::Parallel, bt.map(|t| t.0)),
                mk("hw", x[2] * x[3], LoopKind::Reduction, bt.map(|t| t.1)),
            ]
        }
        // pure elementwise / movement: flat 2-level nest
        _ => {
            let n: usize = out.iter().product();
            let inner = out.last().copied().unwrap_or(1).max(1);
            vec![
                mk("i", (n / inner).max(1), LoopKind::Parallel, bt.map(|t| t.0)),
                mk("j", inner, LoopKind::Parallel, bt.map(|t| t.1)),
            ]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{infer_shapes, Graph};
    use crate::kir::{lower_naive, Schedule};

    #[test]
    fn matmul_nest_mnk() {
        let mut g = Graph::new("t");
        let x = g.input("x", &[32, 64]);
        let w = g.weight("w", &[64, 16]);
        let mm = g.op(Op::MatMul, &[x, w]);
        g.mark_output(mm);
        let shapes = infer_shapes(&g);
        let p = lower_naive(&g);
        let nest = loop_nest(&p.kernels[0], &g, &shapes);
        assert_eq!(nest.len(), 3);
        assert_eq!(nest[0].extent, 32);
        assert_eq!(nest[2].kind, LoopKind::Reduction);
        assert!(nest.iter().all(|l| l.tile.is_none()));
    }

    #[test]
    fn tiles_show_in_nest() {
        let mut g = Graph::new("t");
        let x = g.input("x", &[128, 128]);
        let w = g.weight("w", &[128, 128]);
        let mm = g.op(Op::MatMul, &[x, w]);
        g.mark_output(mm);
        let shapes = infer_shapes(&g);
        let mut p = lower_naive(&g);
        p.kernels[0].schedule = Schedule {
            block_tile: Some((64, 32, 16)),
            ..Default::default()
        };
        let nest = loop_nest(&p.kernels[0], &g, &shapes);
        assert_eq!(nest[0].tile, Some(64));
        assert_eq!(nest[1].tile, Some(32));
        assert_eq!(nest[2].tile, Some(16));
    }

    #[test]
    fn softmax_nest_rows_cols() {
        let mut g = Graph::new("t");
        let x = g.input("x", &[4, 7, 9]);
        let s = g.op(Op::Softmax, &[x]);
        g.mark_output(s);
        let shapes = infer_shapes(&g);
        let p = lower_naive(&g);
        let nest = loop_nest(&p.kernels[0], &g, &shapes);
        assert_eq!(nest[0].extent, 28);
        assert_eq!(nest[1].extent, 9);
        assert_eq!(nest[1].kind, LoopKind::Reduction);
    }

    #[test]
    fn conv_nest_has_window_loops() {
        let mut g = Graph::new("t");
        let x = g.input("x", &[1, 3, 8, 8]);
        let w = g.weight("w", &[4, 3, 3, 3]);
        let c = g.op(Op::Conv2d { stride: 1, pad: 1 }, &[x, w]);
        g.mark_output(c);
        let shapes = infer_shapes(&g);
        let p = lower_naive(&g);
        let nest = loop_nest(&p.kernels[0], &g, &shapes);
        assert_eq!(nest.iter().filter(|l| l.kind == LoopKind::Window).count(), 2);
    }
}
