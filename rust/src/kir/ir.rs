//! Core IR types: Schedule, Kernel, Program.

use crate::graph::{Graph, Mutation, NodeId};

/// Loop ordering of a kernel's iteration space — the Reorder action's
/// target. Affects memory coalescing in the cost model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoopOrder {
    /// Straight-from-reference order: innermost loop strides the *outer*
    /// tensor axis (row-major hostile). What naive generated code does.
    Naive,
    /// Innermost loop walks contiguous memory — fully coalesced.
    Coalesced,
    /// Block-contiguous (tile-major) order: coalesced within tiles,
    /// strided across; the usual order after tiling.
    Blocked,
}

/// Per-kernel schedule state. `Default` = the naive schedule produced by
/// `lower_naive` (no tiles, no pipeline, naive order, scalar accesses).
#[derive(Clone, Debug, PartialEq)]
pub struct Schedule {
    /// Shared-memory block tile (M, N, K) for contraction kernels, or
    /// (rows, cols, 1) for reduction/elementwise kernels.
    pub block_tile: Option<(usize, usize, usize)>,
    /// Register sub-tile (m, n) under the block tile.
    pub reg_tile: Option<(usize, usize)>,
    /// Software pipeline stages: 1 = none, 2 = double buffer, >=3 = async
    /// multi-stage (cp.async-style).
    pub pipeline_depth: usize,
    pub loop_order: LoopOrder,
    /// Vectorized access width in elements (1, 2, 4, 8).
    pub vector_width: usize,
}

impl Default for Schedule {
    fn default() -> Self {
        Schedule {
            block_tile: None,
            reg_tile: None,
            pipeline_depth: 1,
            loop_order: LoopOrder::Naive,
            vector_width: 1,
        }
    }
}

impl Schedule {
    /// Shared memory bytes per block implied by this schedule (f32).
    /// Operand staging buffers times the pipeline multiplicity.
    pub fn smem_bytes(&self) -> usize {
        match self.block_tile {
            None => 0,
            Some((m, n, k)) => {
                let operands = m * k + k * n;
                operands * 4 * self.pipeline_depth.max(1)
            }
        }
    }

    /// A summary score in [0, ~5] of how "scheduled" this kernel is —
    /// used by the observation featurizer.
    pub fn sophistication(&self) -> f32 {
        let mut s = 0.0;
        if self.block_tile.is_some() {
            s += 1.0;
        }
        if self.reg_tile.is_some() {
            s += 1.0;
        }
        s += (self.pipeline_depth.saturating_sub(1) as f32).min(2.0) * 0.5;
        if self.loop_order != LoopOrder::Naive {
            s += 1.0;
        }
        if self.vector_width > 1 {
            s += 0.5;
        }
        s
    }
}

/// One fused kernel: a contiguous-in-topo-order group of graph nodes plus
/// its schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct Kernel {
    pub nodes: Vec<NodeId>,
    pub schedule: Schedule,
    pub name: String,
}

impl Kernel {
    /// The "anchor" node: the most expensive op in the group (contraction
    /// if present, else the first reduction, else the first node). Tiling
    /// decisions key off its iteration space.
    pub fn anchor(&self, g: &Graph) -> NodeId {
        use crate::graph::OpClass;
        for &n in &self.nodes {
            if g.nodes[n].op.class() == OpClass::Contraction {
                return n;
            }
        }
        for &n in &self.nodes {
            if g.nodes[n].op.class() == OpClass::Reduction {
                return n;
            }
        }
        self.nodes[0]
    }
}

/// A full scheduled program for one task graph, plus the semantic bugs the
/// micro-coder has introduced so far (executed by the verif run).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Program {
    pub kernels: Vec<Kernel>,
    /// Injected semantic bugs (empty for a correct program).
    pub mutations: Vec<Mutation>,
    /// True if the last micro-coding step produced code that does not
    /// compile — the program is unusable until regenerated.
    pub compile_broken: bool,
}

impl Program {
    /// Which kernel computes a given node, if any.
    pub fn kernel_of(&self, node: NodeId) -> Option<usize> {
        self.kernels
            .iter()
            .position(|k| k.nodes.contains(&node))
    }

    /// Invariants: every non-input node in exactly one kernel; kernels
    /// internally topo-ordered; no empty kernels. Used by property tests
    /// after every transform.
    pub fn validate(&self, g: &Graph) -> Result<(), String> {
        let mut seen = vec![0usize; g.nodes.len()];
        for (ki, k) in self.kernels.iter().enumerate() {
            if k.nodes.is_empty() {
                return Err(format!("kernel {ki} is empty"));
            }
            for w in k.nodes.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("kernel {ki} nodes not topo-sorted"));
                }
            }
            for &n in &k.nodes {
                if matches!(g.nodes[n].op, crate::graph::Op::Input) {
                    return Err(format!("kernel {ki} contains input node {n}"));
                }
                seen[n] += 1;
            }
            if k.schedule.pipeline_depth > 1 && k.schedule.block_tile.is_none() {
                return Err(format!(
                    "kernel {ki} pipelined without block tile (nothing to stage)"
                ));
            }
        }
        for (n, node) in g.nodes.iter().enumerate() {
            let is_input = matches!(node.op, crate::graph::Op::Input);
            if is_input && seen[n] != 0 {
                return Err(format!("input node {n} assigned to a kernel"));
            }
            if !is_input && seen[n] != 1 {
                return Err(format!(
                    "node {n} ({}) covered {} times",
                    node.name, seen[n]
                ));
            }
        }
        // kernel execution order must respect cross-kernel dataflow
        let mut kernel_idx = vec![usize::MAX; g.nodes.len()];
        for (ki, k) in self.kernels.iter().enumerate() {
            for &n in &k.nodes {
                kernel_idx[n] = ki;
            }
        }
        for (ki, k) in self.kernels.iter().enumerate() {
            for &n in &k.nodes {
                for &inp in &g.nodes[n].inputs {
                    let pi = kernel_idx[inp];
                    if pi != usize::MAX && pi > ki {
                        return Err(format!(
                            "kernel {ki} consumes node {inp} from later kernel {pi}"
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Mean schedule sophistication across kernels (featurizer input).
    pub fn mean_sophistication(&self) -> f32 {
        if self.kernels.is_empty() {
            return 0.0;
        }
        self.kernels
            .iter()
            .map(|k| k.schedule.sophistication())
            .sum::<f32>()
            / self.kernels.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_schedule_is_naive() {
        let s = Schedule::default();
        assert_eq!(s.pipeline_depth, 1);
        assert_eq!(s.loop_order, LoopOrder::Naive);
        assert_eq!(s.smem_bytes(), 0);
        assert_eq!(s.sophistication(), 0.0);
    }

    #[test]
    fn smem_scales_with_pipeline() {
        let mut s = Schedule::default();
        s.block_tile = Some((64, 64, 32));
        let single = s.smem_bytes();
        s.pipeline_depth = 2;
        assert_eq!(s.smem_bytes(), 2 * single);
        assert_eq!(single, (64 * 32 + 32 * 64) * 4);
    }

    #[test]
    fn sophistication_monotone() {
        let mut s = Schedule::default();
        let s0 = s.sophistication();
        s.block_tile = Some((64, 64, 32));
        let s1 = s.sophistication();
        s.pipeline_depth = 2;
        let s2 = s.sophistication();
        s.loop_order = LoopOrder::Blocked;
        let s3 = s.sophistication();
        assert!(s0 < s1 && s1 < s2 && s2 < s3);
    }
}
