//! The PPO loop: rollout → GAE → fixed-size minibatches → `train_step`
//! artifact → repeat.

use super::gae::{compute_gae, normalize};
use crate::engine::Session;
use crate::env::{EnvConfig, TreeEnv};
use crate::gpusim::GpuSpec;
use crate::microcode::{LlmProfile, ProfileId};
use crate::runtime::{PjrtRuntime, TrainState};
use crate::runtime::TrainBatch;
use crate::tasks::Task;
use crate::transform::ACTION_DIM;
use crate::util::Rng;
use anyhow::Result;

/// PPO hyperparameters (the gradient-side ones are baked into the
/// artifact; these are the rollout-side ones).
#[derive(Clone, Debug)]
pub struct PpoCfg {
    pub iterations: usize,
    /// PPO epochs over each collected batch.
    pub epochs: usize,
    pub gamma: f64,
    pub lam: f64,
    pub env: EnvConfig,
    pub seed: u64,
    /// Micro-coding profile used during training rollouts.
    pub profile: ProfileId,
    pub log_every: usize,
    /// Batch policy inference across parallel episodes through the B=64
    /// artifact (§Perf L3 optimization: amortizes PJRT dispatch, ~0.25 ms
    /// per call, across `eval_batch` steps).
    pub batched_rollouts: bool,
}

impl Default for PpoCfg {
    fn default() -> Self {
        PpoCfg {
            iterations: 60,
            epochs: 2,
            gamma: 0.99,
            lam: 0.95,
            env: EnvConfig::default(),
            seed: 0x9902,
            profile: ProfileId::GeminiFlash25,
            log_every: 5,
            batched_rollouts: true,
        }
    }
}

/// Per-iteration training log row.
#[derive(Clone, Debug)]
pub struct IterLog {
    pub iter: usize,
    pub mean_episode_reward: f64,
    pub mean_final_speedup: f64,
    pub loss: f32,
    pub pg_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
    pub grad_norm: f32,
    pub cache_hit_rate: f64,
}

struct Buffer {
    obs: Vec<f32>,
    mask: Vec<f32>,
    act: Vec<i32>,
    logp: Vec<f32>,
    value: Vec<f32>,
    reward: Vec<f64>,
    done: Vec<bool>,
}

impl Buffer {
    fn new() -> Buffer {
        Buffer {
            obs: vec![], mask: vec![], act: vec![], logp: vec![],
            value: vec![], reward: vec![], done: vec![],
        }
    }
    fn len(&self) -> usize {
        self.act.len()
    }
}

/// Train the policy in `state` over `tasks`; returns the per-iteration
/// log. Rollouts use sampled decoding through the B=1 artifact; updates
/// run the fused train_step at the artifact's fixed batch size. The
/// [`Session`] carries the run's memo trio — analysis/cost caches shared
/// by every tree, and (when enabled) one shared [`crate::env::EdgeMemo`]
/// pooling transitions across trees and, via `--memo-store`, across runs.
/// Edge replay is bit-identical to live stepping either way.
pub fn train_ppo(
    rt: &PjrtRuntime,
    state: &mut TrainState,
    tasks: &[Task],
    spec: &GpuSpec,
    cfg: &PpoCfg,
    session: &Session,
) -> Result<Vec<IterLog>> {
    assert_eq!(rt.meta.act_dim, ACTION_DIM, "artifact/action-space mismatch");
    let batch_size = rt.meta.train_batch;
    let obs_dim = rt.meta.obs_dim;
    let mut rng = Rng::new(cfg.seed);
    let mut logs = Vec::new();

    // one warm tree per task, reused across iterations; the trees share
    // the session's analysis/cost caches for the whole run, so replayed
    // visits skip micro-coding (EdgeMemo) *and* masks/observations stop
    // re-walking and re-pricing programs (bit-identical either way)
    let mut envs: Vec<TreeEnv> = tasks
        .iter()
        .enumerate()
        .map(|(i, t)| {
            TreeEnv::with_session(
                t,
                spec.clone(),
                LlmProfile::get(cfg.profile),
                cfg.env.clone(),
                cfg.seed ^ ((i as u64) << 32),
                session,
            )
        })
        .collect();

    for iter in 0..cfg.iterations {
        let mut buf = Buffer::new();
        let mut ep_rewards = Vec::new();
        let mut ep_speedups = Vec::new();
        // collect at least one full train batch
        while buf.len() < batch_size {
            if cfg.batched_rollouts {
                rollout_wave(rt, state, &mut envs, &mut rng, &mut buf,
                             &mut ep_rewards, &mut ep_speedups, obs_dim)?;
            } else {
                rollout_single(rt, state, &mut envs, &mut rng, &mut buf,
                               &mut ep_rewards, &mut ep_speedups)?;
            }
        }

        let (mut adv, ret) =
            compute_gae(&buf.reward, &buf.value, &buf.done, cfg.gamma, cfg.lam);
        normalize(&mut adv);

        // assemble fixed-size minibatches (shuffled; remainder padded by
        // resampling — the artifact batch is static)
        let n = buf.len();
        let mut order: Vec<usize> = (0..n).collect();
        let mut metrics = vec![0f32; 6];
        for _epoch in 0..cfg.epochs {
            rng.shuffle(&mut order);
            for chunk_start in (0..n).step_by(batch_size) {
                let idx: Vec<usize> = (0..batch_size)
                    .map(|k| order[(chunk_start + k) % n])
                    .collect();
                let mut obs = Vec::with_capacity(batch_size * obs_dim);
                let mut mask = Vec::with_capacity(batch_size * ACTION_DIM);
                let mut act = Vec::with_capacity(batch_size);
                let mut old_logp = Vec::with_capacity(batch_size);
                let mut badv = Vec::with_capacity(batch_size);
                let mut bret = Vec::with_capacity(batch_size);
                for &i in &idx {
                    obs.extend_from_slice(&buf.obs[i * obs_dim..(i + 1) * obs_dim]);
                    mask.extend_from_slice(
                        &buf.mask[i * ACTION_DIM..(i + 1) * ACTION_DIM],
                    );
                    act.push(buf.act[i]);
                    old_logp.push(buf.logp[i]);
                    badv.push(adv[i]);
                    bret.push(ret[i]);
                }
                metrics = rt.train_step(
                    state,
                    &TrainBatch {
                        obs: &obs,
                        mask: &mask,
                        act: &act,
                        old_logp: &old_logp,
                        adv: &badv,
                        ret: &bret,
                    },
                )?;
            }
        }

        let (hits, misses) = envs.iter().fold((0, 0), |acc, e| {
            let (h, m) = e.stats();
            (acc.0 + h, acc.1 + m)
        });
        let log = IterLog {
            iter,
            mean_episode_reward: ep_rewards.iter().sum::<f64>()
                / ep_rewards.len().max(1) as f64,
            mean_final_speedup: ep_speedups.iter().sum::<f64>()
                / ep_speedups.len().max(1) as f64,
            loss: metrics[0],
            pg_loss: metrics[1],
            v_loss: metrics[2],
            entropy: metrics[3],
            approx_kl: metrics[4],
            grad_norm: metrics[5],
            cache_hit_rate: hits as f64 / (hits + misses).max(1) as f64,
        };
        if iter % cfg.log_every == 0 || iter + 1 == cfg.iterations {
            eprintln!(
                "[ppo] iter {:>3} reward {:+.3} speedup {:.2}x loss {:+.4} \
                 ent {:.3} kl {:+.4} cache {:.0}%",
                log.iter,
                log.mean_episode_reward,
                log.mean_final_speedup,
                log.loss,
                log.entropy,
                log.approx_kl,
                log.cache_hit_rate * 100.0
            );
        }
        logs.push(log);
    }
    Ok(logs)
}

/// One sequential episode through the B=1 artifact (reference path; also
/// used when the task pool is tiny).
fn rollout_single(
    rt: &PjrtRuntime,
    state: &TrainState,
    envs: &mut [TreeEnv],
    rng: &mut Rng,
    buf: &mut Buffer,
    ep_rewards: &mut Vec<f64>,
    ep_speedups: &mut Vec<f64>,
) -> Result<()> {
    let ei = rng.below(envs.len());
    let env = &mut envs[ei];
    env.reset();
    let mut ep_reward = 0.0;
    while !env.env.state.done {
        let mask = env.env.mask();
        let obs = env.env.observe(&mask);
        let mask_f: Vec<f32> =
            mask.iter().map(|&m| if m { 1.0 } else { 0.0 }).collect();
        let (logp, value) = rt.fwd_b1(&state.params, &obs, &mask_f)?;
        let action = rng.categorical_logp(&logp);
        let r = env.step(action);
        ep_reward += r.reward;
        buf.obs.extend_from_slice(&obs);
        buf.mask.extend_from_slice(&mask_f);
        buf.act.push(action as i32);
        buf.logp.push(logp[action]);
        buf.value.push(value);
        buf.reward.push(r.reward);
        buf.done.push(env.env.state.done);
    }
    ep_rewards.push(ep_reward);
    ep_speedups.push(env.env.state.best_speedup);
    Ok(())
}

/// A wave of up to `eval_batch` episodes stepped in lockstep through the
/// batched forward artifact. Episodes are flushed to the buffer whole
/// (GAE requires episode-contiguous layout).
#[allow(clippy::too_many_arguments)]
fn rollout_wave(
    rt: &PjrtRuntime,
    state: &TrainState,
    envs: &mut [TreeEnv],
    rng: &mut Rng,
    buf: &mut Buffer,
    ep_rewards: &mut Vec<f64>,
    ep_speedups: &mut Vec<f64>,
    obs_dim: usize,
) -> Result<()> {
    let b = rt.meta.eval_batch;
    let act_dim = rt.meta.act_dim;
    let p = b.min(envs.len());
    // distinct envs per wave (a TreeEnv holds one episode at a time)
    let mut order: Vec<usize> = (0..envs.len()).collect();
    rng.shuffle(&mut order);
    let slots: Vec<usize> = order[..p].to_vec();
    for &ei in &slots {
        envs[ei].reset();
    }
    // per-slot episode accumulators
    let mut ep: Vec<Buffer> = (0..p).map(|_| Buffer::new()).collect();
    let mut ep_reward = vec![0.0f64; p];

    let mut obs_mat = vec![0.0f32; b * obs_dim];
    let mut mask_mat = vec![0.0f32; b * act_dim];
    loop {
        let mut any_active = false;
        for (si, &ei) in slots.iter().enumerate() {
            let row_o = &mut obs_mat[si * obs_dim..(si + 1) * obs_dim];
            let row_m = &mut mask_mat[si * act_dim..(si + 1) * act_dim];
            if envs[ei].env.state.done {
                row_o.fill(0.0);
                row_m.fill(1.0); // padding row: any valid distribution
                continue;
            }
            any_active = true;
            let mask = envs[ei].env.mask();
            let obs = envs[ei].env.observe(&mask);
            row_o.copy_from_slice(&obs);
            for (j, &m) in mask.iter().enumerate() {
                row_m[j] = if m { 1.0 } else { 0.0 };
            }
        }
        if !any_active {
            break;
        }
        // padding rows beyond p: all-valid masks, zero obs
        for row in p..b {
            mask_mat[row * act_dim..(row + 1) * act_dim].fill(1.0);
        }
        let (logp_all, value_all) =
            rt.fwd_batch(&state.params, &obs_mat, &mask_mat)?;
        for (si, &ei) in slots.iter().enumerate() {
            if envs[ei].env.state.done {
                continue;
            }
            let logp = &logp_all[si * act_dim..(si + 1) * act_dim];
            let action = rng.categorical_logp(logp);
            let e = &mut ep[si];
            e.obs.extend_from_slice(&obs_mat[si * obs_dim..(si + 1) * obs_dim]);
            e.mask.extend_from_slice(&mask_mat[si * act_dim..(si + 1) * act_dim]);
            e.act.push(action as i32);
            e.logp.push(logp[action]);
            e.value.push(value_all[si]);
            let r = envs[ei].step(action);
            ep_reward[si] += r.reward;
            e.reward.push(r.reward);
            e.done.push(envs[ei].env.state.done);
        }
    }
    // flush whole episodes, preserving per-episode contiguity for GAE
    for (si, &ei) in slots.iter().enumerate() {
        let e = &ep[si];
        buf.obs.extend_from_slice(&e.obs);
        buf.mask.extend_from_slice(&e.mask);
        buf.act.extend_from_slice(&e.act);
        buf.logp.extend_from_slice(&e.logp);
        buf.value.extend_from_slice(&e.value);
        buf.reward.extend_from_slice(&e.reward);
        buf.done.extend_from_slice(&e.done);
        ep_rewards.push(ep_reward[si]);
        ep_speedups.push(envs[ei].env.state.best_speedup);
    }
    Ok(())
}

// End-to-end PPO coverage (needs artifacts) lives in
// rust/tests/runtime_pjrt.rs and examples/end_to_end.rs.
