//! PPO training orchestrator (paper §4.2 "Training Methodology"): the
//! TWOSOME-style action-likelihood policy is optimized with PPO + GAE on
//! the tree-structured offline environment. The heavy math (loss, grads,
//! Adam) runs in the AOT-compiled `train_step` artifact; rust owns
//! rollouts, advantage estimation, batching and logging.

mod gae;
mod ppo;

pub use gae::compute_gae;
pub use ppo::{train_ppo, IterLog, PpoCfg};
