//! Generalised Advantage Estimation over episode buffers.

/// Compute GAE advantages and returns for a flat buffer of transitions.
/// `dones[i]` marks the *last* step of an episode (no bootstrapping across
/// episode ends; terminal value is 0 — episodes always end via Stop).
pub fn compute_gae(rewards: &[f64], values: &[f32], dones: &[bool],
                   gamma: f64, lam: f64) -> (Vec<f32>, Vec<f32>) {
    let n = rewards.len();
    assert_eq!(values.len(), n);
    assert_eq!(dones.len(), n);
    let mut adv = vec![0f32; n];
    let mut ret = vec![0f32; n];
    let mut last_gae = 0f64;
    for i in (0..n).rev() {
        let (next_value, next_nonterminal) = if dones[i] {
            (0.0, 0.0)
        } else if i + 1 < n {
            (values[i + 1] as f64, 1.0)
        } else {
            // buffer truncated mid-episode: bootstrap with own value
            (values[i] as f64, 1.0)
        };
        let delta = rewards[i] + gamma * next_value * next_nonterminal
            - values[i] as f64;
        last_gae = delta + gamma * lam * next_nonterminal * last_gae;
        if dones[i] {
            last_gae = delta;
        }
        adv[i] = last_gae as f32;
        ret[i] = (last_gae + values[i] as f64) as f32;
    }
    (adv, ret)
}

/// In-place advantage normalisation (zero mean, unit std).
pub fn normalize(adv: &mut [f32]) {
    let n = adv.len().max(1) as f32;
    let mean: f32 = adv.iter().sum::<f32>() / n;
    let var: f32 = adv.iter().map(|a| (a - mean).powi(2)).sum::<f32>() / n;
    let inv = 1.0 / (var.sqrt() + 1e-8);
    for a in adv.iter_mut() {
        *a = (*a - mean) * inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_step_episode() {
        let (adv, ret) = compute_gae(&[1.0], &[0.25], &[true], 0.99, 0.95);
        // terminal: delta = r - v = 0.75
        assert!((adv[0] - 0.75).abs() < 1e-6);
        assert!((ret[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn no_leakage_across_episodes() {
        // two one-step episodes: second's reward must not affect first
        let (adv_a, _) = compute_gae(&[1.0, 100.0], &[0.0, 0.0],
                                     &[true, true], 0.99, 0.95);
        let (adv_b, _) = compute_gae(&[1.0, -100.0], &[0.0, 0.0],
                                     &[true, true], 0.99, 0.95);
        assert_eq!(adv_a[0], adv_b[0]);
    }

    #[test]
    fn discounting_accumulates() {
        let (adv, _) = compute_gae(&[0.0, 0.0, 1.0], &[0.0, 0.0, 0.0],
                                   &[false, false, true], 0.9, 1.0);
        assert!(adv[0] > 0.0 && adv[0] < adv[1] && adv[1] < adv[2]);
        assert!((adv[2] - 1.0).abs() < 1e-6);
        assert!((adv[1] - 0.9).abs() < 1e-6);
        assert!((adv[0] - 0.81).abs() < 1e-6);
    }

    #[test]
    fn normalize_zero_mean_unit_std() {
        let mut a = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        normalize(&mut a);
        let mean: f32 = a.iter().sum::<f32>() / 5.0;
        let var: f32 = a.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / 5.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-4);
    }
}
