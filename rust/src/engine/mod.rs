//! The search engine's shared-state layer.
//!
//! Scaling the MTMC pipeline hinges on a clean separation between the
//! search itself (envs, eval harness, PPO loop) and the evaluation state
//! those searches share (memo tiers, disk persistence, stats). This
//! module owns that state: [`Session`] is the one context object built
//! from CLI flags and passed by reference down every layer —
//! `main.rs` command handlers → `BatchRunner`/`evaluate_in`/
//! `evaluate_task` → `OptimEnv`/`TreeEnv` → `train_ppo`/
//! `dataset::generate`.

mod session;

pub use session::{
    FaultReport, Session, SessionBuilder, StatsRegistry, StoreReport,
};
