//! [`Session`]: one context object for the memo trio, the cache-policy
//! flags, the `--memo-store` persistence tier, and a unified stats
//! registry.
//!
//! Before the Session existed, the three memo tiers ([`CostCache`],
//! [`AnalysisCache`], [`EdgeMemo`]) plus the disk store were threaded
//! through dozens of ad-hoc touch points: `Option<&CostCache>` params on
//! eval entry points, `EnvCaches`/`with_caches` constructor variants,
//! `shared_edges` fields duplicated across `EvalCfg`/`PpoCfg`/
//! `DatasetCfg`, and warm-start/flush logic copy-pasted into five CLI
//! commands. A Session consolidates all of it:
//!
//! - **Ownership**: the Session owns whichever memos its policy flags
//!   enable. Presence *is* policy — `cost()` returning `None` means the
//!   cost tier is off, and every consumer falls through to the direct
//!   (cold) computation bit-identically.
//! - **Persistence**: `memo_store(path)` warm-starts the edge memo from
//!   disk at [`SessionBuilder::build`] and flushes it back on
//!   [`Session::finish`] (or on drop, as a safety net). The flush is a
//!   **compaction pass**: only live (non-evicted) entries are written,
//!   so a store can never grow past the memo's capacity. The store is a
//!   directory of per-shard segment files, so the flush is also a
//!   **dirty-skip pass** — clean shards are skipped untouched, and a
//!   corrupt segment at warm start costs only its own shard (see
//!   `env/memo_store.rs` and the per-segment counters in
//!   [`StoreReport`]).
//! - **Stats**: [`Session::stats`] snapshots every memo into one
//!   [`StatsRegistry`] — printable in the classic per-memo stderr format
//!   and serializable as one JSON object (`--stats-json`).
//!
//! Every memoized computation is pure or edge-deterministic, so outcomes
//! are bit-identical across all 8 on/off combinations (guarded by the
//! generative differential suite in `rust/tests/properties.rs`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::env::{
    flush_edge_memo_with, warm_start_edge_memo_with, EdgeMemo, FlushReport,
    WarmStartReport,
};
use crate::gpusim::{graph_fingerprint, program_fingerprint, CostCache,
                    MemoStats};
use crate::graph::Graph;
use crate::kir::{render, GateStats, Program, TargetLang};
use crate::transform::AnalysisCache;
use crate::util::faults::{FaultPlan, FaultSite, FaultStats};
use crate::util::json::Json;

/// Environment override for the edge memo's entry capacity (useful to
/// exercise eviction + store compaction from CI without a dedicated
/// flag). An explicit [`SessionBuilder::edge_capacity`] wins over it.
pub const MEMO_CAPACITY_ENV: &str = "QIMENG_MEMO_CAPACITY";

/// Shared evaluation state for one run: the memo trio, the cache-policy
/// flags (encoded as presence), the optional disk persistence tier, and
/// the stats registry. Build one from CLI flags via [`Session::builder`]
/// and pass it by reference down the stack; `&Session` is `Sync`, so a
/// whole batched sweep shares one through its work queue.
pub struct Session {
    cost: Option<CostCache>,
    analysis: Option<AnalysisCache>,
    edges: Option<Arc<EdgeMemo>>,
    /// Pre-verif static gate counters (`kir::verify`); `None` = gate off
    /// (`--no-static-gate`), and envs fall through to dynamic-only
    /// verification exactly as before the gate existed.
    gate: Option<Arc<GateStats>>,
    /// Render memo: `kir::render` is pure per (graph fp, program fp,
    /// dialect), so `--show-code` and golden tests share one rendering
    /// per distinct program.
    renders: Mutex<HashMap<(u64, u64, u8), Arc<String>>>,
    render_hits: AtomicUsize,
    render_misses: AtomicUsize,
    store: Option<PathBuf>,
    warm: WarmStartReport,
    persisted: AtomicUsize,
    seg_written: AtomicUsize,
    seg_skipped: AtomicUsize,
    finished: AtomicBool,
    /// Deterministic fault-injection schedule (`--inject-faults`);
    /// `None` = injection off, every site costs one branch.
    faults: Option<Arc<FaultPlan>>,
    /// What the retry loop and degradation paths actually did this run
    /// (always present; all-zero on a clean run).
    fault_stats: FaultStats,
}

impl Session {
    /// Start configuring a Session (all three memo tiers default to on,
    /// no persistence).
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// The pricing memo, when the cost tier is enabled.
    pub fn cost(&self) -> Option<&CostCache> {
        self.cost.as_ref()
    }

    /// The region-analysis / action-mask memo, when enabled.
    pub fn analysis(&self) -> Option<&AnalysisCache> {
        self.analysis.as_ref()
    }

    /// The transition transposition table, when enabled (`Arc`-shared so
    /// envs can hold it beyond the borrow).
    pub fn edges(&self) -> Option<&Arc<EdgeMemo>> {
        self.edges.as_ref()
    }

    /// The static-gate counters, when the pre-verif gate is enabled
    /// (`Arc`-shared so envs can hold them beyond the borrow).
    pub fn gate(&self) -> Option<&Arc<GateStats>> {
        self.gate.as_ref()
    }

    /// The fault-injection plan, when one is armed (`Arc`-shared so envs
    /// and sinks can hold it beyond the borrow).
    pub fn faults(&self) -> Option<&Arc<FaultPlan>> {
        self.faults.as_ref()
    }

    /// The run's fault-tolerance counters (always present; all-zero when
    /// nothing went wrong).
    pub fn fault_stats(&self) -> &FaultStats {
        &self.fault_stats
    }

    /// Render a program through the session's render memo. `kir::render`
    /// is a pure function of (program, graph, shapes, dialect), so
    /// identical programs render once per session; repeated `--show-code`
    /// paths and golden comparisons hit the cached string.
    pub fn render_cached(&self, p: &Program, g: &Graph,
                         shapes: &[Vec<usize>], lang: TargetLang)
                         -> Arc<String> {
        let key = (graph_fingerprint(g, shapes), program_fingerprint(p),
                   lang as u8);
        if let Some(hit) = self.renders.lock().unwrap().get(&key) {
            self.render_hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(hit);
        }
        // render outside the lock: renders are pure, so a racing miss on
        // the same key computes the same string and the insert is benign
        let text = Arc::new(render(p, g, shapes, lang));
        self.render_misses.fetch_add(1, Ordering::Relaxed);
        self.renders
            .lock()
            .unwrap()
            .entry(key)
            .or_insert_with(|| Arc::clone(&text))
            .clone()
    }

    /// The persistence-tier path, when configured (requires the edge
    /// memo: a store without a memo to fill has nothing to persist).
    pub fn store(&self) -> Option<&Path> {
        self.store.as_deref()
    }

    /// Edges warm-started from the store at construction.
    pub fn warm_loaded(&self) -> usize {
        self.warm.edges
    }

    /// The full warm-start report: edges plus per-segment recovery
    /// counters (how many segment files parsed, how many degraded).
    pub fn warm_report(&self) -> WarmStartReport {
        self.warm
    }

    /// Flush the edge memo back to the configured store. Idempotent (the
    /// first call wins; `Drop` re-invokes it as a safety net) and a
    /// no-op without a store. Returns the entry count persisted.
    ///
    /// This is the store-compaction pass: the memo's LRU keeps at most
    /// `capacity()` entries live, and the flush serializes exactly those
    /// — evicted entries are dropped from the store instead of
    /// accumulating across runs, so `persisted <= capacity` always. With
    /// the segmented store it is also the dirty-skip pass: only shards
    /// whose entry set changed since the warm start are rewritten, so a
    /// pure-replay run writes zero segments.
    pub fn finish(&self) -> usize {
        if self.finished.swap(true, Ordering::SeqCst) {
            return self.persisted.load(Ordering::SeqCst);
        }
        let report = match (&self.edges, &self.store) {
            (Some(memo), Some(path)) => {
                flush_edge_memo_with(memo, path, self.faults.as_deref())
            }
            _ => FlushReport::default(),
        };
        self.persisted.store(report.edges, Ordering::SeqCst);
        self.seg_written.store(report.written_segments, Ordering::SeqCst);
        self.seg_skipped.store(report.skipped_segments, Ordering::SeqCst);
        report.edges
    }

    /// Snapshot every memo's counters into one registry.
    pub fn stats(&self) -> StatsRegistry {
        StatsRegistry {
            cost: self.cost.as_ref().map(|c| c.full_stats()),
            analysis: self.analysis.as_ref().map(|a| a.stats()),
            edges: self.edges.as_ref().map(|e| e.stats()),
            static_gate: self
                .gate
                .as_ref()
                .map(|g| (g.checks(), g.rejects())),
            render_hits: self.render_hits.load(Ordering::Relaxed),
            render_misses: self.render_misses.load(Ordering::Relaxed),
            edge_len: self.edges.as_ref().map_or(0, |e| e.len()),
            edge_capacity: self.edges.as_ref().map_or(0, |e| e.capacity()),
            edge_disk_loaded: self
                .edges
                .as_ref()
                .map_or(0, |e| e.disk_loaded()),
            store: self.store.as_ref().map(|p| {
                let done = self.finished.load(Ordering::SeqCst);
                StoreReport {
                    path: p.clone(),
                    warm_loaded: self.warm.edges,
                    recovered_segments: self.warm.recovered_segments,
                    degraded_segments: self.warm.degraded_segments,
                    stale_rejected: self.warm.stale_rejected,
                    persisted: done
                        .then(|| self.persisted.load(Ordering::SeqCst)),
                    written_segments: done
                        .then(|| self.seg_written.load(Ordering::SeqCst)),
                    skipped_segments: done
                        .then(|| self.seg_skipped.load(Ordering::SeqCst)),
                }
            }),
            faults: FaultReport {
                enabled: self.faults.is_some(),
                panicked: self.fault_stats.panicked(),
                retried: self.fault_stats.retried(),
                recovered: self.fault_stats.recovered(),
                exhausted: self.fault_stats.exhausted(),
                sink_retries: self.fault_stats.sink_retries(),
                injected: match &self.faults {
                    Some(plan) => FaultSite::all()
                        .iter()
                        .map(|s| (s.name(), plan.injected(*s)))
                        .collect(),
                    None => Vec::new(),
                },
            },
        }
    }
}

impl Default for Session {
    /// All three memo tiers on, no persistence — the configuration every
    /// pre-Session caller defaulted to.
    fn default() -> Self {
        Session::builder().build()
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        // safety net: a handler that returns early (or `?`s out) still
        // persists what the run computed; finish() is idempotent
        self.finish();
    }
}

impl std::fmt::Debug for Session {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Session")
            .field("cost", &self.cost.is_some())
            .field("analysis", &self.analysis.is_some())
            .field("edges", &self.edges.is_some())
            .field("store", &self.store)
            .finish()
    }
}

/// Builder for [`Session`]. Flags map 1:1 to the CLI escape hatches
/// (`--no-cost-cache` / `--no-analysis-cache` / `--no-edge-memo` /
/// `--memo-store`).
#[derive(Clone, Debug)]
pub struct SessionBuilder {
    cost: bool,
    analysis: bool,
    edges: bool,
    gate: bool,
    store: Option<PathBuf>,
    edge_capacity: Option<usize>,
    faults: Option<Arc<FaultPlan>>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            cost: true,
            analysis: true,
            edges: true,
            gate: true,
            store: None,
            edge_capacity: None,
            faults: None,
        }
    }
}

impl SessionBuilder {
    /// Enable/disable the pricing memo ([`CostCache`]).
    pub fn cost_cache(mut self, on: bool) -> Self {
        self.cost = on;
        self
    }

    /// Enable/disable the region-analysis memo ([`AnalysisCache`]).
    pub fn analysis_cache(mut self, on: bool) -> Self {
        self.analysis = on;
        self
    }

    /// Enable/disable the transition memo ([`EdgeMemo`]).
    pub fn edge_memo(mut self, on: bool) -> Self {
        self.edges = on;
        self
    }

    /// Enable/disable the pre-verif static gate (`--no-static-gate`).
    /// The gate rejects statically-illegal candidates before dynamic
    /// verif trials; Error-severity rules are transform invariants, so
    /// outcomes are byte-identical either way (guarded by
    /// `rust/tests/verify.rs`) — only the trial count can differ.
    pub fn static_gate(mut self, on: bool) -> Self {
        self.gate = on;
        self
    }

    /// Persist the edge memo across runs: warm-start from `path` at
    /// build (missing store = silent cold start; a corrupt segment = a
    /// logged cold start of that shard only; a legacy single-file store
    /// is migrated to the segmented layout), flush back on
    /// [`Session::finish`]. Ignored when the edge memo is disabled.
    pub fn memo_store(mut self, path: Option<PathBuf>) -> Self {
        self.store = path;
        self
    }

    /// Bound the edge memo to `max_entries` (default 200k). Tiny
    /// capacities are legitimate — the differential tests run under
    /// eviction pressure to prove outcomes never depend on residency.
    pub fn edge_capacity(mut self, max_entries: usize) -> Self {
        self.edge_capacity = Some(max_entries);
        self
    }

    /// Arm a deterministic fault-injection plan (`--inject-faults` /
    /// `QIMENG_FAULT_SEED`). `None` (the default) keeps every injection
    /// site disabled.
    pub fn faults(mut self, plan: Option<FaultPlan>) -> Self {
        self.faults = plan.map(Arc::new);
        self
    }

    /// Build the Session: construct the enabled memos and warm-start the
    /// edge memo from the store (when both are configured).
    pub fn build(self) -> Session {
        let edges = self.edges.then(|| {
            let cap = self.edge_capacity.or_else(|| {
                std::env::var(MEMO_CAPACITY_ENV).ok()?.parse().ok()
            });
            Arc::new(match cap {
                Some(c) => EdgeMemo::with_capacity(c),
                None => EdgeMemo::new(),
            })
        });
        let store = if edges.is_some() { self.store } else { None };
        let warm = match (&edges, &store) {
            (Some(memo), Some(path)) => {
                warm_start_edge_memo_with(memo, path, self.faults.as_deref())
            }
            _ => WarmStartReport::default(),
        };
        Session {
            cost: self.cost.then(CostCache::new),
            analysis: self.analysis.then(AnalysisCache::new),
            edges,
            gate: self.gate.then(|| Arc::new(GateStats::new())),
            renders: Mutex::new(HashMap::new()),
            render_hits: AtomicUsize::new(0),
            render_misses: AtomicUsize::new(0),
            store,
            warm,
            persisted: AtomicUsize::new(0),
            seg_written: AtomicUsize::new(0),
            seg_skipped: AtomicUsize::new(0),
            finished: AtomicBool::new(false),
            faults: self.faults,
            fault_stats: FaultStats::new(),
        }
    }
}

/// Where a persisted store stands for one Session.
#[derive(Clone, Debug)]
pub struct StoreReport {
    pub path: PathBuf,
    /// Edges warm-started from the store at construction.
    pub warm_loaded: usize,
    /// Segment files that parsed cleanly at warm start (a legacy
    /// single-file store counts as 1).
    pub recovered_segments: usize,
    /// Segment files rejected as corrupt/truncated at warm start; each
    /// cost only its own shard (the others still loaded).
    pub degraded_segments: usize,
    /// Cached programs dropped at warm start because they are no longer
    /// statically legal under the current verifier (healed out of the
    /// store by the next flush).
    pub stale_rejected: usize,
    /// Edges written by [`Session::finish`]; `None` until it has run.
    pub persisted: Option<usize>,
    /// Segments rewritten by the flush (dirty shards only); `None`
    /// until [`Session::finish`] has run.
    pub written_segments: Option<usize>,
    /// Segments the flush skipped as clean; `None` until
    /// [`Session::finish`] has run.
    pub skipped_segments: Option<usize>,
}

/// One snapshot of every memo's traffic, taken via [`Session::stats`].
/// Disabled memos report `None` — physically absent, necessarily silent.
#[derive(Clone, Debug)]
pub struct StatsRegistry {
    pub cost: Option<MemoStats>,
    pub analysis: Option<MemoStats>,
    pub edges: Option<MemoStats>,
    /// `(checks, rejects)` of the pre-verif static gate; `None` when the
    /// gate is disabled.
    pub static_gate: Option<(usize, usize)>,
    /// Render-memo traffic (the memo itself is always present — renders
    /// are pure and the map is tiny).
    pub render_hits: usize,
    pub render_misses: usize,
    /// Live entry count of the edge memo (0 when disabled).
    pub edge_len: usize,
    /// Residency bound of the edge memo (0 when disabled) — the most a
    /// compacting flush can ever persist.
    pub edge_capacity: usize,
    /// Edges warm-started from a persisted store.
    pub edge_disk_loaded: usize,
    pub store: Option<StoreReport>,
    /// Fault-tolerance counters (always present; `enabled` says whether
    /// an injection plan was armed).
    pub faults: FaultReport,
}

/// Fault-tolerance snapshot for one run: what the sweep survived plus
/// what the injection plan fired, per site.
#[derive(Clone, Debug, Default)]
pub struct FaultReport {
    /// A [`FaultPlan`] was armed this run.
    pub enabled: bool,
    /// Units isolated after a non-transient panic.
    pub panicked: usize,
    /// Transient unit failures that were retried.
    pub retried: usize,
    /// Retried units that then completed cleanly.
    pub recovered: usize,
    /// Units that kept failing past the retry budget.
    pub exhausted: usize,
    /// Sink write attempts retried in place.
    pub sink_retries: usize,
    /// `(site name, fires)` per injection site; empty without a plan.
    pub injected: Vec<(&'static str, usize)>,
}

impl FaultReport {
    fn any(&self) -> bool {
        self.panicked + self.retried + self.recovered + self.exhausted
            + self.sink_retries
            > 0
    }

    fn injected_total(&self) -> usize {
        self.injected.iter().map(|(_, n)| n).sum()
    }
}

impl StatsRegistry {
    /// The classic per-memo stderr report (one line per *touched* memo,
    /// in the format the CLI has always printed — CI greps for the
    /// `disk hits` suffix).
    pub fn print(&self) {
        print_memo_line("cost-cache", &self.cost);
        print_memo_line("analysis-cache", &self.analysis);
        print_memo_line("edge-memo", &self.edges);
        if let Some((checks, rejects)) = self.static_gate {
            if checks > 0 {
                eprintln!(
                    "static-gate: {checks} candidates checked / {rejects} \
                     static rejects"
                );
            }
        }
        if self.render_hits + self.render_misses > 0 {
            eprintln!(
                "render-memo: {} hits / {} misses",
                self.render_hits, self.render_misses
            );
        }
        if self.faults.enabled || self.faults.any() {
            eprintln!(
                "faults: {} retried / {} recovered / {} exhausted / {} \
                 panicked / {} sink retries ({} injected)",
                self.faults.retried,
                self.faults.recovered,
                self.faults.exhausted,
                self.faults.panicked,
                self.faults.sink_retries,
                self.faults.injected_total()
            );
        }
    }

    /// The whole registry as one JSON object (the `--stats-json`
    /// payload): per-memo lookups/hits/misses/evictions/disk hits, plus
    /// edge-memo residency and persistence-tier info.
    pub fn to_json(&self) -> Json {
        let mut edge = memo_json(&self.edges);
        if let Json::Obj(m) = &mut edge {
            m.insert("len".into(), Json::from(self.edge_len));
            m.insert("capacity".into(), Json::from(self.edge_capacity));
            m.insert("disk_loaded".into(), Json::from(self.edge_disk_loaded));
        }
        let store = match &self.store {
            None => Json::Null,
            Some(s) => Json::obj(vec![
                ("path", Json::from(s.path.display().to_string())),
                ("warm_loaded", Json::from(s.warm_loaded)),
                ("recovered_segments", Json::from(s.recovered_segments)),
                ("degraded_segments", Json::from(s.degraded_segments)),
                ("stale_rejected", Json::from(s.stale_rejected)),
                ("persisted", opt_json(s.persisted)),
                ("written_segments", opt_json(s.written_segments)),
                ("skipped_segments", opt_json(s.skipped_segments)),
            ]),
        };
        let gate = match self.static_gate {
            None => Json::obj(vec![("enabled", Json::from(false))]),
            Some((checks, rejects)) => Json::obj(vec![
                ("enabled", Json::from(true)),
                ("checks", Json::from(checks)),
                ("static_rejects", Json::from(rejects)),
            ]),
        };
        let injected = Json::Obj(
            self.faults
                .injected
                .iter()
                .map(|(name, n)| ((*name).to_string(), Json::from(*n)))
                .collect(),
        );
        let faults = Json::obj(vec![
            ("enabled", Json::from(self.faults.enabled)),
            ("panicked", Json::from(self.faults.panicked)),
            ("retried", Json::from(self.faults.retried)),
            ("recovered", Json::from(self.faults.recovered)),
            ("exhausted", Json::from(self.faults.exhausted)),
            ("sink_retries", Json::from(self.faults.sink_retries)),
            ("injected", injected),
        ]);
        Json::obj(vec![
            ("cost_cache", memo_json(&self.cost)),
            ("analysis_cache", memo_json(&self.analysis)),
            ("edge_memo", edge),
            ("static_gate", gate),
            ("render_memo", Json::obj(vec![
                ("hits", Json::from(self.render_hits)),
                ("misses", Json::from(self.render_misses)),
            ])),
            ("store", store),
            ("faults", faults),
        ])
    }
}

fn opt_json(v: Option<usize>) -> Json {
    match v {
        Some(n) => Json::from(n),
        None => Json::Null,
    }
}

fn print_memo_line(name: &str, stats: &Option<MemoStats>) {
    let Some(s) = stats else { return };
    if s.lookups == 0 {
        return;
    }
    let disk = if s.disk_hits > 0 {
        format!(", {} disk hits", s.disk_hits)
    } else {
        String::new()
    };
    eprintln!(
        "{name}: {} hits / {} misses ({:.1}% hit rate, {} evictions{disk})",
        s.hits,
        s.misses,
        100.0 * s.hit_rate(),
        s.evictions
    );
}

fn memo_json(stats: &Option<MemoStats>) -> Json {
    match stats {
        None => Json::obj(vec![("enabled", Json::from(false))]),
        Some(s) => Json::obj(vec![
            ("enabled", Json::from(true)),
            ("lookups", Json::from(s.lookups)),
            ("hits", Json::from(s.hits)),
            ("misses", Json::from(s.misses)),
            ("evictions", Json::from(s.evictions)),
            ("disk_hits", Json::from(s.disk_hits)),
            ("hit_rate", Json::from(s.hit_rate())),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{load_edge_memo, CachedEdge, StepSignal};

    fn edge() -> CachedEdge {
        CachedEdge {
            program: None,
            signal: StepSignal::Rejected,
            speedup: 1.0,
            from_disk: false,
        }
    }

    /// A fresh store path (the segmented store is a directory; tests
    /// clear both shapes so reruns start cold).
    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("qimeng_session_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        cleanup(&path);
        path
    }

    fn cleanup(path: &PathBuf) {
        let _ = std::fs::remove_dir_all(path);
        let _ = std::fs::remove_file(path);
    }

    /// All 8 on/off combinations construct exactly the requested memo
    /// set (presence encodes policy).
    #[test]
    fn builder_constructs_every_flag_combination() {
        for combo in 0..8u8 {
            let (c, a, e) = (combo & 1 != 0, combo & 2 != 0, combo & 4 != 0);
            let s = Session::builder()
                .cost_cache(c)
                .analysis_cache(a)
                .edge_memo(e)
                .build();
            assert_eq!(s.cost().is_some(), c, "combo {combo}: cost tier");
            assert_eq!(s.analysis().is_some(), a, "combo {combo}: analysis");
            assert_eq!(s.edges().is_some(), e, "combo {combo}: edge memo");
            let reg = s.stats();
            assert_eq!(reg.cost.is_some(), c);
            assert_eq!(reg.analysis.is_some(), a);
            assert_eq!(reg.edges.is_some(), e);
            assert_eq!(reg.edge_capacity > 0, e);
        }
    }

    #[test]
    fn default_session_is_fully_cached_and_storeless() {
        let s = Session::default();
        assert!(s.cost().is_some());
        assert!(s.analysis().is_some());
        assert!(s.edges().is_some());
        assert!(s.store().is_none());
        assert_eq!(s.finish(), 0, "no store: nothing to persist");
    }

    /// `--memo-store` without the edge memo has nothing to persist: the
    /// builder drops the store rather than warm-starting into a memo
    /// that will never be consulted.
    #[test]
    fn store_requires_edge_memo() {
        let path = tmp("ignored.store");
        let s = Session::builder()
            .edge_memo(false)
            .memo_store(Some(path.clone()))
            .build();
        assert!(s.store().is_none());
        assert_eq!(s.finish(), 0);
        assert!(!path.exists(), "no store may appear");
    }

    /// The regression guard for the compaction pass: fill a tiny-capacity
    /// memo far past its bound, flush, and the store must contain only
    /// the live (non-evicted) entries — never more than capacity.
    #[test]
    fn flush_after_eviction_writes_only_live_entries() {
        let path = tmp("compaction.store");
        let s = Session::builder()
            .edge_capacity(2)
            .memo_store(Some(path.clone()))
            .build();
        let memo = s.edges().unwrap();
        // keys 0..32 share the zero high bits => one shard => hard
        // eviction pressure against the per-shard bound
        for k in 0..32u64 {
            memo.insert(k, edge());
        }
        assert!(memo.stats().evictions > 0, "pressure must evict");
        let mut live: Vec<u64> =
            memo.entries().iter().map(|(k, _)| *k).collect();
        live.sort_unstable();
        assert!(live.len() <= memo.capacity());
        let persisted = s.finish();
        assert_eq!(persisted, live.len(), "flush writes exactly the live set");
        assert!(persisted < 32, "store must drop the evicted entries");

        let reloaded = EdgeMemo::new();
        let n = load_edge_memo(&reloaded, &path).unwrap();
        assert_eq!(n, persisted);
        let mut reloaded_keys: Vec<u64> =
            reloaded.entries().iter().map(|(k, _)| *k).collect();
        reloaded_keys.sort_unstable();
        assert_eq!(reloaded_keys, live,
                   "store holds the live set, nothing evicted");
        cleanup(&path);
    }

    /// `finish` is idempotent and `Drop` re-runs it safely.
    #[test]
    fn finish_is_idempotent() {
        let path = tmp("idempotent.store");
        let s = Session::builder().memo_store(Some(path.clone())).build();
        s.edges().unwrap().insert(7, edge());
        let first = s.finish();
        assert_eq!(first, 1);
        assert_eq!(s.finish(), first, "second finish reports, not rewrites");
        let store = s.stats().store.unwrap();
        assert_eq!(store.persisted, Some(1));
        assert_eq!(store.written_segments, Some(1), "one dirty shard");
        assert_eq!(store.skipped_segments, Some(15), "the rest skipped clean");
        drop(s); // Drop must not double-flush or panic
        cleanup(&path);
    }

    /// A second Session over the same store warm-starts what the first
    /// one persisted (the cross-run handshake the CLI relies on).
    #[test]
    fn store_round_trips_across_sessions() {
        let path = tmp("roundtrip.store");
        let a = Session::builder().memo_store(Some(path.clone())).build();
        assert_eq!(a.warm_loaded(), 0, "missing store = silent cold start");
        for k in 0..5u64 {
            a.edges().unwrap().insert(k << 48, edge()); // spread shards
        }
        assert_eq!(a.finish(), 5);
        let b = Session::builder().memo_store(Some(path.clone())).build();
        assert_eq!(b.warm_loaded(), 5);
        assert_eq!(b.edges().unwrap().disk_loaded(), 5);
        let report = b.warm_report();
        assert_eq!(report.recovered_segments, 5, "one segment per shard hit");
        assert_eq!(report.degraded_segments, 0);
        let store = b.stats().store.unwrap();
        assert_eq!(store.warm_loaded, 5);
        assert_eq!(store.recovered_segments, 5);
        // a pure-replay session dirtied nothing: its flush skips every
        // segment (the dirty-skip fast path)
        assert_eq!(b.finish(), 5);
        let store = b.stats().store.unwrap();
        assert_eq!(store.written_segments, Some(0));
        assert_eq!(store.skipped_segments, Some(16));
        cleanup(&path);
    }

    #[test]
    fn stats_json_shape() {
        let s = Session::builder().analysis_cache(false).build();
        s.edges().unwrap().insert(1, edge());
        s.edges().unwrap().get(1);
        s.edges().unwrap().get(2);
        let j = s.stats().to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            parsed.get("cost_cache").unwrap().get("enabled"),
            Some(&Json::Bool(true))
        );
        assert_eq!(
            parsed.get("analysis_cache").unwrap().get("enabled"),
            Some(&Json::Bool(false))
        );
        let em = parsed.get("edge_memo").unwrap();
        assert_eq!(em.get("lookups").unwrap().as_usize(), Some(2));
        assert_eq!(em.get("hits").unwrap().as_usize(), Some(1));
        assert_eq!(em.get("misses").unwrap().as_usize(), Some(1));
        assert_eq!(em.get("len").unwrap().as_usize(), Some(1));
        assert!(em.get("capacity").unwrap().as_usize().unwrap() > 0);
        assert_eq!(parsed.get("store"), Some(&Json::Null));
        let gate = parsed.get("static_gate").unwrap();
        assert_eq!(gate.get("enabled"), Some(&Json::Bool(true)));
        assert_eq!(gate.get("static_rejects").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn static_gate_flag_controls_presence() {
        let on = Session::default();
        assert!(on.gate().is_some());
        assert_eq!(on.stats().static_gate, Some((0, 0)));
        let off = Session::builder().static_gate(false).build();
        assert!(off.gate().is_none());
        assert_eq!(off.stats().static_gate, None);
        let gate = parse_gate(&off.stats().to_json());
        assert_eq!(gate.get("enabled"), Some(&Json::Bool(false)));
    }

    fn parse_gate(j: &Json) -> Json {
        Json::parse(&j.to_string())
            .unwrap()
            .get("static_gate")
            .unwrap()
            .clone()
    }

    #[test]
    fn fault_plan_and_stats_surface_in_registry() {
        let s = Session::builder().faults(Some(FaultPlan::new(7))).build();
        assert!(s.faults().is_some());
        s.fault_stats().note_retried();
        s.fault_stats().note_recovered();
        let reg = s.stats();
        assert!(reg.faults.enabled);
        assert_eq!(reg.faults.retried, 1);
        assert_eq!(reg.faults.recovered, 1);
        let parsed = Json::parse(&reg.to_json().to_string()).unwrap();
        let f = parsed.get("faults").unwrap();
        assert_eq!(f.get("enabled"), Some(&Json::Bool(true)));
        assert_eq!(f.get("retried").unwrap().as_usize(), Some(1));
        assert_eq!(f.get("recovered").unwrap().as_usize(), Some(1));
        assert!(f.get("injected").unwrap().get("verif-flake").is_some());

        // without a plan the object is present but disabled, and a
        // storeless run reports no stale rejections anywhere
        let off = Session::default();
        assert!(off.faults().is_none());
        let parsed = Json::parse(&off.stats().to_json().to_string()).unwrap();
        let f = parsed.get("faults").unwrap();
        assert_eq!(f.get("enabled"), Some(&Json::Bool(false)));
        assert_eq!(f.get("panicked").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn store_report_carries_stale_rejected() {
        let path = tmp("stale_report.store");
        let s = Session::builder().memo_store(Some(path.clone())).build();
        s.edges().unwrap().insert(5, edge());
        s.finish();
        let store = s.stats().store.unwrap();
        assert_eq!(store.stale_rejected, 0, "clean store: nothing screened");
        let parsed = Json::parse(&s.stats().to_json().to_string()).unwrap();
        let js = parsed.get("store").unwrap();
        assert_eq!(js.get("stale_rejected").unwrap().as_usize(), Some(0));
        cleanup(&path);
    }

    #[test]
    fn render_memo_hits_on_identical_programs() {
        use crate::graph::{infer_shapes, Op};

        let mut g = Graph::new("t");
        let x = g.input("x", &[8, 16]);
        let w = g.weight("w", &[16, 4]);
        let mm = g.op(Op::MatMul, &[x, w]);
        g.mark_output(mm);
        let shapes = infer_shapes(&g);
        let p = crate::kir::lower_naive(&g);

        let s = Session::default();
        let direct = render(&p, &g, &shapes, TargetLang::Triton);
        let first = s.render_cached(&p, &g, &shapes, TargetLang::Triton);
        assert_eq!(*first, direct, "memoized render must match direct");
        let second = s.render_cached(&p, &g, &shapes, TargetLang::Triton);
        assert!(Arc::ptr_eq(&first, &second), "second render is a hit");
        // a different dialect is a different key, not a collision
        let cuda = s.render_cached(&p, &g, &shapes, TargetLang::Cuda);
        assert_ne!(*cuda, *first);
        let reg = s.stats();
        assert_eq!(reg.render_hits, 1);
        assert_eq!(reg.render_misses, 2);
    }
}
