//! Property-testing mini-framework (proptest is unavailable offline).
//!
//! A [`Gen`] produces random cases from an [`crate::util::Rng`]; [`check`]
//! runs a property over many cases and, on failure, re-runs a bounded
//! shrink loop (halving-style simplification via `Shrink`) before
//! panicking with the minimal counterexample it found.
//!
//! Used by `rust/tests/properties.rs` for coordinator invariants (routing,
//! schedule legality, reward monotonicity, serialization round-trips) and
//! the cache-differential suite. [`gens`] holds the recipe-based
//! generators/shrinkers for random tasks, programs, action sequences and
//! env configs.

pub mod gens;

use crate::util::Rng;

/// Number of cases per property (override with env QIMENG_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("QIMENG_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(128)
}

/// A generator of random values.
pub trait Gen<T> {
    fn gen(&self, rng: &mut Rng) -> T;
}

impl<T, F: Fn(&mut Rng) -> T> Gen<T> for F {
    fn gen(&self, rng: &mut Rng) -> T {
        self(rng)
    }
}

/// Values that know how to produce simpler versions of themselves.
pub trait Shrink: Sized + Clone {
    /// Candidate simplifications, most aggressive first. Default: none.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.is_empty() {
            out.push(self[..self.len() / 2].to_vec());
            let mut minus_last = self.clone();
            minus_last.pop();
            out.push(minus_last);
        }
        out
    }
}

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` generated inputs; shrink on first failure.
///
/// Panics with the minimal counterexample (debug-printed) so `cargo test`
/// reports it like a normal assertion failure.
pub fn check<T, G, P>(seed: u64, cases: usize, gen: G, prop: P)
where
    T: Shrink + std::fmt::Debug,
    G: Gen<T>,
    P: Fn(&T) -> PropResult,
{
    let mut rng = Rng::new(seed);
    for case_idx in 0..cases {
        let value = gen.gen(&mut rng);
        if let Err(msg) = prop(&value) {
            // shrink loop: breadth-limited greedy descent
            let mut best = value;
            let mut best_msg = msg;
            let mut budget = 200usize;
            'outer: while budget > 0 {
                for cand in best.shrink() {
                    budget = budget.saturating_sub(1);
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={seed}, case {case_idx}/{cases}):\n  \
                 counterexample: {best:?}\n  reason: {best_msg}"
            );
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(1, 64, |r: &mut Rng| r.below(100), |&n| {
            if n < 100 { Ok(()) } else { Err("oob".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_counterexample() {
        check(2, 64, |r: &mut Rng| r.below(100), |&n| {
            if n < 101 && n != 42 && n % 97 != 3 {
                Ok(())
            } else {
                Err("boom".into())
            }
        });
    }

    #[test]
    fn shrink_usize_descends() {
        let s = 10usize.shrink();
        assert!(s.contains(&0) && s.contains(&5) && s.contains(&9));
    }

    #[test]
    fn shrink_vec_shortens() {
        let v = vec![1usize, 2, 3, 4];
        let s = v.shrink();
        assert!(s.iter().all(|c| c.len() < v.len()));
    }
}
