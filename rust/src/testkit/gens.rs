//! Generators + shrinkers for the differential test suite: random tasks,
//! programs, action sequences and env configs.
//!
//! The cache subsystems (cost / analysis / edge-memo) silently rewire
//! every transition the evaluator takes, so their parity guarantees must
//! hold on *arbitrary* programs, not just the hand-picked table shapes.
//! Everything here is recipe-based: a case carries the small integers
//! that generated it (seed, op count, action stream), and `build()`
//! re-materializes graphs deterministically from the recipe — so
//! [`Shrink`] can walk toward genuinely smaller graphs and shorter action
//! paths while the failure stays reproducible from the printed
//! counterexample alone.

use super::Shrink;
use crate::env::EnvConfig;
use crate::graph::{Graph, Op};
use crate::gpusim::GpuSpec;
use crate::kir::{lower_naive, Program};
use crate::tasks::{Family, Suite, Task};
use crate::transform::{apply_action, decode_action, ACTION_DIM, STOP_ACTION};
use crate::util::Rng;

/// Perf-scale dimension table (indexed by the recipe's dim picks).
const PERF_DIMS: [usize; 3] = [96, 128, 192];
/// Verif-scale twin — same topology, executably small tensors.
const VERIF_DIMS: [usize; 3] = [4, 8, 16];

/// One step of a generated op chain; dims are table *indices* so the perf
/// and verif twins materialize from the same plan.
#[derive(Clone, Copy, Debug)]
enum PlanOp {
    MatMul { n_idx: usize },
    BiasAdd,
    Relu,
    Gelu,
    Tanh,
    Softmax,
    Scale(u32), // milli-units; same constant at both scales
}

/// Deterministic recipe for a random chain-structured task: `seed` fixes
/// the op/dimension draws, `n_ops` bounds the chain length. Two recipes
/// with equal fields build identical tasks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GraphRecipe {
    pub seed: u64,
    pub n_ops: usize,
}

impl GraphRecipe {
    fn plan(&self) -> (usize, usize, Vec<PlanOp>) {
        let mut rng = Rng::new(self.seed);
        let m_idx = rng.below(PERF_DIMS.len());
        let k_idx = rng.below(PERF_DIMS.len());
        let ops = (0..self.n_ops.max(1))
            .map(|_| match rng.below(7) {
                0 | 1 => PlanOp::MatMul { n_idx: rng.below(PERF_DIMS.len()) },
                2 => PlanOp::BiasAdd,
                3 => PlanOp::Relu,
                4 => PlanOp::Gelu,
                5 => PlanOp::Tanh,
                _ => {
                    if rng.bool(0.5) {
                        PlanOp::Softmax
                    } else {
                        PlanOp::Scale(rng.below(3000) as u32 + 100)
                    }
                }
            })
            .collect();
        (m_idx, k_idx, ops)
    }

    fn materialize(&self, dims: &[usize; 3]) -> Graph {
        let (m_idx, k_idx, plan) = self.plan();
        let mut g = Graph::new(&format!("gen_{:016x}_{}", self.seed,
                                        self.n_ops));
        let mut cur = g.input("x", &[dims[m_idx], dims[k_idx]]);
        let mut col_idx = k_idx; // current trailing-dim table index
        for (wi, op) in plan.iter().enumerate() {
            cur = match *op {
                PlanOp::MatMul { n_idx } => {
                    let w = g.weight(&format!("w{wi}"),
                                     &[dims[col_idx], dims[n_idx]]);
                    col_idx = n_idx;
                    g.op(Op::MatMul, &[cur, w])
                }
                PlanOp::BiasAdd => {
                    let b = g.weight(&format!("b{wi}"), &[dims[col_idx]]);
                    g.op(Op::BiasAdd, &[cur, b])
                }
                PlanOp::Relu => g.op(Op::Relu, &[cur]),
                PlanOp::Gelu => g.op(Op::Gelu, &[cur]),
                PlanOp::Tanh => g.op(Op::Tanh, &[cur]),
                PlanOp::Softmax => g.op(Op::Softmax, &[cur]),
                PlanOp::Scale(milli) => {
                    g.op(Op::Scale(milli as f32 / 1000.0), &[cur])
                }
            };
        }
        g.mark_output(cur);
        g
    }

    /// The perf-scale graph alone (for program-level properties).
    pub fn build_graph(&self) -> Graph {
        self.materialize(&PERF_DIMS)
    }

    /// A full [`Task`] (perf graph + executable verif twin) for
    /// episode-level properties.
    pub fn task(&self) -> Task {
        let graph = self.materialize(&PERF_DIMS);
        let verif_graph = self.materialize(&VERIF_DIMS);
        let has_matmul = graph
            .nodes
            .iter()
            .any(|n| matches!(n.op, Op::MatMul));
        Task {
            id: format!("gen_{:016x}_{}", self.seed, self.n_ops),
            suite: Suite::TrainCorpus,
            family: if has_matmul {
                Family::GemmBiasAct
            } else {
                Family::Elementwise
            },
            graph,
            verif_graph,
        }
    }
}

impl Shrink for GraphRecipe {
    /// Shrink toward smaller graphs (the seed is kept: it pins which op
    /// chain the survivors come from).
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        for n in [1, self.n_ops / 2, self.n_ops.saturating_sub(1)] {
            if n >= 1 && n < self.n_ops {
                out.push(GraphRecipe { seed: self.seed, n_ops: n });
            }
        }
        out.dedup();
        out
    }
}

/// Generate a random action stream (indices over the full action space,
/// Stop included). `Vec<usize>` already shrinks toward shorter paths via
/// the blanket [`Shrink`] impl.
pub fn gen_actions(rng: &mut Rng, max_len: usize) -> Vec<usize> {
    (0..rng.below(max_len.max(1)) + 1)
        .map(|_| rng.below(ACTION_DIM))
        .collect()
}

/// A generated program: a random task graph lowered naively, advanced by
/// a random action stream at a random micro-coder quality.
#[derive(Clone, Debug)]
pub struct ProgramCase {
    pub recipe: GraphRecipe,
    pub actions: Vec<usize>,
    pub quality_milli: usize,
}

impl ProgramCase {
    /// Materialize (graph, shapes, program): invalid actions are skipped,
    /// valid ones applied in stream order.
    pub fn build(&self, spec: &GpuSpec) -> (Graph, Vec<Vec<usize>>, Program) {
        let g = self.recipe.build_graph();
        let shapes = crate::graph::infer_shapes(&g);
        let mut p = lower_naive(&g);
        for &a in &self.actions {
            if a >= STOP_ACTION {
                continue;
            }
            if let Ok(next) = apply_action(
                &p, &g, &shapes, &decode_action(a), spec,
                self.quality_milli as f32 / 1000.0,
            ) {
                p = next;
            }
        }
        (g, shapes, p)
    }
}

impl Shrink for ProgramCase {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<ProgramCase> = self
            .actions
            .shrink()
            .into_iter()
            .map(|actions| ProgramCase { actions, ..self.clone() })
            .collect();
        out.extend(
            self.recipe
                .shrink()
                .into_iter()
                .map(|recipe| ProgramCase { recipe, ..self.clone() }),
        );
        out
    }
}

/// [`crate::testkit::Gen`] entry point for [`ProgramCase`].
pub fn gen_program_case(rng: &mut Rng) -> ProgramCase {
    ProgramCase {
        recipe: GraphRecipe { seed: rng.next_u64(), n_ops: rng.below(6) + 1 },
        actions: gen_actions(rng, 10),
        quality_milli: rng.below(1001),
    }
}

/// A generated [`EnvConfig`] (the transition-relevant knobs; reward
/// shaping stays at its default — it never feeds the caches).
#[derive(Clone, Debug)]
pub struct EnvCfgCase {
    pub max_steps: usize,
    pub verif_trials: usize,
    pub cuda: bool,
}

impl EnvCfgCase {
    pub fn to_cfg(&self) -> EnvConfig {
        EnvConfig {
            max_steps: self.max_steps,
            verif_trials: self.verif_trials,
            cuda: self.cuda,
            ..EnvConfig::default()
        }
    }
}

impl Shrink for EnvCfgCase {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.max_steps > 1 {
            out.push(EnvCfgCase { max_steps: 1, ..self.clone() });
            out.push(EnvCfgCase {
                max_steps: self.max_steps / 2,
                ..self.clone()
            });
        }
        if self.verif_trials > 1 {
            out.push(EnvCfgCase { verif_trials: 1, ..self.clone() });
        }
        if self.cuda {
            out.push(EnvCfgCase { cuda: false, ..self.clone() });
        }
        out
    }
}

/// [`crate::testkit::Gen`] entry point for [`EnvCfgCase`].
pub fn gen_env_cfg(rng: &mut Rng) -> EnvCfgCase {
    EnvCfgCase {
        max_steps: rng.below(8) + 1,
        verif_trials: rng.below(3) + 1,
        cuda: rng.bool(0.25),
    }
}

/// A whole generated episode: task recipe + env config + base seed +
/// action stream. The unit of the cache-differential properties.
#[derive(Clone, Debug)]
pub struct EpisodeCase {
    pub recipe: GraphRecipe,
    pub env: EnvCfgCase,
    pub seed: u64,
    pub actions: Vec<usize>,
}

impl Shrink for EpisodeCase {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<EpisodeCase> = self
            .actions
            .shrink()
            .into_iter()
            .filter(|a| !a.is_empty())
            .map(|actions| EpisodeCase { actions, ..self.clone() })
            .collect();
        out.extend(
            self.recipe
                .shrink()
                .into_iter()
                .map(|recipe| EpisodeCase { recipe, ..self.clone() }),
        );
        out.extend(
            self.env
                .shrink()
                .into_iter()
                .map(|env| EpisodeCase { env, ..self.clone() }),
        );
        out
    }
}

/// [`crate::testkit::Gen`] entry point for [`EpisodeCase`].
pub fn gen_episode_case(rng: &mut Rng) -> EpisodeCase {
    EpisodeCase {
        recipe: GraphRecipe { seed: rng.next_u64(), n_ops: rng.below(5) + 1 },
        env: gen_env_cfg(rng),
        seed: rng.next_u64(),
        actions: gen_actions(rng, 8),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::check;

    #[test]
    fn recipes_build_valid_twin_graphs() {
        check(
            0xF00D,
            48,
            |rng: &mut Rng| GraphRecipe {
                seed: rng.next_u64(),
                n_ops: rng.below(8) + 1,
            },
            |recipe: &GraphRecipe| {
                let task = recipe.task();
                task.graph.validate().map_err(|e| format!("perf: {e}"))?;
                task.verif_graph
                    .validate()
                    .map_err(|e| format!("verif: {e}"))?;
                crate::prop_assert!(
                    task.graph.nodes.len() == task.verif_graph.nodes.len(),
                    "perf/verif topology mismatch"
                );
                let shapes = crate::graph::infer_shapes(&task.verif_graph);
                let biggest = shapes
                    .iter()
                    .map(|s| s.iter().product::<usize>())
                    .max()
                    .unwrap();
                crate::prop_assert!(
                    biggest <= 1 << 12,
                    "verif tensors must stay executable, got {biggest}"
                );
                Ok(())
            },
        );
    }

    #[test]
    fn recipes_are_deterministic() {
        let r = GraphRecipe { seed: 0xAB5E, n_ops: 4 };
        let a = r.task();
        let b = r.task();
        assert_eq!(a.id, b.id);
        assert_eq!(a.graph.nodes.len(), b.graph.nodes.len());
        assert_eq!(
            crate::gpusim::graph_fingerprint(
                &a.graph, &crate::graph::infer_shapes(&a.graph)),
            crate::gpusim::graph_fingerprint(
                &b.graph, &crate::graph::infer_shapes(&b.graph)),
        );
    }

    #[test]
    fn program_case_builds_valid_programs() {
        let spec = GpuSpec::a100();
        check(0xBEEF, 48, gen_program_case, |case: &ProgramCase| {
            let (g, _shapes, p) = case.build(&spec);
            p.validate(&g)
        });
    }

    #[test]
    fn shrinks_walk_downward() {
        let mut rng = Rng::new(3);
        let case = gen_episode_case(&mut rng);
        for s in case.shrink() {
            assert!(
                s.actions.len() < case.actions.len()
                    || s.recipe.n_ops < case.recipe.n_ops
                    || s.env.max_steps < case.env.max_steps
                    || s.env.verif_trials < case.env.verif_trials
                    || (case.env.cuda && !s.env.cuda),
                "shrink must simplify at least one axis"
            );
        }
        let r = GraphRecipe { seed: 9, n_ops: 6 };
        assert!(r.shrink().iter().all(|s| s.n_ops < r.n_ops));
        assert!(GraphRecipe { seed: 9, n_ops: 1 }.shrink().is_empty());
    }
}
