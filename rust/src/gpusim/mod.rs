//! Analytic GPU simulator: prices a scheduled [`Program`](crate::kir::Program)
//! on a concrete GPU spec (Table 2 of the paper) and prices the
//! "PyTorch Eager" expert-library baseline the benchmarks compare against.
//!
//! This is the substitution for the paper's physical V100/A100/H100
//! testbeds (DESIGN.md): a roofline × occupancy × pipeline-overlap ×
//! coalescing model that is monotone in exactly the axes the semantic
//! optimization actions manipulate.

mod spec;
mod cost;
mod cache;
mod eager;

pub use cache::{graph_fingerprint, kernel_fingerprint, program_fingerprint,
                CostCache, Fnv, MemoStats, Pricer, ShardedMemo};
pub(crate) use cache::{combine, spec_tag};
pub use cost::{kernel_time_us, op_flops, program_time_us, CostBreakdown};
pub use eager::{eager_time_us, library_affinity};
pub use spec::{GpuArch, GpuSpec};
