//! GPU hardware specs — the exact Table 2 of the paper.

/// Architecture generation (drives feature gates like cp.async).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GpuArch {
    Volta,
    Ampere,
    Hopper,
}

/// One GPU platform (paper Table 2 numbers).
#[derive(Clone, Debug, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    pub arch: GpuArch,
    pub sms: usize,
    pub global_mem_gb: usize,
    pub smem_per_sm_kb: usize,
    pub l2_mb: usize,
    pub mem_bw_gbs: f64,
    pub fp32_tflops: f64,
    /// Per-kernel launch overhead (µs) — CPU dispatch + driver; the same
    /// order on all three platforms but slightly lower on newer parts.
    pub launch_overhead_us: f64,
}

impl GpuSpec {
    pub fn v100() -> GpuSpec {
        GpuSpec {
            name: "V100",
            arch: GpuArch::Volta,
            sms: 80,
            global_mem_gb: 32,
            smem_per_sm_kb: 96,
            l2_mb: 6,
            mem_bw_gbs: 900.0,
            fp32_tflops: 15.7,
            launch_overhead_us: 6.0,
        }
    }

    pub fn a100() -> GpuSpec {
        GpuSpec {
            name: "A100",
            arch: GpuArch::Ampere,
            sms: 108,
            global_mem_gb: 80,
            smem_per_sm_kb: 164,
            l2_mb: 40,
            mem_bw_gbs: 1935.0,
            fp32_tflops: 19.5,
            launch_overhead_us: 5.0,
        }
    }

    pub fn h100() -> GpuSpec {
        GpuSpec {
            name: "H100",
            arch: GpuArch::Hopper,
            sms: 132,
            global_mem_gb: 80,
            smem_per_sm_kb: 228,
            l2_mb: 50,
            mem_bw_gbs: 3350.0,
            fp32_tflops: 60.0,
            launch_overhead_us: 4.0,
        }
    }

    pub fn by_name(name: &str) -> Option<GpuSpec> {
        match name.to_ascii_uppercase().as_str() {
            "V100" => Some(Self::v100()),
            "A100" => Some(Self::a100()),
            "H100" => Some(Self::h100()),
            _ => None,
        }
    }

    pub fn all() -> Vec<GpuSpec> {
        vec![Self::v100(), Self::a100(), Self::h100()]
    }

    /// cp.async-style deep pipelining exists on Ampere+ only; on Volta the
    /// PipelineAsync action is architecturally invalid (the policy must
    /// learn this — the paper's cross-hardware generalisation story).
    pub fn supports_async_copy(&self) -> bool {
        !matches!(self.arch, GpuArch::Volta)
    }

    /// Peak FLOP/s (f64).
    pub fn peak_flops(&self) -> f64 {
        self.fp32_tflops * 1e12
    }

    /// Peak bytes/s.
    pub fn peak_bw(&self) -> f64 {
        self.mem_bw_gbs * 1e9
    }

    /// Shared memory per SM in bytes.
    pub fn smem_bytes(&self) -> usize {
        self.smem_per_sm_kb * 1024
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values() {
        let v = GpuSpec::v100();
        assert_eq!((v.sms, v.smem_per_sm_kb, v.l2_mb), (80, 96, 6));
        let a = GpuSpec::a100();
        assert_eq!((a.sms, a.smem_per_sm_kb, a.l2_mb), (108, 164, 40));
        let h = GpuSpec::h100();
        assert_eq!((h.sms, h.smem_per_sm_kb, h.l2_mb), (132, 228, 50));
        assert_eq!(h.fp32_tflops, 60.0);
    }

    #[test]
    fn async_copy_gate() {
        assert!(!GpuSpec::v100().supports_async_copy());
        assert!(GpuSpec::a100().supports_async_copy());
        assert!(GpuSpec::h100().supports_async_copy());
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(GpuSpec::by_name("a100").unwrap().name, "A100");
        assert!(GpuSpec::by_name("B200").is_none());
    }
}
