//! "PyTorch Eager" baseline pricing: one expert-library kernel per op.
//!
//! Library kernels are individually excellent (high efficiency ladder)
//! but (a) pay a launch per op, (b) round-trip every intermediate through
//! HBM, and (c) lose efficiency on shapes the library wasn't tuned for —
//! the `affinity` factor, drawn deterministically per task, models the
//! cuBLAS/cuDNN heuristic-table mismatch that lets generated kernels beat
//! Eager on some tasks (the paper's fast_1 wins).

use super::cost::op_flops;
use super::spec::GpuSpec;
use crate::graph::{Graph, Op, OpClass};

fn numel(s: &[usize]) -> f64 {
    s.iter().product::<usize>() as f64
}

/// Deterministic per-task library-affinity in [0.42, 1.0] from a stable
/// hash of the task id (how well the library's tuning tables match the
/// task's shapes). Above ~0.85 the shapes also hit the tensor-core (TF32)
/// fast paths — see `eager_time_us`.
pub fn library_affinity(task_id: &str) -> f64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in task_id.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    0.42 + 0.58 * ((h >> 16) & 0xffff) as f64 / 65535.0
}

/// Shapes in the library's sweet spot additionally dispatch to
/// tensor-core-accelerated (TF32) kernels — the reason generated f32
/// Triton cannot beat cuBLAS on well-tuned shapes (paper: fast_1 at L1 is
/// ~43-67%, not ~100%).
fn tensor_core_bonus(affinity: f64) -> f64 {
    if affinity > 0.85 { 1.5 } else { 1.0 }
}

/// Price the eager execution of a graph: every non-input node is its own
/// library kernel.
pub fn eager_time_us(g: &Graph, shapes: &[Vec<usize>], spec: &GpuSpec,
                     affinity: f64) -> f64 {
    let mut total = 0.0;
    for (id, node) in g.nodes.iter().enumerate() {
        if matches!(node.op, Op::Input) {
            continue;
        }
        let flops = op_flops(g, shapes, id);
        // library kernels stream inputs once and write the output once
        let mut bytes = numel(&shapes[id]) * 4.0;
        for &i in &node.inputs {
            bytes += numel(&shapes[i]) * 4.0;
        }
        // eager attention also materializes scores (it is not flash
        // unless the user opted into SDPA fused path; KernelBench's
        // reference modules are the naive formulation)
        if matches!(node.op, Op::Attention) {
            let s_q = shapes[node.inputs[0]][0] as f64;
            let s_k = shapes[node.inputs[1]][0] as f64;
            bytes += s_q * s_k * 4.0 * 3.0;
        }
        let (ce, me) = match node.op.class() {
            // cuBLAS/cuDNN-grade contraction (+TF32 on sweet-spot shapes)
            OpClass::Contraction => {
                (0.70 * affinity * tensor_core_bonus(affinity), 0.85)
            }
            OpClass::Reduction => (0.5, 0.82 * (0.72 + 0.28 * affinity)),
            OpClass::Elementwise => (0.5, 0.88 * (0.75 + 0.25 * affinity)),
            OpClass::Movement => (0.5, 0.80),
            OpClass::Input => unreachable!(),
        };
        let l2_bytes = spec.l2_mb as f64 * 1e6;
        let bw_mult = if bytes < l2_bytes * 0.5 { 1.8 } else { 1.0 };
        let t_comp = flops / (spec.peak_flops() * ce) * 1e6;
        let t_mem = bytes / (spec.peak_bw() * me * bw_mult) * 1e6;
        // library kernels overlap copy/compute well (0.7)
        total += t_comp.max(t_mem) + 0.3 * t_comp.min(t_mem)
            + spec.launch_overhead_us;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;
    use crate::kir::{lower_naive, LoopOrder, Schedule};

    #[test]
    fn affinity_deterministic_and_bounded() {
        let a = library_affinity("kb1_000_matmul");
        assert_eq!(a, library_affinity("kb1_000_matmul"));
        assert!((0.55..=1.0).contains(&a));
        assert_ne!(a, library_affinity("kb1_001_matmul"));
    }

    #[test]
    fn eager_beats_naive_but_loses_to_optimized_gemm() {
        let mut g = Graph::new("mm");
        let x = g.input("x", &[4096, 4096]);
        let w = g.weight("w", &[4096, 4096]);
        let mm = g.op(Op::MatMul, &[x, w]);
        g.mark_output(mm);
        let shapes = infer_shapes(&g);
        let spec = GpuSpec::a100();
        let eager = eager_time_us(&g, &shapes, &spec, 0.8);

        let naive = lower_naive(&g);
        let t_naive = super::super::program_time_us(&naive, &g, &shapes, &spec);
        assert!(t_naive > eager * 2.0, "naive {t_naive:.0} vs eager {eager:.0}");

        let mut opt = naive.clone();
        opt.kernels[0].schedule = Schedule {
            block_tile: Some((128, 128, 32)),
            reg_tile: Some((8, 8)),
            pipeline_depth: 3,
            loop_order: LoopOrder::Blocked,
            vector_width: 4,
        };
        let t_opt = super::super::program_time_us(&opt, &g, &shapes, &spec);
        assert!(t_opt < eager, "opt {t_opt:.0} vs eager {eager:.0}");
    }

    #[test]
    fn eager_pays_per_op_launches_on_fused_workloads() {
        // a chain of elementwise ops: eager must launch each; a single
        // fused generated kernel with good order wins
        let mut g = Graph::new("chain");
        let mut cur = g.input("x", &[4096, 1024]);
        for _ in 0..6 {
            cur = g.op(Op::Relu, &[cur]);
            let y = g.input(&format!("y{cur}"), &[4096, 1024]);
            cur = g.op(Op::Add, &[cur, y]);
        }
        g.mark_output(cur);
        let shapes = infer_shapes(&g);
        let spec = GpuSpec::a100();
        let eager = eager_time_us(&g, &shapes, &spec, 1.0);
        let mut fused = lower_naive(&g);
        let all_nodes: Vec<_> = fused.kernels.iter().flat_map(|k| k.nodes.clone()).collect();
        fused.kernels = vec![crate::kir::Kernel {
            nodes: all_nodes,
            schedule: Schedule {
                block_tile: None,
                reg_tile: None,
                pipeline_depth: 1,
                loop_order: LoopOrder::Coalesced,
                vector_width: 4,
            },
            name: "fused".into(),
        }];
        let t_fused = super::super::program_time_us(&fused, &g, &shapes, &spec);
        assert!(
            t_fused < eager * 0.6,
            "fused {t_fused:.0} vs eager {eager:.0}"
        );
    }
}
