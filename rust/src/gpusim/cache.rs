//! Thread-safe memoization of cost-model results, plus the shared
//! sharding / fingerprint plumbing the repo's other memo subsystems are
//! built on ([`crate::transform::AnalysisCache`], [`crate::env::EdgeMemo`]).
//!
//! The batched evaluation engine ([`crate::eval::BatchRunner`]) sweeps
//! method × suite × GPU, and the same pricing inputs recur constantly —
//! most of all the per-(task, gpu) eager baselines, which every method of
//! a sweep shares. The cache keys on `(graph fingerprint, kernel/program
//! fingerprint, spec)` and is sharded (16 ways) so concurrent workers
//! rarely contend on a lock; values are whole [`CostBreakdown`]s, and
//! since the cost model is a pure function, a hit returns exactly what a
//! cold miss would compute.
//!
//! The cache is the pricing engine for the whole evaluation stack: one
//! cache per sweep is threaded through [`crate::eval::evaluate`] /
//! [`crate::eval::BatchRunner`] into [`crate::env::OptimEnv`] and the
//! greedy-lookahead action pricing (via [`Pricer`]), so a one-action
//! mutation re-prices one kernel instead of the whole program — sibling
//! lookahead candidates share every untouched kernel and hit the memo.
//! The BatchRunner's eager-baseline JSONL enrichment rides the same
//! cache. Warm-vs-cold equivalence is guarded end-to-end by the property
//! tests in `rust/tests/properties.rs` and `rust/tests/batch.rs`.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::cost::{kernel_time_us, CostBreakdown};
use super::eager::eager_time_us;
use super::spec::GpuSpec;
use crate::graph::{Graph, Op};
use crate::kir::{Kernel, LoopOrder, Program};

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// Minimal FNV-1a accumulator (no std Hasher: we want a stable, portable
/// 64-bit fingerprint, not a per-process randomized hash). Shared by every
/// memo subsystem that needs content-addressed keys.
pub struct Fnv(u64);

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv {
    pub fn new() -> Fnv {
        Fnv(FNV_OFFSET)
    }

    pub fn byte(&mut self, b: u8) {
        self.0 = (self.0 ^ b as u64).wrapping_mul(FNV_PRIME);
    }

    pub fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.byte(b);
        }
    }

    pub fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Fingerprint of the cost-relevant content of a graph + its shapes.
/// Computed once per task by callers and threaded through as `ctx`.
pub fn graph_fingerprint(g: &Graph, shapes: &[Vec<usize>]) -> u64 {
    let mut h = Fnv::new();
    h.bytes(g.name.as_bytes());
    h.usize(g.nodes.len());
    for (node, shape) in g.nodes.iter().zip(shapes) {
        h.bytes(node.op.mnemonic().as_bytes());
        match node.op {
            Op::Conv2d { stride, pad } => {
                h.usize(stride);
                h.usize(pad);
            }
            Op::MaxPool2d { k, stride } => {
                h.usize(k);
                h.usize(stride);
            }
            Op::Scale(s) => h.u64(s.to_bits() as u64),
            _ => {}
        }
        h.usize(node.inputs.len());
        for &i in &node.inputs {
            h.usize(i);
        }
        h.usize(shape.len());
        for &d in shape {
            h.usize(d);
        }
        h.byte(node.is_weight as u8);
    }
    h.usize(g.outputs.len());
    for &o in &g.outputs {
        h.usize(o);
    }
    h.0
}

/// Fingerprint of one kernel's cost-relevant state (node group +
/// schedule). Mutations are deliberately excluded: they change semantics,
/// never pricing.
pub fn kernel_fingerprint(k: &Kernel) -> u64 {
    let mut h = Fnv::new();
    h.usize(k.nodes.len());
    for &n in &k.nodes {
        h.usize(n);
    }
    let s = &k.schedule;
    match s.block_tile {
        None => h.byte(0),
        Some((m, n, kk)) => {
            h.byte(1);
            h.usize(m);
            h.usize(n);
            h.usize(kk);
        }
    }
    match s.reg_tile {
        None => h.byte(0),
        Some((m, n)) => {
            h.byte(1);
            h.usize(m);
            h.usize(n);
        }
    }
    h.usize(s.pipeline_depth);
    h.byte(match s.loop_order {
        LoopOrder::Naive => 0,
        LoopOrder::Coalesced => 1,
        LoopOrder::Blocked => 2,
    });
    h.usize(s.vector_width);
    h.0
}

/// Fingerprint of a program's *structural* state: the kernel partition
/// (names + node groups) and every schedule. Mutations and the
/// compile-broken flag are deliberately excluded — they change the
/// program's semantics, never its region structure or action validity —
/// so a buggy program shares its analysis with its clean twin. Keys the
/// [`crate::transform::AnalysisCache`].
pub fn program_fingerprint(p: &Program) -> u64 {
    let mut h = Fnv::new();
    h.usize(p.kernels.len());
    for k in &p.kernels {
        h.bytes(k.name.as_bytes());
        h.u64(kernel_fingerprint(k));
    }
    h.0
}

pub(crate) fn spec_tag(spec: &GpuSpec) -> u64 {
    let mut h = Fnv::new();
    h.bytes(spec.name.as_bytes());
    h.0
}

/// splitmix-style avalanche over the combined key parts.
pub(crate) fn combine(a: u64, b: u64, c: u64) -> u64 {
    let mut x = a ^ b.rotate_left(21) ^ c.rotate_left(42);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58476D1CE4E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

const SHARDS: usize = 16;
/// Per-shard entry cap used by [`CostCache`]: a runaway sweep degrades to
/// recomputation, never to unbounded memory.
const MAX_PER_SHARD: usize = 1 << 16;

/// Aggregate traffic counters of one memo. `lookups` is derived as
/// `hits + misses` when the snapshot is taken — the identity holds by
/// construction (guarded by `rust/tests/batch.rs`) and costs no third
/// atomic on the lookup hot path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemoStats {
    pub lookups: usize,
    pub hits: usize,
    pub misses: usize,
    pub evictions: usize,
    /// Hits whose value was warm-started from a persisted store rather
    /// than computed by this process. Only [`crate::env::EdgeMemo`]
    /// overlays this (via `--memo-store`); plain memos report 0.
    pub disk_hits: usize,
}

impl MemoStats {
    /// Hit rate in [0, 1]; 0 when the memo saw no traffic.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            return 0.0;
        }
        self.hits as f64 / self.lookups as f64
    }

    /// Component-wise sum (for caches built from several memos).
    pub fn merged(&self, other: &MemoStats) -> MemoStats {
        MemoStats {
            lookups: self.lookups + other.lookups,
            hits: self.hits + other.hits,
            misses: self.misses + other.misses,
            evictions: self.evictions + other.evictions,
            disk_hits: self.disk_hits + other.disk_hits,
        }
    }
}

struct Slot<V> {
    value: V,
    /// Recency stamp: matches exactly one `(key, stamp)` pair in the
    /// shard's `order` queue — that pair is the entry's *live* position;
    /// older pairs for the same key are stale and skipped at eviction.
    stamp: u64,
}

struct MemoShard<V> {
    map: HashMap<u64, Slot<V>>,
    /// Recency queue, least-recent first, of `(key, stamp)` pairs.
    /// Touching a key (get-hit or insert) pushes a fresh pair instead of
    /// splicing the old one out (O(1) instead of O(n)); eviction and
    /// compaction drop pairs whose stamp no longer matches the map.
    order: VecDeque<(u64, u64)>,
    /// Monotone stamp source for this shard.
    tick: u64,
    /// Contents-dirty flag backing the persistence tier's dirty-skip
    /// flushes: set whenever the shard's *entry set* changes (insert, and
    /// the evictions an insert triggers), cleared by the flush that
    /// serialized the shard. Get-hits and recency compaction touch only
    /// LRU bookkeeping — nothing persisted — so they leave it alone.
    dirty: bool,
}

impl<V> MemoShard<V> {
    fn new() -> MemoShard<V> {
        MemoShard {
            map: HashMap::new(),
            order: VecDeque::new(),
            tick: 0,
            dirty: false,
        }
    }

    fn next_stamp(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Drop stale `(key, stamp)` pairs once they dominate the queue, so
    /// `order` stays O(live entries) even under heavy re-touching.
    fn compact_if_needed(&mut self) {
        if self.order.len() > self.map.len().saturating_mul(2).max(8) {
            let map = &self.map;
            self.order.retain(|&(k, s)| {
                map.get(&k).map(|slot| slot.stamp) == Some(s)
            });
        }
    }
}

/// Sharded, thread-safe, capacity-bounded memo table: the common chassis
/// under [`CostCache`], [`crate::transform::AnalysisCache`] and
/// [`crate::env::EdgeMemo`]. 16-way sharded on the key's high bits so
/// concurrent workers rarely contend; bounded per shard with LRU
/// eviction (recency refreshed on both `get` hits and re-`insert`s), so
/// overflow degrades to recomputation of the coldest entries, never to
/// unbounded memory. Values must be cheap to clone (breakdowns, `Arc`s,
/// programs).
pub struct ShardedMemo<V> {
    shards: Vec<Mutex<MemoShard<V>>>,
    max_per_shard: usize,
    hits: AtomicUsize,
    misses: AtomicUsize,
    evictions: AtomicUsize,
}

impl<V: Clone> ShardedMemo<V> {
    /// A memo holding at most `max_entries` values in total (rounded up to
    /// at least one per shard).
    pub fn new(max_entries: usize) -> ShardedMemo<V> {
        ShardedMemo {
            shards: (0..SHARDS).map(|_| Mutex::new(MemoShard::new())).collect(),
            max_per_shard: (max_entries / SHARDS).max(1),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
        }
    }

    /// Which shard (and so which persisted segment file) a key lives in.
    /// Stable across processes — the segmented memo store relies on it to
    /// partition entries into per-shard segment files.
    #[inline]
    pub fn shard_index(key: u64) -> usize {
        // high bits: the low bits feed the HashMap's own bucketing
        (key >> 48) as usize % SHARDS
    }

    #[inline]
    fn shard(&self, key: u64) -> &Mutex<MemoShard<V>> {
        &self.shards[Self::shard_index(key)]
    }

    /// Look a key up, counting the hit or miss. A hit refreshes the
    /// entry's LRU recency.
    pub fn get(&self, key: u64) -> Option<V> {
        let mut guard = self.shard(key).lock().unwrap();
        let shard = &mut *guard;
        let stamp = shard.next_stamp();
        let hit = shard.map.get_mut(&key).map(|slot| {
            slot.stamp = stamp;
            slot.value.clone()
        });
        match &hit {
            Some(_) => {
                shard.order.push_back((key, stamp));
                shard.compact_if_needed();
                self.hits.fetch_add(1, Ordering::Relaxed)
            }
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        hit
    }

    /// Insert a value, LRU-evicting the shard's least-recently-touched
    /// entries when the capacity bound is hit. Re-inserting an existing
    /// key refreshes its recency (and keeps the last writer's value —
    /// racing writers compute the same pure value anyway).
    pub fn insert(&self, key: u64, value: V) {
        let mut guard = self.shard(key).lock().unwrap();
        let shard = &mut *guard;
        let stamp = shard.next_stamp();
        shard.dirty = true;
        shard.map.insert(key, Slot { value, stamp });
        shard.order.push_back((key, stamp));
        while shard.map.len() > self.max_per_shard {
            let (k, s) = shard.order.pop_front().expect("order covers map");
            // stale pair: the key was touched again after this pair was
            // queued (or already evicted) — only live removals count
            if shard.map.get(&k).map(|slot| slot.stamp) == Some(s) {
                shard.map.remove(&k);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        shard.compact_if_needed();
    }

    /// Traffic counters since construction.
    pub fn stats(&self) -> MemoStats {
        let hits = self.hits.load(Ordering::Relaxed);
        let misses = self.misses.load(Ordering::Relaxed);
        MemoStats {
            lookups: hits + misses,
            hits,
            misses,
            evictions: self.evictions.load(Ordering::Relaxed),
            disk_hits: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total residency bound (`max_per_shard × SHARDS`): the most entries
    /// the memo can keep live, and therefore the most a
    /// flush-after-eviction compaction pass can ever persist.
    pub fn capacity(&self) -> usize {
        self.max_per_shard * SHARDS
    }

    /// Snapshot every resident `(key, value)` pair, locking one shard at
    /// a time. For persistence and diagnostics — not a hot path, and not
    /// an atomic view across shards (racing inserts may or may not be
    /// included). Counts no stats and bumps no recency.
    pub fn entries(&self) -> Vec<(u64, V)> {
        let mut out = Vec::with_capacity(self.len());
        for shard in &self.shards {
            let s = shard.lock().unwrap();
            out.extend(s.map.iter().map(|(k, slot)| (*k, slot.value.clone())));
        }
        out
    }

    /// Number of shards (== persisted segment files); fixed at
    /// construction.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Live entry count of one shard.
    pub fn shard_len(&self, i: usize) -> usize {
        self.shards[i].lock().unwrap().map.len()
    }

    /// Snapshot one shard's resident `(key, value)` pairs (the unit the
    /// segmented memo store serializes). Counts no stats, bumps no
    /// recency.
    pub fn entries_of_shard(&self, i: usize) -> Vec<(u64, V)> {
        let s = self.shards[i].lock().unwrap();
        s.map.iter().map(|(k, slot)| (*k, slot.value.clone())).collect()
    }

    /// Whether shard `i`'s entry set changed since the last
    /// [`Self::clear_shard_dirty`]. Freshly-constructed shards are clean.
    pub fn shard_dirty(&self, i: usize) -> bool {
        self.shards[i].lock().unwrap().dirty
    }

    /// Atomically clear shard `i`'s dirty flag and snapshot its entries —
    /// the flush handshake. Clearing and snapshotting under one lock means
    /// an insert racing with the flush either lands in the snapshot or
    /// re-dirties the shard for the next flush; it can never be lost.
    pub fn take_shard_for_flush(&self, i: usize) -> Vec<(u64, V)> {
        let mut s = self.shards[i].lock().unwrap();
        s.dirty = false;
        s.map.iter().map(|(k, slot)| (*k, slot.value.clone())).collect()
    }

    /// Clear shard `i`'s dirty flag (used after a warm start that loaded
    /// the shard to exactly its on-disk contents).
    pub fn clear_shard_dirty(&self, i: usize) {
        self.shards[i].lock().unwrap().dirty = false;
    }

    /// Re-mark shard `i` dirty (a flush that failed mid-write puts the
    /// flag back so the next flush retries the segment).
    pub fn mark_shard_dirty(&self, i: usize) {
        self.shards[i].lock().unwrap().dirty = true;
    }

    /// Test hook: every map entry must own exactly one live recency pair,
    /// and no shard may exceed its capacity bound.
    #[cfg(test)]
    fn assert_lru_invariant(&self) {
        for shard in &self.shards {
            let s = shard.lock().unwrap();
            assert!(s.map.len() <= self.max_per_shard, "shard over capacity");
            for (k, slot) in &s.map {
                let live = s
                    .order
                    .iter()
                    .filter(|&&(ok, os)| ok == *k && os == slot.stamp)
                    .count();
                assert_eq!(live, 1, "key {k}: one live recency pair expected");
            }
        }
    }
}

/// Sharded, thread-safe cost-model memo cache.
pub struct CostCache {
    kernels: ShardedMemo<CostBreakdown>,
    eager: ShardedMemo<f64>,
}

impl Default for CostCache {
    fn default() -> Self {
        Self::new()
    }
}

impl CostCache {
    pub fn new() -> CostCache {
        CostCache {
            kernels: ShardedMemo::new(SHARDS * MAX_PER_SHARD),
            eager: ShardedMemo::new(SHARDS * MAX_PER_SHARD),
        }
    }

    /// Price one kernel through the cache. `ctx` is the
    /// [`graph_fingerprint`] of the task the kernel belongs to.
    pub fn kernel_time_us(&self, ctx: u64, kernel: &Kernel, g: &Graph,
                          shapes: &[Vec<usize>], spec: &GpuSpec)
                          -> CostBreakdown {
        let key = combine(ctx, kernel_fingerprint(kernel), spec_tag(spec));
        if let Some(hit) = self.kernels.get(key) {
            return hit;
        }
        // compute outside the lock: pricing an L3 kernel is ~µs-scale and
        // must not serialize other shard users
        let cost = kernel_time_us(kernel, g, shapes, spec);
        self.kernels.insert(key, cost.clone());
        cost
    }

    /// Price a whole program through the cache (kernels back-to-back).
    pub fn program_time_us(&self, ctx: u64, p: &Program, g: &Graph,
                           shapes: &[Vec<usize>], spec: &GpuSpec) -> f64 {
        p.kernels
            .iter()
            .map(|k| self.kernel_time_us(ctx, k, g, shapes, spec).time_us)
            .sum()
    }

    /// Memoized eager (expert-library) baseline for a task graph.
    pub fn eager_time_us(&self, ctx: u64, g: &Graph, shapes: &[Vec<usize>],
                         spec: &GpuSpec, affinity: f64) -> f64 {
        let key = combine(ctx, affinity.to_bits(), spec_tag(spec));
        if let Some(hit) = self.eager.get(key) {
            return hit;
        }
        let t = eager_time_us(g, shapes, spec, affinity);
        self.eager.insert(key, t);
        t
    }

    /// (hits, misses) since construction.
    pub fn stats(&self) -> (usize, usize) {
        let s = self.full_stats();
        (s.hits, s.misses)
    }

    /// Full traffic counters (both the kernel and eager memos).
    pub fn full_stats(&self) -> MemoStats {
        self.kernels.stats().merged(&self.eager.stats())
    }

    pub fn len(&self) -> usize {
        self.kernels.len() + self.eager.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A pricing handle for one task: couples an optional shared
/// [`CostCache`] with the task's precomputed [`graph_fingerprint`], so
/// hot loops (env steps, greedy lookahead) price kernels without
/// re-fingerprinting the graph per call. With `cache: None` every method
/// falls through to the direct cost-model functions — the cached and
/// uncached paths are bit-identical because the cost model is pure.
#[derive(Clone, Copy, Debug)]
pub struct Pricer<'c> {
    cache: Option<&'c CostCache>,
    ctx: u64,
}

impl<'c> Pricer<'c> {
    pub fn new(cache: Option<&'c CostCache>, g: &Graph,
               shapes: &[Vec<usize>]) -> Pricer<'c> {
        Self::from_ctx(cache, graph_fingerprint(g, shapes))
    }

    /// Build from an already-computed [`graph_fingerprint`] (shared with
    /// the env's [`crate::transform::Analyzer`] so a task is
    /// fingerprinted once per episode, not once per subsystem).
    pub fn from_ctx(cache: Option<&'c CostCache>, ctx: u64) -> Pricer<'c> {
        Pricer { cache, ctx }
    }

    /// The cache this pricer routes through, if any (used to rebuild an
    /// env over the same task without re-fingerprinting).
    pub fn cache(&self) -> Option<&'c CostCache> {
        self.cache
    }

    /// Price one kernel (through the memo when caching).
    pub fn kernel_time_us(&self, k: &Kernel, g: &Graph,
                          shapes: &[Vec<usize>], spec: &GpuSpec)
                          -> CostBreakdown {
        match self.cache {
            Some(c) => c.kernel_time_us(self.ctx, k, g, shapes, spec),
            None => kernel_time_us(k, g, shapes, spec),
        }
    }

    /// Price a whole program (per-kernel through the memo when caching).
    pub fn program_time_us(&self, p: &Program, g: &Graph,
                           shapes: &[Vec<usize>], spec: &GpuSpec) -> f64 {
        match self.cache {
            Some(c) => c.program_time_us(self.ctx, p, g, shapes, spec),
            None => super::cost::program_time_us(p, g, shapes, spec),
        }
    }

    /// Price the eager (expert-library) baseline.
    pub fn eager_time_us(&self, g: &Graph, shapes: &[Vec<usize>],
                         spec: &GpuSpec, affinity: f64) -> f64 {
        match self.cache {
            Some(c) => c.eager_time_us(self.ctx, g, shapes, spec, affinity),
            None => eager_time_us(g, shapes, spec, affinity),
        }
    }
}

impl std::fmt::Debug for CostCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (h, m) = self.stats();
        write!(f, "CostCache {{ entries: {}, hits: {h}, misses: {m} }}",
               self.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;
    use crate::kir::lower_naive;

    fn demo() -> (Graph, Vec<Vec<usize>>) {
        let mut g = Graph::new("cache_demo");
        let x = g.input("x", &[512, 256]);
        let w = g.weight("w", &[256, 128]);
        let mm = g.op(Op::MatMul, &[x, w]);
        let r = g.op(Op::Relu, &[mm]);
        g.mark_output(r);
        let shapes = infer_shapes(&g);
        (g, shapes)
    }

    #[test]
    fn hit_returns_identical_breakdown() {
        let (g, shapes) = demo();
        let spec = GpuSpec::a100();
        let p = lower_naive(&g);
        let cache = CostCache::new();
        let ctx = graph_fingerprint(&g, &shapes);
        let cold = cache.kernel_time_us(ctx, &p.kernels[0], &g, &shapes, &spec);
        let warm = cache.kernel_time_us(ctx, &p.kernels[0], &g, &shapes, &spec);
        let direct = kernel_time_us(&p.kernels[0], &g, &shapes, &spec);
        assert_eq!(cold, direct);
        assert_eq!(warm, direct);
        let (hits, misses) = cache.stats();
        assert_eq!((hits, misses), (1, 1));
    }

    #[test]
    fn schedule_changes_miss() {
        let (g, shapes) = demo();
        let spec = GpuSpec::h100();
        let mut p = lower_naive(&g);
        let cache = CostCache::new();
        let ctx = graph_fingerprint(&g, &shapes);
        let a = cache.kernel_time_us(ctx, &p.kernels[0], &g, &shapes, &spec);
        p.kernels[0].schedule.block_tile = Some((64, 64, 32));
        let b = cache.kernel_time_us(ctx, &p.kernels[0], &g, &shapes, &spec);
        assert_ne!(a.time_us, b.time_us);
        assert_eq!(cache.stats().0, 0, "different schedules must not hit");
    }

    #[test]
    fn specs_are_distinguished() {
        let (g, shapes) = demo();
        let p = lower_naive(&g);
        let cache = CostCache::new();
        let ctx = graph_fingerprint(&g, &shapes);
        let v = cache
            .program_time_us(ctx, &p, &g, &shapes, &GpuSpec::v100());
        let h = cache
            .program_time_us(ctx, &p, &g, &shapes, &GpuSpec::h100());
        assert!(h < v);
        assert_eq!(cache.stats().0, 0);
    }

    #[test]
    fn eager_memo_matches_direct() {
        let (g, shapes) = demo();
        let spec = GpuSpec::a100();
        let cache = CostCache::new();
        let ctx = graph_fingerprint(&g, &shapes);
        let a = cache.eager_time_us(ctx, &g, &shapes, &spec, 0.7);
        let b = cache.eager_time_us(ctx, &g, &shapes, &spec, 0.7);
        assert_eq!(a, eager_time_us(&g, &shapes, &spec, 0.7));
        assert_eq!(a, b);
        assert!(cache.stats().0 >= 1);
    }

    #[test]
    fn pricer_cached_and_uncached_identical() {
        let (g, shapes) = demo();
        let spec = GpuSpec::a100();
        let p = lower_naive(&g);
        let cache = CostCache::new();
        let cached = Pricer::new(Some(&cache), &g, &shapes);
        let plain = Pricer::new(None, &g, &shapes);
        for _ in 0..2 {
            assert_eq!(
                cached.program_time_us(&p, &g, &shapes, &spec).to_bits(),
                plain.program_time_us(&p, &g, &shapes, &spec).to_bits()
            );
            assert_eq!(
                cached.eager_time_us(&g, &shapes, &spec, 0.5).to_bits(),
                plain.eager_time_us(&g, &shapes, &spec, 0.5).to_bits()
            );
            assert_eq!(
                cached.kernel_time_us(&p.kernels[0], &g, &shapes, &spec),
                plain.kernel_time_us(&p.kernels[0], &g, &shapes, &spec)
            );
        }
        assert!(cache.stats().0 > 0, "second round must hit");
        assert!(plain.cache().is_none() && cached.cache().is_some());
    }

    #[test]
    fn program_fingerprint_tracks_structure_not_mutations() {
        let (g, _shapes) = demo();
        let p = lower_naive(&g);
        let base = program_fingerprint(&p);
        let mut mutated = p.clone();
        mutated.mutations.push(crate::graph::Mutation {
            node: 2,
            kind: crate::graph::MutationKind::SkippedOp,
        });
        mutated.compile_broken = true;
        assert_eq!(base, program_fingerprint(&mutated),
                   "mutations change semantics, not structure");
        let mut tiled = p.clone();
        tiled.kernels[0].schedule.block_tile = Some((32, 32, 32));
        assert_ne!(base, program_fingerprint(&tiled),
                   "schedule changes must change the fingerprint");
    }

    #[test]
    fn sharded_memo_evicts_and_counts() {
        let memo: ShardedMemo<usize> = ShardedMemo::new(2);
        // keys with identical high bits land in one shard (cap = 1)
        for k in 0..10u64 {
            memo.insert(k, k as usize);
        }
        let s = memo.stats();
        assert_eq!(s.evictions, 9, "cap-1 shard keeps only the newest");
        assert_eq!(memo.len(), 1);
        assert_eq!(memo.get(9), Some(9));
        assert_eq!(memo.get(0), None, "oldest entries were evicted");
        let s = memo.stats();
        assert_eq!((s.lookups, s.hits, s.misses), (2, 1, 1));
        assert_eq!(s.disk_hits, 0, "plain memos never report disk hits");
        memo.assert_lru_invariant();
    }

    #[test]
    fn lru_get_refreshes_recency() {
        // max_entries = 32 -> cap 2 per shard; keys 0..3 share shard 0
        let memo: ShardedMemo<u64> = ShardedMemo::new(32);
        memo.insert(0, 100);
        memo.insert(1, 101);
        assert_eq!(memo.get(0), Some(100), "touch 0: now 1 is coldest");
        memo.insert(2, 102);
        assert_eq!(memo.stats().evictions, 1);
        assert_eq!(memo.get(0), Some(100), "recently-read entry survives");
        assert_eq!(memo.get(1), None, "LRU entry was evicted");
        assert_eq!(memo.get(2), Some(102));
        memo.assert_lru_invariant();
    }

    #[test]
    fn lru_reinsert_refreshes_recency() {
        // regression: FIFO left a re-inserted key at its original queue
        // position, so refreshing a hot entry could still evict it first
        let memo: ShardedMemo<u64> = ShardedMemo::new(32);
        memo.insert(0, 100);
        memo.insert(1, 101);
        memo.insert(0, 200);
        memo.insert(2, 102);
        assert_eq!(memo.stats().evictions, 1);
        assert_eq!(memo.get(0), Some(200), "re-inserted key keeps new value");
        assert_eq!(memo.get(1), None, "stale key evicted instead");
        assert_eq!(memo.get(2), Some(102));
        memo.assert_lru_invariant();
    }

    #[test]
    fn lru_order_map_invariant_under_eviction_pressure() {
        // hammer one cap-2 shard with interleaved inserts, re-inserts and
        // gets; the live-pair/map invariant must hold at every step and
        // same-key traffic must never count as an eviction
        let memo: ShardedMemo<u64> = ShardedMemo::new(32);
        for round in 0..50u64 {
            memo.insert(round % 5, round);
            memo.get(round % 3);
            memo.insert(round % 2, round + 1000);
            memo.assert_lru_invariant();
        }
        assert_eq!(memo.len(), 2);
        // key 1 or 0 was re-touched on every round; both kinds of touch
        // must have kept the hottest keys resident at the end
        assert!(memo.get(0).is_some() || memo.get(1).is_some());
        let s = memo.stats();
        assert_eq!(s.lookups, s.hits + s.misses);
    }

    #[test]
    fn lru_same_key_traffic_never_evicts() {
        let memo: ShardedMemo<u64> = ShardedMemo::new(2);
        for round in 0..100u64 {
            memo.insert(7, round);
            memo.get(7);
        }
        let s = memo.stats();
        assert_eq!(s.evictions, 0, "one resident key can never evict");
        assert_eq!(memo.len(), 1);
        assert_eq!(memo.get(7), Some(99));
        memo.assert_lru_invariant();
    }

    #[test]
    fn dirty_tracks_entry_set_changes_only() {
        let memo: ShardedMemo<u64> = ShardedMemo::new(32);
        let n = memo.shard_count();
        assert!((0..n).all(|i| !memo.shard_dirty(i)),
                "fresh shards must be clean");
        memo.insert(0, 100); // key 0 -> shard 0
        assert!(memo.shard_dirty(0), "insert must dirty its shard");
        assert!((1..n).all(|i| !memo.shard_dirty(i)),
                "insert must not dirty other shards");
        assert_eq!(memo.take_shard_for_flush(0), vec![(0, 100)]);
        assert!(!memo.shard_dirty(0), "flush snapshot must clear the flag");
        // reads and recency traffic change nothing persisted
        memo.get(0);
        memo.get(999);
        assert!(!memo.shard_dirty(0), "get must never dirty a shard");
        // eviction pressure (cap 2 per shard) changes the entry set
        memo.insert(1, 101);
        memo.insert(2, 102);
        memo.take_shard_for_flush(0);
        memo.insert(3, 103); // evicts the coldest of {0,1,2}
        assert!(memo.stats().evictions > 0);
        assert!(memo.shard_dirty(0), "eviction-triggering insert dirties");
        memo.clear_shard_dirty(0);
        memo.mark_shard_dirty(0);
        assert!(memo.shard_dirty(0), "mark/clear round-trips");
    }

    #[test]
    fn shard_accessors_partition_entries() {
        let memo: ShardedMemo<u64> = ShardedMemo::new(1024);
        for k in 0..SHARDS as u64 {
            memo.insert(k << 48, k); // one key per shard
        }
        assert_eq!(memo.shard_count(), SHARDS);
        for i in 0..SHARDS {
            assert_eq!(memo.shard_len(i), 1);
            let entries = memo.entries_of_shard(i);
            assert_eq!(entries.len(), 1);
            assert_eq!(ShardedMemo::<u64>::shard_index(entries[0].0), i);
        }
        let total: usize = (0..SHARDS).map(|i| memo.shard_len(i)).sum();
        assert_eq!(total, memo.len());
    }

    #[test]
    fn cache_is_share_safe_across_threads() {
        let (g, shapes) = demo();
        let spec = GpuSpec::a100();
        let p = lower_naive(&g);
        let cache = CostCache::new();
        let ctx = graph_fingerprint(&g, &shapes);
        let direct = kernel_time_us(&p.kernels[0], &g, &shapes, &spec);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for _ in 0..50 {
                        let c = cache.kernel_time_us(
                            ctx, &p.kernels[0], &g, &shapes, &spec,
                        );
                        assert_eq!(c, direct);
                    }
                });
            }
        });
        let (hits, misses) = cache.stats();
        assert_eq!(hits + misses, 400);
        assert!(hits >= 399 - 7, "at most one miss per racing thread");
    }
}
