//! Thread-safe memoization of cost-model results.
//!
//! The batched evaluation engine ([`crate::eval::BatchRunner`]) sweeps
//! method × suite × GPU, and the same pricing inputs recur constantly —
//! most of all the per-(task, gpu) eager baselines, which every method of
//! a sweep shares. The cache keys on `(graph fingerprint, kernel/program
//! fingerprint, spec)` and is sharded (16 ways) so concurrent workers
//! rarely contend on a lock; values are whole [`CostBreakdown`]s, and
//! since the cost model is a pure function, a hit returns exactly what a
//! cold miss would compute.
//!
//! The cache is the pricing engine for the whole evaluation stack: one
//! cache per sweep is threaded through [`crate::eval::evaluate`] /
//! [`crate::eval::BatchRunner`] into [`crate::env::OptimEnv`] and the
//! greedy-lookahead action pricing (via [`Pricer`]), so a one-action
//! mutation re-prices one kernel instead of the whole program — sibling
//! lookahead candidates share every untouched kernel and hit the memo.
//! The BatchRunner's eager-baseline JSONL enrichment rides the same
//! cache. Warm-vs-cold equivalence is guarded end-to-end by the property
//! tests in `rust/tests/properties.rs` and `rust/tests/batch.rs`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::cost::{kernel_time_us, CostBreakdown};
use super::eager::eager_time_us;
use super::spec::GpuSpec;
use crate::graph::{Graph, Op};
use crate::kir::{Kernel, LoopOrder, Program};

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// Minimal FNV-1a accumulator (no std Hasher: we want a stable, portable
/// 64-bit fingerprint, not a per-process randomized hash).
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(FNV_OFFSET)
    }

    fn byte(&mut self, b: u8) {
        self.0 = (self.0 ^ b as u64).wrapping_mul(FNV_PRIME);
    }

    fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.byte(b);
        }
    }

    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
}

/// Fingerprint of the cost-relevant content of a graph + its shapes.
/// Computed once per task by callers and threaded through as `ctx`.
pub fn graph_fingerprint(g: &Graph, shapes: &[Vec<usize>]) -> u64 {
    let mut h = Fnv::new();
    h.bytes(g.name.as_bytes());
    h.usize(g.nodes.len());
    for (node, shape) in g.nodes.iter().zip(shapes) {
        h.bytes(node.op.mnemonic().as_bytes());
        match node.op {
            Op::Conv2d { stride, pad } => {
                h.usize(stride);
                h.usize(pad);
            }
            Op::MaxPool2d { k, stride } => {
                h.usize(k);
                h.usize(stride);
            }
            Op::Scale(s) => h.u64(s.to_bits() as u64),
            _ => {}
        }
        h.usize(node.inputs.len());
        for &i in &node.inputs {
            h.usize(i);
        }
        h.usize(shape.len());
        for &d in shape {
            h.usize(d);
        }
        h.byte(node.is_weight as u8);
    }
    h.usize(g.outputs.len());
    for &o in &g.outputs {
        h.usize(o);
    }
    h.0
}

/// Fingerprint of one kernel's cost-relevant state (node group +
/// schedule). Mutations are deliberately excluded: they change semantics,
/// never pricing.
pub fn kernel_fingerprint(k: &Kernel) -> u64 {
    let mut h = Fnv::new();
    h.usize(k.nodes.len());
    for &n in &k.nodes {
        h.usize(n);
    }
    let s = &k.schedule;
    match s.block_tile {
        None => h.byte(0),
        Some((m, n, kk)) => {
            h.byte(1);
            h.usize(m);
            h.usize(n);
            h.usize(kk);
        }
    }
    match s.reg_tile {
        None => h.byte(0),
        Some((m, n)) => {
            h.byte(1);
            h.usize(m);
            h.usize(n);
        }
    }
    h.usize(s.pipeline_depth);
    h.byte(match s.loop_order {
        LoopOrder::Naive => 0,
        LoopOrder::Coalesced => 1,
        LoopOrder::Blocked => 2,
    });
    h.usize(s.vector_width);
    h.0
}

fn spec_tag(spec: &GpuSpec) -> u64 {
    let mut h = Fnv::new();
    h.bytes(spec.name.as_bytes());
    h.0
}

/// splitmix-style avalanche over the combined key parts.
fn combine(a: u64, b: u64, c: u64) -> u64 {
    let mut x = a ^ b.rotate_left(21) ^ c.rotate_left(42);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58476D1CE4E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

const SHARDS: usize = 16;
/// Per-shard entry cap: a runaway sweep degrades to recomputation, never
/// to unbounded memory.
const MAX_PER_SHARD: usize = 1 << 16;

/// Sharded, thread-safe cost-model memo cache.
pub struct CostCache {
    kernels: Vec<Mutex<HashMap<u64, CostBreakdown>>>,
    eager: Vec<Mutex<HashMap<u64, f64>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl Default for CostCache {
    fn default() -> Self {
        Self::new()
    }
}

impl CostCache {
    pub fn new() -> CostCache {
        CostCache {
            kernels: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            eager: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    #[inline]
    fn shard(key: u64) -> usize {
        // high bits: the low bits feed the HashMap's own bucketing
        (key >> 48) as usize % SHARDS
    }

    /// Price one kernel through the cache. `ctx` is the
    /// [`graph_fingerprint`] of the task the kernel belongs to.
    pub fn kernel_time_us(&self, ctx: u64, kernel: &Kernel, g: &Graph,
                          shapes: &[Vec<usize>], spec: &GpuSpec)
                          -> CostBreakdown {
        let key = combine(ctx, kernel_fingerprint(kernel), spec_tag(spec));
        let shard = &self.kernels[Self::shard(key)];
        if let Some(hit) = shard.lock().unwrap().get(&key).cloned() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return hit;
        }
        // compute outside the lock: pricing an L3 kernel is ~µs-scale and
        // must not serialize other shard users
        let cost = kernel_time_us(kernel, g, shapes, spec);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut guard = shard.lock().unwrap();
        if guard.len() < MAX_PER_SHARD {
            guard.insert(key, cost.clone());
        }
        cost
    }

    /// Price a whole program through the cache (kernels back-to-back).
    pub fn program_time_us(&self, ctx: u64, p: &Program, g: &Graph,
                           shapes: &[Vec<usize>], spec: &GpuSpec) -> f64 {
        p.kernels
            .iter()
            .map(|k| self.kernel_time_us(ctx, k, g, shapes, spec).time_us)
            .sum()
    }

    /// Memoized eager (expert-library) baseline for a task graph.
    pub fn eager_time_us(&self, ctx: u64, g: &Graph, shapes: &[Vec<usize>],
                         spec: &GpuSpec, affinity: f64) -> f64 {
        let key = combine(ctx, affinity.to_bits(), spec_tag(spec));
        let shard = &self.eager[Self::shard(key)];
        if let Some(&hit) = shard.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return hit;
        }
        let t = eager_time_us(g, shapes, spec, affinity);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut guard = shard.lock().unwrap();
        if guard.len() < MAX_PER_SHARD {
            guard.insert(key, t);
        }
        t
    }

    /// (hits, misses) since construction.
    pub fn stats(&self) -> (usize, usize) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    pub fn len(&self) -> usize {
        self.kernels.iter().map(|s| s.lock().unwrap().len()).sum::<usize>()
            + self.eager.iter().map(|s| s.lock().unwrap().len()).sum::<usize>()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A pricing handle for one task: couples an optional shared
/// [`CostCache`] with the task's precomputed [`graph_fingerprint`], so
/// hot loops (env steps, greedy lookahead) price kernels without
/// re-fingerprinting the graph per call. With `cache: None` every method
/// falls through to the direct cost-model functions — the cached and
/// uncached paths are bit-identical because the cost model is pure.
#[derive(Clone, Copy, Debug)]
pub struct Pricer<'c> {
    cache: Option<&'c CostCache>,
    ctx: u64,
}

impl<'c> Pricer<'c> {
    pub fn new(cache: Option<&'c CostCache>, g: &Graph,
               shapes: &[Vec<usize>]) -> Pricer<'c> {
        Pricer { cache, ctx: graph_fingerprint(g, shapes) }
    }

    /// The cache this pricer routes through, if any (used to rebuild an
    /// env over the same task without re-fingerprinting).
    pub fn cache(&self) -> Option<&'c CostCache> {
        self.cache
    }

    /// Price a whole program (per-kernel through the memo when caching).
    pub fn program_time_us(&self, p: &Program, g: &Graph,
                           shapes: &[Vec<usize>], spec: &GpuSpec) -> f64 {
        match self.cache {
            Some(c) => c.program_time_us(self.ctx, p, g, shapes, spec),
            None => super::cost::program_time_us(p, g, shapes, spec),
        }
    }

    /// Price the eager (expert-library) baseline.
    pub fn eager_time_us(&self, g: &Graph, shapes: &[Vec<usize>],
                         spec: &GpuSpec, affinity: f64) -> f64 {
        match self.cache {
            Some(c) => c.eager_time_us(self.ctx, g, shapes, spec, affinity),
            None => eager_time_us(g, shapes, spec, affinity),
        }
    }
}

impl std::fmt::Debug for CostCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (h, m) = self.stats();
        write!(f, "CostCache {{ entries: {}, hits: {h}, misses: {m} }}",
               self.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;
    use crate::kir::lower_naive;

    fn demo() -> (Graph, Vec<Vec<usize>>) {
        let mut g = Graph::new("cache_demo");
        let x = g.input("x", &[512, 256]);
        let w = g.weight("w", &[256, 128]);
        let mm = g.op(Op::MatMul, &[x, w]);
        let r = g.op(Op::Relu, &[mm]);
        g.mark_output(r);
        let shapes = infer_shapes(&g);
        (g, shapes)
    }

    #[test]
    fn hit_returns_identical_breakdown() {
        let (g, shapes) = demo();
        let spec = GpuSpec::a100();
        let p = lower_naive(&g);
        let cache = CostCache::new();
        let ctx = graph_fingerprint(&g, &shapes);
        let cold = cache.kernel_time_us(ctx, &p.kernels[0], &g, &shapes, &spec);
        let warm = cache.kernel_time_us(ctx, &p.kernels[0], &g, &shapes, &spec);
        let direct = kernel_time_us(&p.kernels[0], &g, &shapes, &spec);
        assert_eq!(cold, direct);
        assert_eq!(warm, direct);
        let (hits, misses) = cache.stats();
        assert_eq!((hits, misses), (1, 1));
    }

    #[test]
    fn schedule_changes_miss() {
        let (g, shapes) = demo();
        let spec = GpuSpec::h100();
        let mut p = lower_naive(&g);
        let cache = CostCache::new();
        let ctx = graph_fingerprint(&g, &shapes);
        let a = cache.kernel_time_us(ctx, &p.kernels[0], &g, &shapes, &spec);
        p.kernels[0].schedule.block_tile = Some((64, 64, 32));
        let b = cache.kernel_time_us(ctx, &p.kernels[0], &g, &shapes, &spec);
        assert_ne!(a.time_us, b.time_us);
        assert_eq!(cache.stats().0, 0, "different schedules must not hit");
    }

    #[test]
    fn specs_are_distinguished() {
        let (g, shapes) = demo();
        let p = lower_naive(&g);
        let cache = CostCache::new();
        let ctx = graph_fingerprint(&g, &shapes);
        let v = cache
            .program_time_us(ctx, &p, &g, &shapes, &GpuSpec::v100());
        let h = cache
            .program_time_us(ctx, &p, &g, &shapes, &GpuSpec::h100());
        assert!(h < v);
        assert_eq!(cache.stats().0, 0);
    }

    #[test]
    fn eager_memo_matches_direct() {
        let (g, shapes) = demo();
        let spec = GpuSpec::a100();
        let cache = CostCache::new();
        let ctx = graph_fingerprint(&g, &shapes);
        let a = cache.eager_time_us(ctx, &g, &shapes, &spec, 0.7);
        let b = cache.eager_time_us(ctx, &g, &shapes, &spec, 0.7);
        assert_eq!(a, eager_time_us(&g, &shapes, &spec, 0.7));
        assert_eq!(a, b);
        assert!(cache.stats().0 >= 1);
    }

    #[test]
    fn pricer_cached_and_uncached_identical() {
        let (g, shapes) = demo();
        let spec = GpuSpec::a100();
        let p = lower_naive(&g);
        let cache = CostCache::new();
        let cached = Pricer::new(Some(&cache), &g, &shapes);
        let plain = Pricer::new(None, &g, &shapes);
        for _ in 0..2 {
            assert_eq!(
                cached.program_time_us(&p, &g, &shapes, &spec).to_bits(),
                plain.program_time_us(&p, &g, &shapes, &spec).to_bits()
            );
            assert_eq!(
                cached.eager_time_us(&g, &shapes, &spec, 0.5).to_bits(),
                plain.eager_time_us(&g, &shapes, &spec, 0.5).to_bits()
            );
        }
        assert!(cache.stats().0 > 0, "second round must hit");
        assert!(plain.cache().is_none() && cached.cache().is_some());
    }

    #[test]
    fn cache_is_share_safe_across_threads() {
        let (g, shapes) = demo();
        let spec = GpuSpec::a100();
        let p = lower_naive(&g);
        let cache = CostCache::new();
        let ctx = graph_fingerprint(&g, &shapes);
        let direct = kernel_time_us(&p.kernels[0], &g, &shapes, &spec);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for _ in 0..50 {
                        let c = cache.kernel_time_us(
                            ctx, &p.kernels[0], &g, &shapes, &spec,
                        );
                        assert_eq!(c, direct);
                    }
                });
            }
        });
        let (hits, misses) = cache.stats();
        assert_eq!(hits + misses, 400);
        assert!(hits >= 399 - 7, "at most one miss per racing thread");
    }
}
