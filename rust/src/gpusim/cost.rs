//! The analytic kernel cost model.
//!
//! For each fused kernel we derive (1) FLOPs, (2) HBM traffic as a
//! function of the schedule (fusion kills intermediate round-trips,
//! tiling multiplies operand reuse, loop order sets the coalescing
//! efficiency, online/tiled reductions collapse multi-pass streams), and
//! (3) occupancy from the shared-memory footprint. Time is the classic
//! overlap-aware roofline:
//!
//! ```text
//! t = max(t_comp, t_mem) + (1 - overlap) * min(t_comp, t_mem) + launch
//! ```
//!
//! Calibration constants (naive effective cache tile, efficiency ladders)
//! are documented inline; they were tuned so that the *relative* behaviour
//! matches the paper's evaluation shape (naive generated kernels ~0.1-0.5x
//! of PyTorch Eager; well-scheduled fused kernels up to ~2x; see
//! EXPERIMENTS.md).

use super::spec::GpuSpec;
use crate::graph::{Graph, NodeId, Op, OpClass};
use crate::kir::{Kernel, LoopOrder, Program};

/// Detailed costing of one kernel (used by perf reports and tests).
/// `PartialEq` so cache tests can assert a memo hit returns exactly what
/// a cold miss computes (the cost model is a pure function).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CostBreakdown {
    pub flops: f64,
    pub hbm_bytes: f64,
    pub t_comp_us: f64,
    pub t_mem_us: f64,
    pub overlap: f64,
    pub occupancy: f64,
    pub compute_eff: f64,
    pub mem_eff: f64,
    pub time_us: f64,
}

fn numel(s: &[usize]) -> f64 {
    s.iter().product::<usize>() as f64
}

/// FLOPs of one node.
pub fn op_flops(g: &Graph, shapes: &[Vec<usize>], id: NodeId) -> f64 {
    let node = &g.nodes[id];
    let out = numel(&shapes[id]);
    match &node.op {
        Op::Input => 0.0,
        Op::MatMul => {
            let a = &shapes[node.inputs[0]];
            2.0 * a[0] as f64 * a[1] as f64 * shapes[id][1] as f64
        }
        Op::BatchMatMul => {
            let a = &shapes[node.inputs[0]];
            2.0 * a[0] as f64 * a[1] as f64 * a[2] as f64 * shapes[id][2] as f64
        }
        Op::Conv2d { .. } => {
            let w = &shapes[node.inputs[1]];
            // 2 * N * F * OH * OW * C * KH * KW
            2.0 * out * w[1] as f64 * w[2] as f64 * w[3] as f64
        }
        Op::Attention => {
            let q = &shapes[node.inputs[0]];
            let k = &shapes[node.inputs[1]];
            let (s_q, d) = (q[0] as f64, q[1] as f64);
            let s_k = k[0] as f64;
            2.0 * s_q * s_k * d * 2.0 + 5.0 * s_q * s_k
        }
        Op::LstmCell => {
            let x = &shapes[node.inputs[0]];
            let h = &shapes[node.inputs[1]];
            2.0 * x[0] as f64 * (x[1] + h[1]) as f64 * 4.0 * h[1] as f64
        }
        Op::Gelu => 10.0 * out,
        Op::Sigmoid | Op::Tanh | Op::Exp | Op::Sqrt => 4.0 * out,
        Op::Softmax => 5.0 * numel(&shapes[node.inputs[0]]),
        Op::LayerNorm => 8.0 * out,
        Op::BatchNorm2d => 4.0 * out,
        Op::MaxPool2d { k, .. } => (k * k) as f64 * out,
        Op::GlobalAvgPool => numel(&shapes[node.inputs[0]]),
        _ => out, // add/sub/mul/div/max/bias/relu/scale/reduce/argmax/cumsum/transpose
    }
}

/// External input node ids of a kernel (tensors read from HBM) and output
/// node ids (tensors written to HBM).
fn kernel_io(kernel: &Kernel, g: &Graph) -> (Vec<NodeId>, Vec<NodeId>) {
    let in_group = |n: NodeId| kernel.nodes.contains(&n);
    let mut ext_in: Vec<NodeId> = Vec::new();
    for &n in &kernel.nodes {
        for &i in &g.nodes[n].inputs {
            if !in_group(i) && !ext_in.contains(&i) {
                ext_in.push(i);
            }
        }
    }
    let consumers = g.consumers();
    let mut outs: Vec<NodeId> = Vec::new();
    for &n in &kernel.nodes {
        let escapes = consumers[n].iter().any(|&c| !in_group(c))
            || g.outputs.contains(&n);
        if escapes {
            outs.push(n);
        }
    }
    (ext_in, outs)
}

/// Effective reuse-tile when the kernel is *not* explicitly tiled: what
/// the cache hierarchy grants a naive kernel. Larger L2 -> more free
/// reuse (calibration constants; Table 2's L2 column is 6/40/50 MB).
fn naive_reuse_tile(spec: &GpuSpec) -> f64 {
    match spec.l2_mb {
        0..=8 => 16.0,   // Volta-class
        9..=44 => 24.0,  // Ampere-class
        _ => 28.0,       // Hopper-class
    }
}

/// HBM traffic (bytes) of one kernel under its schedule.
fn kernel_traffic(kernel: &Kernel, g: &Graph, shapes: &[Vec<usize>],
                  spec: &GpuSpec) -> f64 {
    let (ext_in, outs) = kernel_io(kernel, g);
    let anchor = kernel.anchor(g);
    let anchor_node = &g.nodes[anchor];
    let sched = &kernel.schedule;
    let mut bytes = 0.0;

    // operand streams
    for &i in &ext_in {
        let n = numel(&shapes[i]) * 4.0;
        let is_contraction_operand = anchor_node.inputs.contains(&i)
            && anchor_node.op.class() == OpClass::Contraction;
        if is_contraction_operand {
            // reuse model: each operand is re-streamed once per tile of
            // the opposing parallel dimension
            let (reuse_m, reuse_n) = match sched.block_tile {
                Some((tm, tn, _)) => (tm as f64, tn as f64),
                None => (naive_reuse_tile(spec), naive_reuse_tile(spec)),
            };
            let passes = match &anchor_node.op {
                Op::MatMul | Op::BatchMatMul | Op::LstmCell => {
                    // A re-read N/Tn times, B re-read M/Tm times
                    let a_id = anchor_node.inputs[0];
                    let out_shape = &shapes[anchor];
                    if i == a_id {
                        (out_shape[out_shape.len() - 1] as f64 / reuse_n).max(1.0)
                    } else {
                        (out_shape[out_shape.len() - 2] as f64 / reuse_m).max(1.0)
                    }
                }
                Op::Conv2d { .. } => {
                    // weights re-read per output tile; activations re-read
                    // per filter tile — symmetric approximation
                    let f = shapes[anchor][1] as f64;
                    let x_id = anchor_node.inputs[0];
                    if i == x_id {
                        (f / reuse_m).max(1.0)
                    } else {
                        let spatial = (shapes[anchor][0] * shapes[anchor][2]
                            * shapes[anchor][3]) as f64;
                        (spatial / (reuse_m * reuse_n)).max(1.0).min(64.0)
                    }
                }
                Op::Attention => {
                    // K/V re-streamed per query tile
                    let s_q = shapes[anchor_node.inputs[0]][0] as f64;
                    if i == anchor_node.inputs[0] {
                        1.0
                    } else {
                        (s_q / reuse_m).max(1.0)
                    }
                }
                _ => 1.0,
            };
            bytes += n * passes;
        } else {
            bytes += n;
        }
    }

    // intra-kernel multi-pass penalty for reductions/normalisations that
    // are not tiled (naive softmax/layernorm re-reads its input per pass;
    // a block-tiled version is single-pass "online")
    for &n in &kernel.nodes {
        let cls = g.nodes[n].op.class();
        if cls == OpClass::Reduction && sched.block_tile.is_none() {
            let extra_passes = match g.nodes[n].op {
                Op::Softmax => 2.0,    // max pass + sum pass re-reads
                Op::LayerNorm => 2.0,  // mean + var passes
                Op::BatchNorm2d => 0.5,
                _ => 0.5,
            };
            bytes += numel(&shapes[g.nodes[n].inputs[0]]) * 4.0 * extra_passes;
        }
    }

    // attention without tiling materializes the S×S score/prob matrices
    if matches!(anchor_node.op, Op::Attention) && sched.block_tile.is_none() {
        let s_q = shapes[anchor_node.inputs[0]][0] as f64;
        let s_k = shapes[anchor_node.inputs[1]][0] as f64;
        bytes += s_q * s_k * 4.0 * 3.0; // write scores, read, write probs
    }

    // output stores
    for &o in &outs {
        bytes += numel(&shapes[o]) * 4.0;
    }
    bytes
}

/// Occupancy in (0, 1]: how much of the machine the schedule can fill.
fn occupancy(kernel: &Kernel, spec: &GpuSpec) -> f64 {
    match kernel.schedule.block_tile {
        None => 0.6, // plenty of tiny blocks, but poorly shaped
        Some(_) => {
            let smem = kernel.schedule.smem_bytes() as f64;
            if smem <= 0.0 {
                return 0.6;
            }
            // GEMM-class kernels tolerate low block-residency well (ILP
            // from register tiles); only a non-fitting schedule craters.
            match (spec.smem_bytes() as f64 / smem).floor() as usize {
                0 => 0.15, // does not fit: spills, serialisation
                1 => 0.55,
                2 => 0.80,
                3 => 0.90,
                _ => 1.0,
            }
        }
    }
}

/// Compute-efficiency ladder: fraction of peak FLOPs the schedule's inner
/// loop can sustain.
fn compute_eff(kernel: &Kernel) -> f64 {
    let s = &kernel.schedule;
    let mut eff: f64 = 0.12; // naive scalar inner loop
    if let Some((tm, tn, _)) = s.block_tile {
        eff = 0.45;
        if tm % 64 == 0 && tn % 64 == 0 {
            eff += 0.10; // MXU/tensor-core-aligned macro tile
        }
    }
    if s.reg_tile.is_some() {
        eff += 0.25; // register blocking: the big ILP win
    }
    if s.vector_width >= 4 {
        eff += 0.05;
    }
    eff.min(0.92)
}

/// Memory-efficiency: fraction of peak bandwidth the access pattern
/// sustains.
fn mem_eff(kernel: &Kernel) -> f64 {
    let s = &kernel.schedule;
    let mut eff: f64 = match s.loop_order {
        LoopOrder::Naive => 0.35,
        LoopOrder::Blocked => 0.75,
        LoopOrder::Coalesced => 0.90,
    };
    if s.vector_width >= 4 {
        eff += 0.08;
    } else if s.vector_width == 2 {
        eff += 0.04;
    }
    eff.min(0.98)
}

/// Comp/mem overlap from pipelining.
fn overlap(kernel: &Kernel) -> f64 {
    match kernel.schedule.pipeline_depth {
        0 | 1 => 0.15,
        2 => 0.55,
        3 => 0.85,
        _ => 0.88,
    }
}

/// Price one kernel.
pub fn kernel_time_us(kernel: &Kernel, g: &Graph, shapes: &[Vec<usize>],
                      spec: &GpuSpec) -> CostBreakdown {
    let flops: f64 = kernel.nodes.iter().map(|&n| op_flops(g, shapes, n)).sum();
    let bytes = kernel_traffic(kernel, g, shapes, spec);
    let occ = occupancy(kernel, spec);
    let ce = compute_eff(kernel);
    let me = mem_eff(kernel);
    let ov = overlap(kernel);

    // L2-resident bonus: small working sets stream from L2, not HBM
    let l2_bytes = spec.l2_mb as f64 * 1e6;
    let bw_mult = if bytes < l2_bytes * 0.5 { 1.8 } else { 1.0 };

    let t_comp = flops / (spec.peak_flops() * ce * (0.5 + 0.5 * occ)) * 1e6;
    let t_mem = bytes / (spec.peak_bw() * me * bw_mult * (0.6 + 0.4 * occ)) * 1e6;
    let time = t_comp.max(t_mem) + (1.0 - ov) * t_comp.min(t_mem)
        + spec.launch_overhead_us;
    CostBreakdown {
        flops,
        hbm_bytes: bytes,
        t_comp_us: t_comp,
        t_mem_us: t_mem,
        overlap: ov,
        occupancy: occ,
        compute_eff: ce,
        mem_eff: me,
        time_us: time,
    }
}

/// Price a whole program (kernels execute back-to-back).
pub fn program_time_us(p: &Program, g: &Graph, shapes: &[Vec<usize>],
                       spec: &GpuSpec) -> f64 {
    p.kernels
        .iter()
        .map(|k| kernel_time_us(k, g, shapes, spec).time_us)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{infer_shapes, Graph};
    use crate::kir::{lower_naive, Schedule};

    fn matmul_graph(m: usize, k: usize, n: usize) -> (Graph, Vec<Vec<usize>>) {
        let mut g = Graph::new("mm");
        let x = g.input("x", &[m, k]);
        let w = g.weight("w", &[k, n]);
        let mm = g.op(Op::MatMul, &[x, w]);
        g.mark_output(mm);
        let shapes = infer_shapes(&g);
        (g, shapes)
    }

    fn tiled(p: &Program, tile: (usize, usize, usize)) -> Program {
        let mut p = p.clone();
        p.kernels[0].schedule = Schedule {
            block_tile: Some(tile),
            reg_tile: Some((8, 8)),
            pipeline_depth: 2,
            loop_order: LoopOrder::Blocked,
            vector_width: 4,
        };
        p
    }

    #[test]
    fn tiling_cuts_traffic_and_time() {
        let (g, shapes) = matmul_graph(4096, 4096, 4096);
        let spec = GpuSpec::a100();
        let naive = lower_naive(&g);
        let opt = tiled(&naive, (128, 128, 32));
        let c_naive = kernel_time_us(&naive.kernels[0], &g, &shapes, &spec);
        let c_opt = kernel_time_us(&opt.kernels[0], &g, &shapes, &spec);
        assert!(c_opt.hbm_bytes < c_naive.hbm_bytes / 3.0);
        assert!(
            c_opt.time_us < c_naive.time_us / 4.0,
            "opt {:.0}us vs naive {:.0}us",
            c_opt.time_us,
            c_naive.time_us
        );
    }

    #[test]
    fn optimized_matmul_near_roofline() {
        let (g, shapes) = matmul_graph(4096, 4096, 4096);
        let spec = GpuSpec::a100();
        let opt = tiled(&lower_naive(&g), (128, 128, 32));
        let c = kernel_time_us(&opt.kernels[0], &g, &shapes, &spec);
        let roofline_us = c.flops / spec.peak_flops() * 1e6;
        let ratio = roofline_us / c.time_us;
        assert!(
            ratio > 0.5 && ratio <= 1.0,
            "achieved/roofline {ratio:.2} out of band"
        );
    }

    #[test]
    fn fusion_removes_intermediate_traffic() {
        let mut g = Graph::new("f");
        let x = g.input("x", &[2048, 2048]);
        let y = g.input("y", &[2048, 2048]);
        let a = g.op(Op::Add, &[x, y]);
        let r = g.op(Op::Relu, &[a]);
        g.mark_output(r);
        let shapes = infer_shapes(&g);
        let spec = GpuSpec::a100();
        let unfused = lower_naive(&g);
        let mut fused = unfused.clone();
        let k2 = fused.kernels.remove(1);
        fused.kernels[0].nodes.extend(k2.nodes);
        let t_un = program_time_us(&unfused, &g, &shapes, &spec);
        let t_fu = program_time_us(&fused, &g, &shapes, &spec);
        assert!(t_fu < t_un * 0.75, "fused {t_fu:.1} vs unfused {t_un:.1}");
    }

    #[test]
    fn faster_gpu_is_faster() {
        let (g, shapes) = matmul_graph(2048, 2048, 2048);
        let opt = tiled(&lower_naive(&g), (128, 128, 32));
        let tv = kernel_time_us(&opt.kernels[0], &g, &shapes, &GpuSpec::v100()).time_us;
        let ta = kernel_time_us(&opt.kernels[0], &g, &shapes, &GpuSpec::a100()).time_us;
        let th = kernel_time_us(&opt.kernels[0], &g, &shapes, &GpuSpec::h100()).time_us;
        assert!(th < ta && ta < tv, "V100 {tv:.0} A100 {ta:.0} H100 {th:.0}");
    }

    #[test]
    fn pipeline_improves_overlap_bound_time() {
        let (g, shapes) = matmul_graph(4096, 1024, 4096);
        let spec = GpuSpec::h100();
        let mut p = lower_naive(&g);
        p.kernels[0].schedule = Schedule {
            block_tile: Some((128, 128, 32)),
            reg_tile: Some((8, 8)),
            pipeline_depth: 1,
            loop_order: LoopOrder::Blocked,
            vector_width: 4,
        };
        let t1 = kernel_time_us(&p.kernels[0], &g, &shapes, &spec).time_us;
        p.kernels[0].schedule.pipeline_depth = 3;
        let t3 = kernel_time_us(&p.kernels[0], &g, &shapes, &spec).time_us;
        assert!(t3 < t1, "pipelined {t3:.1} vs unpipelined {t1:.1}");
    }

    #[test]
    fn untiled_attention_pays_for_score_matrix() {
        let mut g = Graph::new("att");
        let q = g.input("q", &[4096, 128]);
        let k = g.input("k", &[4096, 128]);
        let v = g.input("v", &[4096, 128]);
        let a = g.op(Op::Attention, &[q, k, v]);
        g.mark_output(a);
        let shapes = infer_shapes(&g);
        let spec = GpuSpec::a100();
        let naive = lower_naive(&g);
        let c_naive = kernel_time_us(&naive.kernels[0], &g, &shapes, &spec);
        let mut flash = naive.clone();
        flash.kernels[0].schedule = Schedule {
            block_tile: Some((128, 128, 64)),
            reg_tile: Some((8, 8)),
            pipeline_depth: 2,
            loop_order: LoopOrder::Blocked,
            vector_width: 4,
        };
        let c_flash = kernel_time_us(&flash.kernels[0], &g, &shapes, &spec);
        assert!(c_flash.hbm_bytes < c_naive.hbm_bytes / 4.0);
        assert!(c_flash.time_us < c_naive.time_us / 2.0);
    }

    #[test]
    fn occupancy_penalises_oversized_smem() {
        let (g, _shapes) = matmul_graph(1024, 1024, 1024);
        let mut p = lower_naive(&g);
        p.kernels[0].schedule.block_tile = Some((256, 256, 64));
        p.kernels[0].schedule.pipeline_depth = 2;
        // (256*64 + 64*256)*4*2 = 256KB > V100's 96KB; occupancy floor
        let occ = occupancy(&p.kernels[0], &GpuSpec::v100());
        assert!(occ <= 0.25);
    }
}
