//! Offline trajectory dataset (paper §4.2: "a representative offline
//! dataset comprising 60k trajectories, without benchmark instances").
//!
//! Trajectories are rolled out on the training corpus with a mixture of
//! exploration policies (random + heuristic ladders), recorded compactly
//! and persisted to a binary file. Replaying a trajectory through
//! [`crate::env::TreeEnv`] reproduces it bit-for-bit (edge-deterministic
//! environment), so the dataset doubles as the tree-structured
//! environment's warm cache.

mod gen;
mod store;

pub use gen::{generate, DatasetCfg, DatasetStats};
pub use store::{load_trajectories, save_trajectories, TrajStep, Trajectory};
