//! Trajectory generation: exploration rollouts over the training corpus,
//! parallel across tasks.

use super::store::{TrajStep, Trajectory};
use crate::engine::Session;
use crate::env::{EnvConfig, StepSignal, TreeEnv};
use crate::gpusim::GpuSpec;
use crate::microcode::{LlmProfile, ProfileId};
use crate::policy::{HeuristicPolicy, Policy, RandomPolicy};
use crate::tasks::Task;
use crate::util::{parallel::par_map, Rng};

/// Generation configuration. Memo policy and `--memo-store` persistence
/// live on the [`Session`] handed to [`generate`], not here.
#[derive(Clone, Debug)]
pub struct DatasetCfg {
    /// Episodes per task.
    pub per_task: usize,
    pub env: EnvConfig,
    pub seed: u64,
    pub threads: usize,
    /// Fraction of episodes rolled out by the heuristic ladder (rest are
    /// random exploration).
    pub heuristic_frac: f64,
}

impl Default for DatasetCfg {
    fn default() -> Self {
        DatasetCfg {
            per_task: 64,
            env: EnvConfig::default(),
            seed: 0xDA7A,
            threads: crate::util::parallel::default_threads(),
            heuristic_frac: 0.3,
        }
    }
}

/// Aggregate stats of a generated dataset.
#[derive(Clone, Debug, Default)]
pub struct DatasetStats {
    pub trajectories: usize,
    pub steps: usize,
    pub mean_reward: f64,
    pub mean_final_speedup: f64,
    pub correct_step_frac: f64,
}

pub fn stats(trajs: &[Trajectory]) -> DatasetStats {
    let steps: usize = trajs.iter().map(|t| t.steps.len()).sum();
    let correct = trajs
        .iter()
        .flat_map(|t| &t.steps)
        .filter(|s| s.signal_code == 3)
        .count();
    DatasetStats {
        trajectories: trajs.len(),
        steps,
        mean_reward: trajs.iter().map(|t| t.total_reward()).sum::<f64>()
            / trajs.len().max(1) as f64,
        mean_final_speedup: trajs
            .iter()
            .map(|t| t.final_speedup() as f64)
            .sum::<f64>()
            / trajs.len().max(1) as f64,
        correct_step_frac: correct as f64 / steps.max(1) as f64,
    }
}

pub fn signal_code(s: &StepSignal) -> u8 {
    match s {
        StepSignal::Rejected => 0,
        StepSignal::CompileFail => 1,
        StepSignal::WrongResult => 2,
        StepSignal::Correct { .. } => 3,
        StepSignal::Stop { .. } => 4,
    }
}

/// Generate trajectories over `tasks` (normally the training corpus) on
/// `spec` with the given micro-coding profile. The [`Session`]'s
/// thread-safe memo trio is shared across every worker: masks/pricing run
/// through one analysis + cost cache, and transitions pool in one edge
/// memo — warm-startable across runs via `--memo-store` (bit-identical
/// either way; determinism is guarded by rust/tests/pipeline.rs).
pub fn generate(tasks: &[Task], spec: &GpuSpec, profile_id: ProfileId,
                cfg: &DatasetCfg, session: &Session)
                -> (Vec<Trajectory>, DatasetStats) {
    let per_task_results = par_map(tasks, cfg.threads, |ti, task| {
        let mut out = Vec::with_capacity(cfg.per_task);
        let mut master = Rng::new(cfg.seed ^ (ti as u64) << 20);
        // one tree (one base seed) per task: episodes share the cache
        let tree_seed = master.next_u64();
        let mut env = TreeEnv::with_session(
            task,
            spec.clone(),
            LlmProfile::get(profile_id),
            cfg.env.clone(),
            tree_seed,
            session,
        );
        for ep in 0..cfg.per_task {
            env.reset();
            let mut rng = master.split(ep as u64);
            let mut heuristic = HeuristicPolicy::gemini_flash();
            let mut random = RandomPolicy;
            let use_heuristic = rng.bool(cfg.heuristic_frac);
            let mut steps = Vec::new();
            while !env.env.state.done {
                let mask = env.env.mask();
                let obs = env.env.observe(&mask);
                let policy: &mut dyn Policy = if use_heuristic {
                    &mut heuristic
                } else {
                    &mut random
                };
                let d = policy.act(&obs, &mask, &mut rng);
                // random/heuristic policies never pick invalid actions,
                // but freeform could; clamp to Stop on mask violation
                let action = if mask[d.action] { d.action } else {
                    crate::transform::STOP_ACTION
                };
                let r = env.step(action);
                steps.push(TrajStep {
                    action: action as u16,
                    signal_code: signal_code(&r.signal),
                    reward: r.reward as f32,
                    speedup: env.env.state.speedup as f32,
                });
            }
            out.push(Trajectory { task_idx: ti as u32, seed: tree_seed, steps });
        }
        out
    });
    let trajs: Vec<Trajectory> =
        per_task_results.into_iter().flatten().collect();
    let s = stats(&trajs);
    (trajs, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_expected_counts() {
        let tasks = crate::tasks::training_corpus(4);
        let cfg = DatasetCfg { per_task: 5, threads: 2, ..Default::default() };
        let (trajs, st) = generate(&tasks, &GpuSpec::a100(),
                                   ProfileId::GeminiFlash25, &cfg,
                                   &Session::default());
        assert_eq!(trajs.len(), 20);
        assert_eq!(st.trajectories, 20);
        assert!(st.steps >= 20, "every episode has at least the stop step");
        assert!(st.correct_step_frac > 0.1, "exploration finds valid steps");
    }

    #[test]
    fn generation_deterministic() {
        let tasks = crate::tasks::training_corpus(2);
        let cfg = DatasetCfg { per_task: 3, threads: 1, ..Default::default() };
        // distinct sessions: a warm memo must not change trajectories
        let (a, _) = generate(&tasks, &GpuSpec::v100(),
                              ProfileId::GeminiFlash25, &cfg,
                              &Session::default());
        let (b, _) = generate(&tasks, &GpuSpec::v100(),
                              ProfileId::GeminiFlash25, &cfg,
                              &Session::default());
        assert_eq!(a, b);
    }

    #[test]
    fn trajectories_end_with_stop() {
        let tasks = crate::tasks::training_corpus(2);
        let cfg = DatasetCfg { per_task: 4, threads: 1, ..Default::default() };
        let (trajs, _) = generate(&tasks, &GpuSpec::h100(),
                                  ProfileId::GeminiPro25, &cfg,
                                  &Session::default());
        for t in &trajs {
            assert_eq!(t.steps.last().unwrap().signal_code, 4,
                       "episode must end in Stop/truncation");
        }
    }
}
