//! Compact binary trajectory store.

use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

/// One recorded step. `signal_code`: 0=rejected, 1=compile-fail,
/// 2=wrong-result, 3=correct, 4=stop.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrajStep {
    pub action: u16,
    pub signal_code: u8,
    pub reward: f32,
    pub speedup: f32,
}

/// One episode over one task.
#[derive(Clone, Debug, PartialEq)]
pub struct Trajectory {
    /// Index into the generating corpus (order is deterministic).
    pub task_idx: u32,
    /// Episode seed (replays the exact tree path).
    pub seed: u64,
    pub steps: Vec<TrajStep>,
}

impl Trajectory {
    pub fn total_reward(&self) -> f64 {
        self.steps.iter().map(|s| s.reward as f64).sum()
    }

    pub fn final_speedup(&self) -> f32 {
        self.steps.last().map_or(1.0, |s| s.speedup)
    }
}

const MAGIC: &[u8; 8] = b"QMMCTRJ1";

pub fn save_trajectories(trajs: &[Trajectory], path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(trajs.len() as u64).to_le_bytes())?;
    for t in trajs {
        w.write_all(&t.task_idx.to_le_bytes())?;
        w.write_all(&t.seed.to_le_bytes())?;
        w.write_all(&(t.steps.len() as u32).to_le_bytes())?;
        for s in &t.steps {
            w.write_all(&s.action.to_le_bytes())?;
            w.write_all(&[s.signal_code])?;
            w.write_all(&s.reward.to_le_bytes())?;
            w.write_all(&s.speedup.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn load_trajectories(path: &Path) -> Result<Vec<Trajectory>> {
    let mut r = BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a trajectory file");
    }
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let n = u64::from_le_bytes(b8) as usize;
    if n > 50_000_000 {
        bail!("implausible trajectory count {n}");
    }
    let mut out = Vec::with_capacity(n);
    let mut b4 = [0u8; 4];
    let mut b2 = [0u8; 2];
    let mut b1 = [0u8; 1];
    for _ in 0..n {
        r.read_exact(&mut b4)?;
        let task_idx = u32::from_le_bytes(b4);
        r.read_exact(&mut b8)?;
        let seed = u64::from_le_bytes(b8);
        r.read_exact(&mut b4)?;
        let len = u32::from_le_bytes(b4) as usize;
        if len > 1_000 {
            bail!("implausible trajectory length {len}");
        }
        let mut steps = Vec::with_capacity(len);
        for _ in 0..len {
            r.read_exact(&mut b2)?;
            let action = u16::from_le_bytes(b2);
            r.read_exact(&mut b1)?;
            let signal_code = b1[0];
            r.read_exact(&mut b4)?;
            let reward = f32::from_le_bytes(b4);
            r.read_exact(&mut b4)?;
            let speedup = f32::from_le_bytes(b4);
            steps.push(TrajStep { action, signal_code, reward, speedup });
        }
        out.push(Trajectory { task_idx, seed, steps });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Vec<Trajectory> {
        vec![
            Trajectory {
                task_idx: 3,
                seed: 99,
                steps: vec![
                    TrajStep { action: 0, signal_code: 3, reward: 0.5, speedup: 1.4 },
                    TrajStep { action: 64, signal_code: 4, reward: 0.2, speedup: 1.4 },
                ],
            },
            Trajectory { task_idx: 7, seed: 100, steps: vec![] },
        ]
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("qimeng_traj_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let trajs = demo();
        save_trajectories(&trajs, &path).unwrap();
        assert_eq!(load_trajectories(&path).unwrap(), trajs);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("qimeng_traj_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"XXXXXXXX\0\0\0\0\0\0\0\0").unwrap();
        assert!(load_trajectories(&path).is_err());
    }

    #[test]
    fn stats_helpers() {
        let t = &demo()[0];
        assert!((t.total_reward() - 0.7).abs() < 1e-6);
        assert_eq!(t.final_speedup(), 1.4);
    }
}
