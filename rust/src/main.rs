//! `repro` — the qimeng-mtmc command line.
//!
//! Subcommands:
//!   specs                         print the simulated GPU table (Table 2)
//!   tasks [--suite S]             list benchmark suites and sizes
//!   dataset --out F [...]         generate the offline trajectory dataset
//!   train [--iters N] [...]       PPO-train the Macro-Thinking policy
//!   optimize --task ID [...]      optimize one task, show the schedule story
//!   eval --suite S [...]          evaluate a method over a suite
//!   table N                       regenerate paper table N (3,4,5,6,7)
//!   lint [--suite S|--task ID]    static schedule verifier over the corpus
//!   store fsck PATH [--fix]       check a --memo-store directory on disk
//!
//! Every optimizing command builds one [`Session`] from the shared
//! cache/persistence flags and threads it down the stack; the memo trio,
//! the `--memo-store` tier, and the stats report all live there.

use anyhow::{bail, Context, Result};
use qimeng_mtmc::dataset::{generate, save_trajectories, DatasetCfg};
use qimeng_mtmc::engine::Session;
use qimeng_mtmc::eval::{
    evaluate_in, roster_sweep, table3_methods, table4_methods,
    table6_variants, BatchCfg, BatchJob, BatchRunner, EvalCfg, MacroKind,
    Method,
};
use qimeng_mtmc::env::fsck_store;
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::kir::{
    lower_checked, lower_naive, verify, Diagnostic, Rule, Severity, TargetLang,
};
use qimeng_mtmc::microcode::ProfileId;
use qimeng_mtmc::paths;
use qimeng_mtmc::report::{metric_cells, Table};
use qimeng_mtmc::runtime::{
    load_params, save_params, ParamSet, PjrtRuntime, TrainState,
};
use qimeng_mtmc::tasks::{
    kernelbench_level, kernelbench_suite, training_corpus, tritonbench_g,
    tritonbench_t, Task,
};
use qimeng_mtmc::train::{train_ppo, PpoCfg};
use qimeng_mtmc::util::cli::Args;
use qimeng_mtmc::util::faults::FaultPlan;
use qimeng_mtmc::util::json::Json;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.cmd.as_str() {
        "specs" => cmd_specs(),
        "tasks" => cmd_tasks(&args),
        "dataset" => cmd_dataset(&args),
        "train" => cmd_train(&args),
        "optimize" => cmd_optimize(&args),
        "eval" => cmd_eval(&args),
        "table" => cmd_table(&args),
        "lint" => cmd_lint(&args),
        "store" => cmd_store(&args),
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command `{other}`\n{HELP}"),
    }
}

const HELP: &str = "\
repro — QiMeng-Kernel MTMC reproduction (see DESIGN.md)

USAGE: repro <command> [flags]

COMMANDS:
  specs                      simulated GPU specs (paper Table 2)
  tasks [--suite kb1|kb2|kb3|tbg|tbt|corpus]
  dataset --out data/trees.bin [--tasks 200] [--per-task 64] [--seed N]
          [--memo-store F]
  train [--iters 60] [--tasks 40] [--out data/policy.bin] [--gpu A100]
        [--memo-store F]
  optimize --task kb2_000_gemm_bias_act [--gpu A100] [--show-code]
           [--memo-store F] [--stats-json F]
  eval --suite kb2 [--gpu A100] [--method mtmc|greedy|<profile>] [--limit N]
       [--threads N] [--jsonl out.jsonl] [--resume] [--max-retries N]
       [--inject-faults SEED] [--memo-store F] [--stats-json F]
       [--no-cost-cache] [--no-analysis-cache] [--no-edge-memo]
                             (runs through the BatchRunner; pricing,
                              program analysis and transitions go through
                              the run's Session — one CostCache /
                              AnalysisCache / EdgeMemo trio shared by the
                              whole sweep unless the matching --no-* flag
                              is given; hit/miss/eviction stats on stderr,
                              or as one JSON object via --stats-json;
                              --memo-store persists the EdgeMemo across
                              runs as a directory of per-shard segment
                              files: warm-started at startup, compacted
                              to the live entries and flushed at exit
                              with only the dirty segments rewritten
                              (each via temp+rename, so a crash never
                              corrupts the store); a corrupt segment
                              cold-starts only its own shard, a missing
                              store = cold start, and a legacy
                              single-file store is migrated in place;
                              per-segment recovered/degraded/written/
                              skipped counters land in --stats-json; the
                              QIMENG_MEMO_CAPACITY env var bounds the
                              memo's entry count)
  table 3|4|6 [--limit N] [--threads N] [--jsonl F] [--resume]
       [--max-retries N] [--inject-faults SEED] [--memo-store F]
       [--stats-json F]
       [--no-cost-cache] [--no-analysis-cache] [--no-edge-memo]
                             batched table sweep
  table 5|7                  pointer to the bench binaries
  lint [--suite kb1|kb2|kb3|kb|tbg|tbt|corpus] [--task ID] [--gpu A100]
       [--json]
                             run the static schedule verifier over the
                             naive lowering of every task (default: all
                             benchmark suites); prints one line per
                             diagnostic, or one JSON object under
                             --json; exits 1 if any error-severity
                             diagnostic fires (warnings are advisory)
  store fsck <path> [--fix] [--stats-json F]
                             check a --memo-store directory: manifest,
                             per-segment occupancy, corrupt or missing
                             segments, and orphaned seg_*/temp files
                             (--fix deletes the orphans); exits 1 if any
                             segment is corrupt or missing

  Optimizing commands (dataset/train/optimize/eval/table) statically
  verify every candidate schedule before spending correctness trials on
  it; --no-static-gate disables that pre-verif gate, and the checked/
  rejected counters land in the stderr report and --stats-json.

  Fault tolerance (eval/table, see README \"Fault tolerance and resume\"):
  every (method, suite, gpu, task) unit runs isolated — a panicking unit
  becomes a status:\"panicked\" JSONL record instead of killing the sweep.
  --max-retries N   retry budget for transient unit/sink failures
                    (default 2); retried/recovered/exhausted counters
                    land in --stats-json under \"faults\"
  --inject-faults SEED  arm the deterministic fault plan (or set
                    QIMENG_FAULT_SEED); QIMENG_FAULT_KILL_AFTER=N aborts
                    after N sink writes, QIMENG_FAULT_BURST overrides
                    the per-fault burst (default 2 <= max-retries, so an
                    injected sweep converges to fault-free bytes)
  --resume          scan the --jsonl sink, truncate a torn final line,
                    skip already-recorded units and append the rest;
                    at --threads 1 the resumed sink is byte-identical
                    to an uninterrupted run
";

fn gpu(args: &Args) -> Result<GpuSpec> {
    let name = args.get_or("gpu", "A100");
    GpuSpec::by_name(name).with_context(|| format!("unknown GPU {name}"))
}

fn suite_tasks(name: &str) -> Result<Vec<Task>> {
    Ok(match name {
        "kb1" => kernelbench_level(1),
        "kb2" => kernelbench_level(2),
        "kb3" => kernelbench_level(3),
        "kb" => kernelbench_suite(),
        "tbg" => tritonbench_g(),
        "tbt" => tritonbench_t(),
        "corpus" => training_corpus(200),
        other => bail!("unknown suite `{other}`"),
    })
}

/// Build the run's [`Session`] from the shared cache/persistence flags:
/// the `--no-*` escape hatches disable individual memo tiers (and the
/// static pre-verif gate), `--memo-store <path>` adds the disk
/// persistence tier (ignored under `--no-edge-memo`, which leaves
/// nothing to persist), and `--inject-faults <seed>` (or
/// `QIMENG_FAULT_SEED`) arms the deterministic fault plan the sweep
/// engine's retry loop and the chaos CI job exercise.
fn session_from_args(args: &Args) -> Session {
    Session::builder()
        .cost_cache(!args.has("no-cost-cache"))
        .analysis_cache(!args.has("no-analysis-cache"))
        .edge_memo(!args.has("no-edge-memo"))
        .static_gate(!args.has("no-static-gate"))
        .memo_store(args.get("memo-store").map(std::path::PathBuf::from))
        .faults(FaultPlan::from_env_or(
            args.get("inject-faults").and_then(|v| v.parse().ok()),
        ))
        .build()
}

/// End-of-run bookkeeping shared by every command: flush the memo store
/// (a compacting pass — only live entries are written), print the
/// per-memo stderr report, and honor `--stats-json <path>` by writing
/// the full registry as one JSON object.
fn finish_session(args: &Args, session: &Session) -> Result<()> {
    session.finish();
    let stats = session.stats();
    stats.print();
    if let Some(path) = args.get("stats-json") {
        std::fs::write(path, format!("{}\n", stats.to_json()))
            .with_context(|| format!("write --stats-json {path}"))?;
    }
    Ok(())
}

/// BatchRunner configuration shared by `eval` and `table`, borrowing the
/// run's session for the whole sweep.
fn batch_runner<'s>(args: &Args, session: &'s Session)
                    -> Result<BatchRunner<'s>> {
    BatchRunner::new(
        BatchCfg {
            threads: args.usize_or(
                "threads",
                qimeng_mtmc::util::parallel::default_threads(),
            ),
            sink: args.get("jsonl").map(std::path::PathBuf::from),
            resume: args.has("resume"),
            max_retries: args.usize_or("max-retries", 2),
        },
        session,
    )
}

fn cmd_specs() -> Result<()> {
    let mut t = Table::new(
        "Simulated GPU platforms (paper Table 2)",
        &["Feature", "V100", "A100", "H100"],
    );
    let specs = GpuSpec::all();
    let row = |name: &str, f: &dyn Fn(&GpuSpec) -> String| {
        let mut cells = vec![name.to_string()];
        cells.extend(specs.iter().map(|s| f(s)));
        cells
    };
    t.row(row("Architecture", &|s| format!("{:?}", s.arch)));
    t.row(row("SMs", &|s| s.sms.to_string()));
    t.row(row("Global Memory (GB)", &|s| s.global_mem_gb.to_string()));
    t.row(row("Shared Memory / SM (KB)", &|s| s.smem_per_sm_kb.to_string()));
    t.row(row("L2 Cache (MB)", &|s| s.l2_mb.to_string()));
    t.row(row("Memory Bandwidth (GB/s)", &|s| format!("{:.0}", s.mem_bw_gbs)));
    t.row(row("FP32 TFLOPS", &|s| format!("{}", s.fp32_tflops)));
    print!("{}", t.render());
    Ok(())
}

fn cmd_tasks(args: &Args) -> Result<()> {
    let which = args.get_or("suite", "all");
    let suites: Vec<(&str, Vec<Task>)> = if which == "all" {
        vec![
            ("kb1", kernelbench_level(1)),
            ("kb2", kernelbench_level(2)),
            ("kb3", kernelbench_level(3)),
            ("tbg", tritonbench_g()),
            ("tbt", tritonbench_t()),
        ]
    } else {
        vec![(which, suite_tasks(which)?)]
    };
    for (name, tasks) in suites {
        println!("{name}: {} tasks", tasks.len());
        if args.has("verbose") {
            for t in &tasks {
                println!(
                    "  {}  ops={} family={}",
                    t.id,
                    t.complexity(),
                    t.family.label()
                );
            }
        }
    }
    Ok(())
}

fn cmd_dataset(args: &Args) -> Result<()> {
    let out = std::path::PathBuf::from(args.get_or("out", "data/trees.bin"));
    let n_tasks = args.usize_or("tasks", 200);
    let session = session_from_args(args);
    let cfg = DatasetCfg {
        per_task: args.usize_or("per-task", 64),
        seed: args.u64_or("seed", 0xDA7A),
        threads: args.usize_or(
            "threads",
            qimeng_mtmc::util::parallel::default_threads(),
        ),
        ..Default::default()
    };
    let tasks = training_corpus(n_tasks);
    let spec = gpu(args)?;
    eprintln!(
        "generating {} x {} episodes on {}...",
        n_tasks, cfg.per_task, spec.name
    );
    let t0 = std::time::Instant::now();
    let (trajs, stats) =
        generate(&tasks, &spec, ProfileId::GeminiFlash25, &cfg, &session);
    finish_session(args, &session)?;
    save_trajectories(&trajs, &out)?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "wrote {} trajectories ({} steps) to {} in {:.1}s ({:.0} steps/s)",
        stats.trajectories,
        stats.steps,
        out.display(),
        dt,
        stats.steps as f64 / dt
    );
    println!(
        "mean reward {:.3}, mean final speedup {:.2}x, correct-step rate {:.0}%",
        stats.mean_reward,
        stats.mean_final_speedup,
        stats.correct_step_frac * 100.0
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = PjrtRuntime::load(&paths::artifacts_dir())
        .context("load artifacts (run `make artifacts`)")?;
    let tasks = training_corpus(args.usize_or("tasks", 40));
    let spec = gpu(args)?;
    let session = session_from_args(args);
    let cfg = PpoCfg {
        iterations: args.usize_or("iters", 60),
        seed: args.u64_or("seed", 0x9902),
        ..Default::default()
    };
    let params = ParamSet::init(&rt.meta.raw, cfg.seed ^ 0x11)?;
    let mut state = TrainState::new(params);
    let logs = train_ppo(&rt, &mut state, &tasks, &spec, &cfg, &session)?;
    finish_session(args, &session)?;
    let default_out = paths::default_policy_path();
    let out = std::path::PathBuf::from(
        args.get_or("out", default_out.to_str().unwrap()),
    );
    save_params(&state.params, &out)?;
    let first = logs.first().unwrap();
    let last = logs.last().unwrap();
    println!(
        "trained {} iters on {}: reward {:+.3} -> {:+.3}, speedup {:.2}x -> {:.2}x",
        logs.len(),
        spec.name,
        first.mean_episode_reward,
        last.mean_episode_reward,
        first.mean_final_speedup,
        last.mean_final_speedup
    );
    println!("saved policy to {}", out.display());
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<()> {
    let id = args.get("task").context("--task <id> required")?;
    let all: Vec<Task> = kernelbench_suite()
        .into_iter()
        .chain(tritonbench_g())
        .chain(tritonbench_t())
        .collect();
    let task = all
        .iter()
        .find(|t| t.id == id)
        .with_context(|| format!("no task `{id}` (see `repro tasks`)"))?;
    let spec = gpu(args)?;
    let cfg = EvalCfg { seed: args.u64_or("seed", 1), ..Default::default() };
    let shapes = qimeng_mtmc::graph::infer_shapes(&task.graph);

    // one-task session: the lookahead below re-prices sibling candidates
    // and re-analyzes the state every step, so even here the memo trio
    // pays for itself
    let session = session_from_args(args);
    let mut env = qimeng_mtmc::env::OptimEnv::with_session(
        task,
        spec.clone(),
        qimeng_mtmc::microcode::LlmProfile::get(ProfileId::GeminiPro25),
        cfg.env.clone(),
        cfg.seed,
        &session,
    );
    println!("task {} on {} | eager {:.1}us", task.id, spec.name, env.eager_us);
    println!("step  0: naive lowering, speedup {:.2}x", env.state.speedup);
    let mut step = 1;
    let mut failed: std::collections::HashSet<usize> = Default::default();
    while !env.state.done {
        // the same cached greedy lookahead the eval harness runs
        let choice = qimeng_mtmc::eval::greedy_best_action_excluding(
            &env.state.program, task, &shapes, &spec, &failed, &env.pricer,
            &env.analyzer,
        );
        let Some((a, _)) = choice else { break };
        let act = qimeng_mtmc::transform::decode_action(a);
        let before = env.state.path_hash;
        let r = env.step(a);
        if env.state.path_hash == before {
            failed.insert(a);
        } else {
            failed.clear();
        }
        println!(
            "step {step:>2}: {:?} on region {} -> {}, speedup {:.2}x",
            act.opt,
            act.region,
            signal_brief(&r),
            env.state.speedup
        );
        step += 1;
    }
    println!("best speedup {:.2}x over eager", env.state.best_speedup);
    if args.has("show-code") {
        let lang = if args.get_or("lang", "triton") == "cuda" {
            TargetLang::Cuda
        } else {
            TargetLang::Triton
        };
        // both renders go through the session's render memo, so a
        // best program identical to the naive lowering prints from one
        // cached render
        let naive = lower_naive(&task.graph);
        println!(
            "\n--- naive ---\n{}",
            session.render_cached(&naive, &task.graph, &shapes, lang)
        );
        println!(
            "--- optimized ---\n{}",
            session.render_cached(&env.state.best_program, &task.graph,
                                  &shapes, lang)
        );
    }
    finish_session(args, &session)?;
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let mut tasks = suite_tasks(args.get_or("suite", "kb2"))?;
    if let Some(limit) = args.get("limit") {
        tasks.truncate(limit.parse()?);
    }
    let spec = gpu(args)?;
    let session = session_from_args(args);
    let cfg = EvalCfg {
        seed: args.u64_or("seed", 0xE7A1),
        ..Default::default()
    };
    let method = match args.get_or("method", "mtmc") {
        "mtmc" => Method::Mtmc {
            macro_kind: MacroKind::LearnedOrGreedy {
                params_path: Some(paths::default_policy_path()),
            },
            micro: ProfileId::GeminiPro25,
        },
        "greedy" => Method::Mtmc {
            macro_kind: MacroKind::GreedyLookahead,
            micro: ProfileId::GeminiPro25,
        },
        other => Method::Baseline { profile: profile_by_name(other)? },
    };
    // The learned policy (pjrt builds with trained params + artifacts) is
    // not Sync and cannot ride the sharded unit queue: route exactly that
    // case through the sequential `evaluate_in` path so "mtmc" still
    // means the learned policy when one exists. The probe stays cheap
    // (params parse + meta.json existence) — evaluate_in() itself
    // performs the real artifact compilation, and falls back to the same
    // greedy surrogate if that load fails. Stub builds always take the
    // BatchRunner arm. Both arms share the one session, so warm-start,
    // flush, and stats behave identically either way.
    let learned_available = matches!(
        &method,
        Method::Mtmc {
            macro_kind: MacroKind::LearnedOrGreedy { params_path: Some(pp) },
            ..
        } if load_params(pp).is_ok()
            && paths::artifacts_dir().join("meta.json").exists()
    );
    let r = if learned_available {
        eprintln!(
            "(trained params + artifacts present: sequential evaluate() \
             path — learned policy if the runtime loads, greedy otherwise)"
        );
        evaluate_in(&method, &tasks, &spec, &cfg, &session)
    } else {
        let runner = batch_runner(args, &session)?;
        let jobs = [BatchJob { method, gpu: spec, tasks: tasks.into(), cfg }];
        let results = runner.run(&jobs);
        anyhow::ensure!(
            !runner.sink_failed(),
            "JSONL sink reported I/O failures; output is truncated"
        );
        results.into_iter().next().unwrap()
    };
    finish_session(args, &session)?;
    let mut t = Table::new(
        &format!("{} on {} ({})", r.method, r.suite, r.gpu),
        &["Method", "CallAcc(%)", "ExecAcc(%)", "fast1/fast2(%)", "Mean Speedup"],
    );
    t.row(metric_cells(&r, true));
    print!("{}", t.render());
    Ok(())
}

fn profile_by_name(name: &str) -> Result<ProfileId> {
    use ProfileId::*;
    Ok(match name.to_ascii_lowercase().as_str() {
        "gemini-pro" => GeminiPro25,
        "gemini-flash" => GeminiFlash25,
        "claude-37" => Claude37Sonnet,
        "claude-4" => Claude4Sonnet,
        "o4-mini" => O4Mini,
        "gpt-4o" => Gpt4o,
        "deepseek-r1" => DeepSeekR1,
        "deepseek-v3" => DeepSeekV3,
        "nemotron" => LlamaNemotron,
        "qwen3" => Qwen3,
        "qwen-coder" => QwenCoder32B,
        "gemini-cli" => GeminiCli,
        "kevin" => Kevin32B,
        "kernelllm" => KernelLlm,
        other => bail!("unknown profile `{other}`"),
    })
}

fn cmd_table(args: &Args) -> Result<()> {
    let n: usize = args
        .positional
        .first()
        .context("table number required (3,4,5,6,7)")?
        .parse()
        .context("table number must be an integer")?;
    let limit = args.usize_or("limit", 12);
    match n {
        3 => {
            let methods = table3_methods(Some(paths::default_policy_path()));
            let spec = gpu(args)?;
            let session = session_from_args(args);
            let runner = batch_runner(args, &session)?;
            let blocks: Vec<(GpuSpec, Vec<Task>)> = (1..=3usize)
                .map(|level| {
                    let mut tasks = kernelbench_level(level);
                    tasks.truncate(limit);
                    (spec.clone(), tasks)
                })
                .collect();
            let jobs = roster_sweep(&methods, &blocks);
            let results = runner.run(&jobs);
            for (li, level) in (1..=3usize).enumerate() {
                let mut t = Table::new(
                    &format!(
                        "Table 3 — KernelBench Level {level} on {} \
                         ({} tasks/method, BatchRunner)",
                        spec.name,
                        blocks[li].1.len()
                    ),
                    &["Method", "Accuracy(%)", "fast1/fast2(%)",
                      "Mean Speedup"],
                );
                for r in &results[li * methods.len()..(li + 1) * methods.len()] {
                    t.row(metric_cells(r, false));
                }
                print!("{}", t.render());
            }
            anyhow::ensure!(
                !runner.sink_failed(),
                "JSONL sink reported I/O failures; output is truncated"
            );
            finish_session(args, &session)?;
        }
        4 => {
            let methods = table4_methods(Some(paths::default_policy_path()));
            let spec = GpuSpec::a100();
            let session = session_from_args(args);
            let runner = batch_runner(args, &session)?;
            let suites = [
                ("TRITONBENCH-G", tritonbench_g()),
                ("TRITONBENCH-T", tritonbench_t()),
            ];
            let blocks: Vec<(GpuSpec, Vec<Task>)> = suites
                .iter()
                .map(|(_, tasks)| {
                    let mut tasks = tasks.clone();
                    tasks.truncate(limit);
                    (spec.clone(), tasks)
                })
                .collect();
            let jobs = roster_sweep(&methods, &blocks);
            let results = runner.run(&jobs);
            for (si, (name, _)) in suites.iter().enumerate() {
                let mut t = Table::new(
                    &format!(
                        "Table 4 — {name} on A100 ({} tasks/method, \
                         BatchRunner)",
                        blocks[si].1.len()
                    ),
                    &["Method", "CallAcc(%)", "ExecAcc(%)", "fast1/fast2(%)",
                      "Mean Speedup"],
                );
                for r in &results[si * methods.len()..(si + 1) * methods.len()] {
                    t.row(metric_cells(r, true));
                }
                print!("{}", t.render());
            }
            anyhow::ensure!(
                !runner.sink_failed(),
                "JSONL sink reported I/O failures; output is truncated"
            );
            finish_session(args, &session)?;
        }
        6 => {
            let spec = GpuSpec::a100();
            let session = session_from_args(args);
            let runner = batch_runner(args, &session)?;
            let variants = table6_variants();
            let mut jobs = Vec::new();
            for (_, method) in &variants {
                for level in 1..=3usize {
                    let mut tasks = kernelbench_level(level);
                    tasks.truncate(limit);
                    jobs.push(BatchJob::new(method.clone(), spec.clone(), tasks));
                }
            }
            let results = runner.run(&jobs);
            let mut t = Table::new(
                &format!(
                    "Table 6 — multi-step vs single-pass on A100 \
                     ({limit} tasks/level, BatchRunner)"
                ),
                &["Method", "L1 Acc/Speedup", "L2 Acc/Speedup",
                  "L3 Acc/Speedup"],
            );
            for (vi, (name, _)) in variants.iter().enumerate() {
                let mut cells = vec![name.clone()];
                for r in &results[vi * 3..(vi + 1) * 3] {
                    cells.push(format!(
                        "{:.0}% / {:.2}",
                        r.metrics.exec_acc * 100.0,
                        r.metrics.mean_speedup
                    ));
                }
                t.row(cells);
            }
            print!("{}", t.render());
            anyhow::ensure!(
                !runner.sink_failed(),
                "JSONL sink reported I/O failures; output is truncated"
            );
            finish_session(args, &session)?;
        }
        5 | 7 => println!(
            "table {n} is regenerated by `cargo bench --bench table{n}` \
             (per-variant seeds; see the bench source)"
        ),
        other => bail!("unknown table {other} (3,4,5,6,7)"),
    }
    Ok(())
}

/// `repro lint`: run the static verifier over the naive lowering of a
/// task corpus (every benchmark suite by default) and report each
/// diagnostic. Exit status 1 iff any error-severity diagnostic fires —
/// warnings (tile overhang, remainder loops, vector-width mismatches)
/// are advisory and never fail the lint.
fn cmd_lint(args: &Args) -> Result<()> {
    let tasks: Vec<Task> = if let Some(id) = args.get("task") {
        let all: Vec<Task> = kernelbench_suite()
            .into_iter()
            .chain(tritonbench_g())
            .chain(tritonbench_t())
            .collect();
        let task = all
            .into_iter()
            .find(|t| t.id == id)
            .with_context(|| format!("no task `{id}` (see `repro tasks`)"))?;
        vec![task]
    } else if let Some(suite) = args.get("suite") {
        suite_tasks(suite)?
    } else {
        kernelbench_suite()
            .into_iter()
            .chain(tritonbench_g())
            .chain(tritonbench_t())
            .collect()
    };
    let spec = gpu(args)?;
    let mut findings: Vec<(String, Diagnostic)> = Vec::new();
    for task in &tasks {
        let shapes = qimeng_mtmc::graph::infer_shapes(&task.graph);
        let diags = match lower_checked(&task.graph) {
            Ok(p) => verify(&p, &task.graph, &shapes, &spec),
            // a graph the checked lowering itself rejects is corpus
            // damage — same bucket as the verifier's structural tier
            Err(e) => vec![Diagnostic {
                rule: Rule::Structure,
                kernel: None,
                severity: Severity::Error,
                msg: e,
            }],
        };
        findings.extend(diags.into_iter().map(|d| (task.id.clone(), d)));
    }
    let errors = findings
        .iter()
        .filter(|(_, d)| d.severity == Severity::Error)
        .count();
    let warnings = findings.len() - errors;
    if args.has("json") {
        let list: Vec<Json> = findings
            .iter()
            .map(|(id, d)| {
                Json::obj(vec![
                    ("task", Json::from(id.as_str())),
                    ("rule", Json::from(d.rule.name())),
                    ("kernel", d.kernel.map_or(Json::Null, Json::from)),
                    ("severity", Json::from(d.severity.to_string())),
                    ("msg", Json::from(d.msg.as_str())),
                ])
            })
            .collect();
        // per-rule diagnostic counts: which verifier rules actually fire
        // over this corpus, without consumers re-tallying the list
        let mut rules: std::collections::BTreeMap<String, Json> =
            Default::default();
        for (_, d) in &findings {
            let n = rules
                .get(d.rule.name())
                .and_then(Json::as_usize)
                .unwrap_or(0);
            rules.insert(d.rule.name().to_string(), Json::from(n + 1));
        }
        let out = Json::obj(vec![
            ("gpu", Json::from(spec.name.as_str())),
            ("tasks", Json::from(tasks.len())),
            ("errors", Json::from(errors)),
            ("warnings", Json::from(warnings)),
            ("rules", Json::Obj(rules)),
            ("diagnostics", Json::Arr(list)),
        ]);
        println!("{out}");
    } else {
        for (id, d) in &findings {
            println!("{id}: {d}");
        }
        println!(
            "lint: {} task(s) on {}: {} error(s), {} warning(s)",
            tasks.len(),
            spec.name,
            errors,
            warnings
        );
    }
    if errors > 0 {
        std::process::exit(1);
    }
    Ok(())
}

/// `repro store fsck <path>`: integrity + occupancy check of a
/// `--memo-store` directory. Prints the manifest header, per-segment
/// entry counts, corrupt/missing segments and orphaned files; `--fix`
/// deletes the orphans. Exit status 1 iff a live segment is corrupt or
/// missing (orphans alone are not damage).
fn cmd_store(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("fsck") => {}
        Some(other) => {
            bail!("unknown store subcommand `{other}` (expected `fsck`)")
        }
        None => bail!("usage: repro store fsck <path> [--fix]"),
    }
    let path = args
        .positional
        .get(1)
        .map(String::as_str)
        .or_else(|| args.get("memo-store"))
        .context("usage: repro store fsck <path> [--fix]")?;
    let report = fsck_store(std::path::Path::new(path), args.has("fix"))?;
    println!(
        "store {path}: {} shards, capacity {}, {} live entries",
        report.shards, report.capacity, report.entries
    );
    for seg in &report.segments {
        println!(
            "  seg_{:02}.bin  {:>7} entries  {:>9} bytes{}",
            seg.index,
            seg.entries,
            seg.bytes,
            if seg.ok { "" } else { "  CORRUPT" }
        );
    }
    if report.missing_segments > 0 {
        println!("  missing segment files: {}", report.missing_segments);
    }
    if !report.orphans.is_empty() {
        let state = if report.orphans_removed {
            "removed"
        } else {
            "use --fix to remove"
        };
        println!("orphans ({state}): {}", report.orphans.join(", "));
    }
    if let Some(out) = args.get("stats-json") {
        let segs: Vec<Json> = report
            .segments
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("index", Json::from(s.index)),
                    ("entries", Json::from(s.entries)),
                    ("bytes", Json::from(s.bytes as usize)),
                    ("ok", Json::from(s.ok)),
                ])
            })
            .collect();
        let j = Json::obj(vec![(
            "store_fsck",
            Json::obj(vec![
                ("path", Json::from(path)),
                ("shards", Json::from(report.shards)),
                ("capacity", Json::from(report.capacity as usize)),
                ("entries", Json::from(report.entries)),
                ("missing_segments", Json::from(report.missing_segments)),
                ("corrupt_segments", Json::from(report.corrupt_segments)),
                (
                    "orphans",
                    Json::Arr(
                        report
                            .orphans
                            .iter()
                            .map(|o| Json::from(o.as_str()))
                            .collect(),
                    ),
                ),
                ("orphans_removed", Json::from(report.orphans_removed)),
                ("segments", Json::Arr(segs)),
            ]),
        )]);
        std::fs::write(out, format!("{j}\n"))
            .with_context(|| format!("write --stats-json {out}"))?;
    }
    if report.corrupt_segments > 0 || report.missing_segments > 0 {
        std::process::exit(1);
    }
    Ok(())
}

fn signal_brief(r: &qimeng_mtmc::env::StepResult) -> &'static str {
    use qimeng_mtmc::env::StepSignal::*;
    match r.signal {
        CompileFail => "compile-fail",
        WrongResult => "wrong-result",
        Rejected => "rejected",
        Correct { .. } => "ok",
        Stop { .. } => "stop",
    }
}
