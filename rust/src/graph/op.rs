//! Operator vocabulary. Covers the ops named by KernelBench levels 1-3 and
//! TritonBench (Table 1 of the paper): GEMM/conv/softmax singles, fused
//! subgraphs, and network building blocks (LSTM cell, attention, norms).

/// An operator applied to one or two inputs (weights are separate graph
/// inputs, so e.g. `MatMul` has two predecessors).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Op {
    /// Graph input placeholder (activations or weights).
    Input,
    /// Dense matmul [m,k]x[k,n].
    MatMul,
    /// Batched matmul [b,m,k]x[b,k,n].
    BatchMatMul,
    /// conv2d NCHW with stride/pad.
    Conv2d { stride: usize, pad: usize },
    /// Elementwise unary.
    Relu,
    Gelu,
    Sigmoid,
    Tanh,
    Exp,
    Sqrt,
    /// Scale by constant.
    Scale(f32),
    /// Elementwise binary (broadcasting).
    Add,
    Sub,
    Mul,
    Div,
    Max,
    /// Bias add (alias of Add with vector rhs; kept distinct because
    /// epilogue-fusion treats it specially).
    BiasAdd,
    /// Row softmax over last axis.
    Softmax,
    /// LayerNorm over last axis.
    LayerNorm,
    /// BatchNorm2d (inference) — stats are inputs 2 and 3.
    BatchNorm2d,
    /// Reductions over last axis.
    ReduceSum,
    ReduceMax,
    ReduceMean,
    ArgMax,
    CumSum,
    /// 2-D max pooling.
    MaxPool2d { k: usize, stride: usize },
    /// Global average pooling NCHW -> NC.
    GlobalAvgPool,
    /// Single-head scaled-dot-product attention over (q, k, v).
    Attention,
    /// One LSTM cell step over (x, h, c, w_ih, w_hh) -> h' (c' internal).
    LstmCell,
    /// 2-D transpose.
    Transpose2,
}

/// Coarse roofline class used by the cost model and region analysis.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// Dense contraction (MatMul/Conv/Attention core): compute-bound at
    /// good schedules.
    Contraction,
    /// Elementwise / bias / scale: pure memory-bound streamers.
    Elementwise,
    /// Row/channel reductions + normalisations + pooling: memory-bound
    /// with reuse along the reduced axis.
    Reduction,
    /// Data movement only.
    Movement,
    /// Graph input.
    Input,
}

impl Op {
    pub fn class(&self) -> OpClass {
        use Op::*;
        match self {
            Input => OpClass::Input,
            MatMul | BatchMatMul | Conv2d { .. } | Attention | LstmCell => {
                OpClass::Contraction
            }
            Relu | Gelu | Sigmoid | Tanh | Exp | Sqrt | Scale(_) | Add | Sub
            | Mul | Div | Max | BiasAdd => OpClass::Elementwise,
            Softmax | LayerNorm | BatchNorm2d | ReduceSum | ReduceMax
            | ReduceMean | ArgMax | CumSum | MaxPool2d { .. }
            | GlobalAvgPool => OpClass::Reduction,
            Transpose2 => OpClass::Movement,
        }
    }

    /// Number of tensor inputs the op consumes.
    pub fn arity(&self) -> usize {
        use Op::*;
        match self {
            Input => 0,
            Relu | Gelu | Sigmoid | Tanh | Exp | Sqrt | Scale(_) | Softmax
            | LayerNorm | ReduceSum | ReduceMax | ReduceMean | ArgMax
            | CumSum | MaxPool2d { .. } | GlobalAvgPool | Transpose2 => 1,
            MatMul | BatchMatMul | Conv2d { .. } | Add | Sub | Mul | Div
            | Max | BiasAdd => 2,
            Attention => 3,
            BatchNorm2d => 3,
            LstmCell => 5,
        }
    }

    /// Short mnemonic used in kernel names and pretty-printing.
    pub fn mnemonic(&self) -> &'static str {
        use Op::*;
        match self {
            Input => "in",
            MatMul => "matmul",
            BatchMatMul => "bmm",
            Conv2d { .. } => "conv2d",
            Relu => "relu",
            Gelu => "gelu",
            Sigmoid => "sigmoid",
            Tanh => "tanh",
            Exp => "exp",
            Sqrt => "sqrt",
            Scale(_) => "scale",
            Add => "add",
            Sub => "sub",
            Mul => "mul",
            Div => "div",
            Max => "max",
            BiasAdd => "bias",
            Softmax => "softmax",
            LayerNorm => "layernorm",
            BatchNorm2d => "batchnorm",
            ReduceSum => "rsum",
            ReduceMax => "rmax",
            ReduceMean => "rmean",
            ArgMax => "argmax",
            CumSum => "cumsum",
            MaxPool2d { .. } => "maxpool",
            GlobalAvgPool => "gavgpool",
            Attention => "attention",
            LstmCell => "lstmcell",
            Transpose2 => "transpose",
        }
    }

    /// Whether epilogue-fusion may absorb this op into a producer kernel.
    pub fn fusible_as_epilogue(&self) -> bool {
        matches!(self.class(), OpClass::Elementwise)
            || matches!(self, Op::Softmax | Op::ReduceMax | Op::ReduceSum
                             | Op::ReduceMean)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_partition_ops() {
        assert_eq!(Op::MatMul.class(), OpClass::Contraction);
        assert_eq!(Op::Relu.class(), OpClass::Elementwise);
        assert_eq!(Op::Softmax.class(), OpClass::Reduction);
        assert_eq!(Op::Transpose2.class(), OpClass::Movement);
    }

    #[test]
    fn arities() {
        assert_eq!(Op::Input.arity(), 0);
        assert_eq!(Op::Relu.arity(), 1);
        assert_eq!(Op::MatMul.arity(), 2);
        assert_eq!(Op::Attention.arity(), 3);
        assert_eq!(Op::LstmCell.arity(), 5);
    }

    #[test]
    fn epilogue_fusibility() {
        assert!(Op::Relu.fusible_as_epilogue());
        assert!(Op::BiasAdd.fusible_as_epilogue());
        assert!(Op::Softmax.fusible_as_epilogue());
        assert!(!Op::MatMul.fusible_as_epilogue());
        assert!(!Op::Conv2d { stride: 1, pad: 0 }.fusible_as_epilogue());
    }
}
