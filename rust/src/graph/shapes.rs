//! Shape inference over the operator graph. Every node gets a concrete
//! shape (the task suites always fix input shapes), which the lowering,
//! cost model and featurizer all consume.

use super::graph_def::Graph;
use super::op::Op;

/// Infer the shape of every node. Panics on rank/shape mismatches —
/// task-suite construction is the only caller building new graphs, and it
/// is exhaustively covered by tests.
pub fn infer_shapes(g: &Graph) -> Vec<Vec<usize>> {
    let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(g.nodes.len());
    for (id, node) in g.nodes.iter().enumerate() {
        let s = |i: usize| -> &Vec<usize> { &shapes[node.inputs[i]] };
        let shape = match &node.op {
            Op::Input => node
                .input_shape
                .clone()
                .unwrap_or_else(|| panic!("input {id} missing shape")),
            Op::MatMul => {
                let (a, b) = (s(0), s(1));
                assert_eq!(a.len(), 2, "matmul lhs rank");
                assert_eq!(b.len(), 2, "matmul rhs rank");
                assert_eq!(a[1], b[0], "matmul k mismatch in {}", node.name);
                vec![a[0], b[1]]
            }
            Op::BatchMatMul => {
                let (a, b) = (s(0), s(1));
                assert_eq!(a.len(), 3);
                assert_eq!(b.len(), 3);
                assert_eq!(a[0], b[0]);
                assert_eq!(a[2], b[1]);
                vec![a[0], a[1], b[2]]
            }
            Op::Conv2d { stride, pad } => {
                let (x, w) = (s(0), s(1));
                assert_eq!(x.len(), 4);
                assert_eq!(w.len(), 4);
                assert_eq!(x[1], w[1], "conv channels");
                let oh = (x[2] + 2 * pad - w[2]) / stride + 1;
                let ow = (x[3] + 2 * pad - w[3]) / stride + 1;
                vec![x[0], w[0], oh, ow]
            }
            Op::Relu | Op::Gelu | Op::Sigmoid | Op::Tanh | Op::Exp | Op::Sqrt
            | Op::Scale(_) | Op::Softmax | Op::LayerNorm | Op::CumSum => {
                s(0).clone()
            }
            Op::Add | Op::Sub | Op::Mul | Op::Div | Op::Max => {
                broadcast_shape(s(0), s(1))
            }
            Op::BiasAdd => {
                let (x, b) = (s(0), s(1));
                assert_eq!(b.len(), 1);
                assert_eq!(*x.last().unwrap(), b[0], "bias length");
                x.clone()
            }
            Op::BatchNorm2d => {
                let x = s(0);
                assert_eq!(x.len(), 4);
                assert_eq!(s(1).len(), 1);
                assert_eq!(s(2).len(), 1);
                x.clone()
            }
            Op::ReduceSum | Op::ReduceMax | Op::ReduceMean | Op::ArgMax => {
                let x = s(0);
                assert!(!x.is_empty());
                x[..x.len() - 1].to_vec()
            }
            Op::MaxPool2d { k, stride } => {
                let x = s(0);
                assert_eq!(x.len(), 4);
                vec![x[0], x[1], (x[2] - k) / stride + 1, (x[3] - k) / stride + 1]
            }
            Op::GlobalAvgPool => {
                let x = s(0);
                assert_eq!(x.len(), 4);
                vec![x[0], x[1]]
            }
            Op::Attention => {
                let (q, k, v) = (s(0), s(1), s(2));
                assert_eq!(q.len(), 2);
                assert_eq!(q[1], k[1], "attention dim");
                assert_eq!(k[0], v[0], "attention seq");
                vec![q[0], v[1]]
            }
            Op::LstmCell => {
                let (x, h) = (s(0), s(1));
                assert_eq!(x.len(), 2);
                assert_eq!(h.len(), 2);
                // w_ih: [i, 4u], w_hh: [u, 4u]
                assert_eq!(s(3)[0], x[1]);
                assert_eq!(s(3)[1], 4 * h[1]);
                assert_eq!(s(4)[0], h[1]);
                h.clone()
            }
            Op::Transpose2 => {
                let x = s(0);
                assert_eq!(x.len(), 2);
                vec![x[1], x[0]]
            }
        };
        shapes.push(shape);
    }
    shapes
}

fn broadcast_shape(a: &[usize], b: &[usize]) -> Vec<usize> {
    let rank = a.len().max(b.len());
    let pad = |s: &[usize]| -> Vec<usize> {
        let mut v = vec![1; rank - s.len()];
        v.extend_from_slice(s);
        v
    };
    let (sa, sb) = (pad(a), pad(b));
    (0..rank)
        .map(|i| {
            assert!(
                sa[i] == sb[i] || sa[i] == 1 || sb[i] == 1,
                "broadcast mismatch {a:?} vs {b:?}"
            );
            sa[i].max(sb[i])
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn mlp_shapes() {
        let mut g = Graph::new("mlp");
        let x = g.input("x", &[32, 64]);
        let w1 = g.weight("w1", &[64, 128]);
        let b1 = g.weight("b1", &[128]);
        let mm = g.op(Op::MatMul, &[x, w1]);
        let ba = g.op(Op::BiasAdd, &[mm, b1]);
        let r = g.op(Op::Relu, &[ba]);
        g.mark_output(r);
        let s = infer_shapes(&g);
        assert_eq!(s[mm], vec![32, 128]);
        assert_eq!(s[r], vec![32, 128]);
    }

    #[test]
    fn conv_pool_shapes() {
        let mut g = Graph::new("cnn");
        let x = g.input("x", &[2, 3, 32, 32]);
        let w = g.weight("w", &[8, 3, 3, 3]);
        let c = g.op(Op::Conv2d { stride: 1, pad: 1 }, &[x, w]);
        let p = g.op(Op::MaxPool2d { k: 2, stride: 2 }, &[c]);
        let ga = g.op(Op::GlobalAvgPool, &[p]);
        g.mark_output(ga);
        let s = infer_shapes(&g);
        assert_eq!(s[c], vec![2, 8, 32, 32]);
        assert_eq!(s[p], vec![2, 8, 16, 16]);
        assert_eq!(s[ga], vec![2, 8]);
    }

    #[test]
    fn reduce_drops_last_axis() {
        let mut g = Graph::new("r");
        let x = g.input("x", &[4, 7, 9]);
        let r = g.op(Op::ReduceMax, &[x]);
        g.mark_output(r);
        assert_eq!(infer_shapes(&g)[r], vec![4, 7]);
    }

    #[test]
    fn attention_shape() {
        let mut g = Graph::new("att");
        let q = g.input("q", &[10, 16]);
        let k = g.input("k", &[12, 16]);
        let v = g.input("v", &[12, 16]);
        let a = g.op(Op::Attention, &[q, k, v]);
        g.mark_output(a);
        assert_eq!(infer_shapes(&g)[a], vec![10, 16]);
    }

    #[test]
    #[should_panic(expected = "matmul k mismatch")]
    fn shape_mismatch_panics() {
        let mut g = Graph::new("bad");
        let x = g.input("x", &[2, 3]);
        let w = g.weight("w", &[4, 5]);
        let m = g.op(Op::MatMul, &[x, w]);
        g.mark_output(m);
        infer_shapes(&g);
    }
}
