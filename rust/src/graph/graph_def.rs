//! Graph definition: a DAG of op nodes with a builder API.

use super::op::Op;

pub type NodeId = usize;

/// One node: an op plus its input node ids.
#[derive(Clone, Debug)]
pub struct Node {
    pub op: Op,
    pub inputs: Vec<NodeId>,
    /// Human-readable name (stable across runs; used in kernel names,
    /// region descriptions and reports).
    pub name: String,
    /// For `Op::Input`: the placeholder's shape.
    pub input_shape: Option<Vec<usize>>,
    /// For `Op::Input`: true if this is a weight/constant (affects the
    /// cost model: weights may be resident, activations stream).
    pub is_weight: bool,
}

/// A task's computation DAG. Nodes are topologically ordered by
/// construction (inputs must exist before use).
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    /// Output node ids (usually one).
    pub outputs: Vec<NodeId>,
    pub name: String,
}

impl Graph {
    pub fn new(name: &str) -> Graph {
        Graph { nodes: Vec::new(), outputs: Vec::new(), name: name.to_string() }
    }

    /// Add an activation input placeholder.
    pub fn input(&mut self, name: &str, shape: &[usize]) -> NodeId {
        self.push(Node {
            op: Op::Input,
            inputs: vec![],
            name: name.to_string(),
            input_shape: Some(shape.to_vec()),
            is_weight: false,
        })
    }

    /// Add a weight input placeholder.
    pub fn weight(&mut self, name: &str, shape: &[usize]) -> NodeId {
        self.push(Node {
            op: Op::Input,
            inputs: vec![],
            name: name.to_string(),
            input_shape: Some(shape.to_vec()),
            is_weight: true,
        })
    }

    /// Add an op node.
    pub fn op(&mut self, op: Op, inputs: &[NodeId]) -> NodeId {
        assert_eq!(
            op.arity(),
            inputs.len(),
            "op {:?} expects {} inputs, got {}",
            op,
            op.arity(),
            inputs.len()
        );
        for &i in inputs {
            assert!(i < self.nodes.len(), "input {i} not yet defined");
        }
        let name = format!("{}_{}", op.mnemonic(), self.nodes.len());
        self.push(Node {
            op,
            inputs: inputs.to_vec(),
            name,
            input_shape: None,
            is_weight: false,
        })
    }

    pub fn mark_output(&mut self, id: NodeId) {
        if !self.outputs.contains(&id) {
            self.outputs.push(id);
        }
    }

    fn push(&mut self, node: Node) -> NodeId {
        self.nodes.push(node);
        self.nodes.len() - 1
    }

    /// Ids of all `Op::Input` nodes, in definition order.
    pub fn input_ids(&self) -> Vec<NodeId> {
        (0..self.nodes.len())
            .filter(|&i| matches!(self.nodes[i].op, Op::Input))
            .collect()
    }

    /// Consumers of each node (adjacency reversed).
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut cons = vec![Vec::new(); self.nodes.len()];
        for (id, node) in self.nodes.iter().enumerate() {
            for &i in &node.inputs {
                cons[i].push(id);
            }
        }
        cons
    }

    /// Number of non-input op nodes.
    pub fn op_count(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| !matches!(n.op, Op::Input))
            .count()
    }

    /// Validate topological order + arity (used by property tests).
    pub fn validate(&self) -> Result<(), String> {
        for (id, node) in self.nodes.iter().enumerate() {
            if node.op.arity() != node.inputs.len() {
                return Err(format!("node {id}: arity mismatch"));
            }
            for &i in &node.inputs {
                if i >= id {
                    return Err(format!("node {id}: forward reference to {i}"));
                }
            }
            if matches!(node.op, Op::Input) && node.input_shape.is_none() {
                return Err(format!("node {id}: input without shape"));
            }
        }
        for &o in &self.outputs {
            if o >= self.nodes.len() {
                return Err(format!("output {o} out of range"));
            }
        }
        if self.outputs.is_empty() {
            return Err("graph has no outputs".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn linear_relu() -> Graph {
        let mut g = Graph::new("linear_relu");
        let x = g.input("x", &[8, 16]);
        let w = g.weight("w", &[16, 4]);
        let mm = g.op(Op::MatMul, &[x, w]);
        let r = g.op(Op::Relu, &[mm]);
        g.mark_output(r);
        g
    }

    #[test]
    fn builder_produces_valid_graph() {
        let g = linear_relu();
        assert!(g.validate().is_ok());
        assert_eq!(g.op_count(), 2);
        assert_eq!(g.input_ids(), vec![0, 1]);
    }

    #[test]
    fn consumers_reversed_edges() {
        let g = linear_relu();
        let cons = g.consumers();
        assert_eq!(cons[0], vec![2]); // x -> matmul
        assert_eq!(cons[2], vec![3]); // matmul -> relu
        assert!(cons[3].is_empty());
    }

    #[test]
    #[should_panic(expected = "expects")]
    fn arity_checked() {
        let mut g = Graph::new("bad");
        let x = g.input("x", &[2, 2]);
        g.op(Op::MatMul, &[x]);
    }

    #[test]
    fn validate_catches_no_outputs() {
        let mut g = Graph::new("noout");
        g.input("x", &[1]);
        assert!(g.validate().is_err());
    }
}
