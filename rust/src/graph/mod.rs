//! Operator-graph substrate: the "abstract algorithmic specification S"
//! of the paper's task definition — what KernelBench expresses as naive
//! PyTorch modules. A [`Graph`] is a DAG of [`Op`] nodes over named
//! tensors; [`eval`] executes it with reference semantics ("PyTorch
//! Eager"), [`shapes`] infers all intermediate shapes, and `kir::lower`
//! turns it into schedulable kernels.

mod op;
mod graph_def;
mod shapes;
mod eval;

pub use eval::{eval_graph, eval_graph_with_mutations, Mutation, MutationKind};
pub use graph_def::{Graph, Node, NodeId};
pub use op::{Op, OpClass};
pub use shapes::infer_shapes;
