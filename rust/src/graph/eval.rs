//! Reference executor ("PyTorch Eager" semantics) and the mutation-aware
//! executor used to *measure* the correctness of micro-coded kernels.
//!
//! The micro-coding competence model (microcode::mutation) does not flip a
//! "wrong" bit — it injects a concrete, executable semantic bug (boundary
//! mishandling, missing-sync corruption, off-by-one, dropped epilogue) at a
//! specific node. The eval harness then runs both executors on random
//! inputs and compares with tolerance, exactly how KernelBench checks
//! generated kernels.

use super::graph_def::{Graph, NodeId};
use super::op::Op;
use crate::tensor::{self, Tensor};

/// A concrete semantic bug attached to a node's computation.
#[derive(Clone, Debug, PartialEq)]
pub enum MutationKind {
    /// Remainder rows/cols mishandled: final `frac` of the innermost axis
    /// of the node output is stale (zeros) — classic tile-boundary bug.
    BoundaryDrop { frac: f32 },
    /// Missing __syncthreads between reduction phases: deterministic
    /// pseudo-noise on the output, scaled by `scale` times value magnitude.
    RaceCorruption { scale: f32 },
    /// Off-by-one in the input index: output shifted by one element along
    /// the flattened layout.
    IndexOffset,
    /// Dropped epilogue: the node computes the identity of its first input
    /// (wrong shape bugs become compile errors upstream, this is the
    /// silent flavour).
    SkippedOp,
    /// Accumulator initialised to garbage: constant added everywhere.
    BadAccumInit { bias: f32 },
}

/// A mutation targets one node of the graph.
#[derive(Clone, Debug, PartialEq)]
pub struct Mutation {
    pub node: NodeId,
    pub kind: MutationKind,
}

/// Execute the graph with reference semantics. `inputs` maps over
/// `graph.input_ids()` order.
pub fn eval_graph(g: &Graph, inputs: &[Tensor]) -> Vec<Tensor> {
    eval_graph_with_mutations(g, inputs, &[])
}

/// Execute with injected semantic bugs (empty slice = reference run).
pub fn eval_graph_with_mutations(
    g: &Graph,
    inputs: &[Tensor],
    mutations: &[Mutation],
) -> Vec<Tensor> {
    let input_ids = g.input_ids();
    assert_eq!(
        input_ids.len(),
        inputs.len(),
        "graph {} expects {} inputs, got {}",
        g.name,
        input_ids.len(),
        inputs.len()
    );
    let mut vals: Vec<Option<Tensor>> = vec![None; g.nodes.len()];
    for (slot, &id) in input_ids.iter().enumerate() {
        vals[id] = Some(inputs[slot].clone());
    }
    // LstmCell carries hidden cell state internally per node evaluation;
    // our graphs pass (x, h, c, w_ih, w_hh) explicitly and return h'.
    for (id, node) in g.nodes.iter().enumerate() {
        if matches!(node.op, Op::Input) {
            continue;
        }
        let arg = |i: usize| -> &Tensor {
            vals[node.inputs[i]]
                .as_ref()
                .expect("topological order violated")
        };
        let mut out = match &node.op {
            Op::Input => unreachable!(),
            Op::MatMul => tensor::matmul(arg(0), arg(1)),
            Op::BatchMatMul => tensor::bmm(arg(0), arg(1)),
            Op::Conv2d { stride, pad } => tensor::conv2d(arg(0), arg(1), *stride, *pad),
            Op::Relu => tensor::relu(arg(0)),
            Op::Gelu => tensor::gelu(arg(0)),
            Op::Sigmoid => tensor::sigmoid(arg(0)),
            Op::Tanh => tensor::tanh_t(arg(0)),
            Op::Exp => tensor::exp_t(arg(0)),
            Op::Sqrt => arg(0).map(|v| v.max(0.0).sqrt()),
            Op::Scale(s) => tensor::scale(arg(0), *s),
            Op::Add => tensor::add(arg(0), arg(1)),
            Op::Sub => tensor::sub(arg(0), arg(1)),
            Op::Mul => tensor::mul(arg(0), arg(1)),
            Op::Div => tensor::div(arg(0), arg(1)),
            Op::Max => tensor::maximum(arg(0), arg(1)),
            Op::BiasAdd => tensor::add(arg(0), arg(1)),
            Op::Softmax => tensor::softmax_last(arg(0)),
            Op::LayerNorm => tensor::layernorm_last(arg(0), 1e-5),
            Op::BatchNorm2d => tensor::batchnorm2d(arg(0), arg(1), arg(2), 1e-5),
            Op::ReduceSum => tensor::reduce_last(arg(0), "sum"),
            Op::ReduceMax => tensor::reduce_last(arg(0), "max"),
            Op::ReduceMean => tensor::reduce_last(arg(0), "mean"),
            Op::ArgMax => tensor::reduce_last(arg(0), "argmax"),
            Op::CumSum => tensor::cumsum_last(arg(0)),
            Op::MaxPool2d { k, stride } => tensor::maxpool2d(arg(0), *k, *stride),
            Op::GlobalAvgPool => tensor::global_avgpool(arg(0)),
            Op::Attention => tensor::attention(arg(0), arg(1), arg(2)),
            Op::LstmCell => {
                let (h, _c) = tensor::lstm_cell(arg(0), arg(1), arg(2), arg(3), arg(4));
                h
            }
            Op::Transpose2 => tensor::transpose2(arg(0)),
        };
        for m in mutations.iter().filter(|m| m.node == id) {
            out = apply_mutation(&out, node, arg(0), &m.kind);
        }
        vals[id] = Some(out);
    }
    g.outputs
        .iter()
        .map(|&o| vals[o].clone().expect("output not computed"))
        .collect()
}

fn apply_mutation(out: &Tensor, _node: &super::graph_def::Node,
                  first_input: &Tensor, kind: &MutationKind) -> Tensor {
    match kind {
        MutationKind::BoundaryDrop { frac } => {
            let mut t = out.clone();
            let n = t.len();
            let keep = ((1.0 - frac) * n as f32) as usize;
            for v in t.data_mut()[keep..].iter_mut() {
                *v = 0.0;
            }
            t
        }
        MutationKind::RaceCorruption { scale } => {
            let mut t = out.clone();
            for (i, v) in t.data_mut().iter_mut().enumerate() {
                // deterministic pseudo-noise: depends on position only, so
                // repeated checks fail reproducibly
                let h = (i as u32).wrapping_mul(2654435761);
                let noise = ((h >> 8) & 0xffff) as f32 / 65535.0 - 0.5;
                *v += *v * scale * noise;
            }
            t
        }
        MutationKind::IndexOffset => {
            let mut t = out.clone();
            let n = t.len();
            if n > 1 {
                let d = t.data_mut();
                d.rotate_right(1);
            }
            t
        }
        MutationKind::SkippedOp => {
            if first_input.shape() == out.shape() {
                first_input.clone()
            } else {
                // shape-changing op cannot be silently skipped; manifest as
                // a zeroed output instead (still wrong, still executable)
                Tensor::zeros(out.shape())
            }
        }
        MutationKind::BadAccumInit { bias } => out.map(|v| v + bias),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::util::Rng;

    fn mlp() -> (Graph, Vec<Tensor>) {
        let mut g = Graph::new("mlp");
        let x = g.input("x", &[4, 8]);
        let w = g.weight("w", &[8, 6]);
        let b = g.weight("b", &[6]);
        let mm = g.op(Op::MatMul, &[x, w]);
        let ba = g.op(Op::BiasAdd, &[mm, b]);
        let r = g.op(Op::Relu, &[ba]);
        g.mark_output(r);
        let mut rng = Rng::new(1);
        let inputs = vec![
            Tensor::randn(&[4, 8], &mut rng),
            Tensor::randn(&[8, 6], &mut rng),
            Tensor::randn(&[6], &mut rng),
        ];
        (g, inputs)
    }

    #[test]
    fn eval_matches_manual_composition() {
        let (g, inp) = mlp();
        let out = eval_graph(&g, &inp);
        let manual = tensor::relu(&tensor::add(
            &tensor::matmul(&inp[0], &inp[1]),
            &inp[2],
        ));
        assert!(out[0].allclose(&manual, 1e-6, 1e-6));
    }

    #[test]
    fn reference_run_is_deterministic() {
        let (g, inp) = mlp();
        assert_eq!(eval_graph(&g, &inp), eval_graph(&g, &inp));
    }

    #[test]
    fn mutations_change_output() {
        let (g, inp) = mlp();
        let clean = eval_graph(&g, &inp);
        for kind in [
            MutationKind::BoundaryDrop { frac: 0.25 },
            MutationKind::RaceCorruption { scale: 0.3 },
            MutationKind::IndexOffset,
            MutationKind::SkippedOp,
            MutationKind::BadAccumInit { bias: 0.5 },
        ] {
            let muts = vec![Mutation { node: 3, kind: kind.clone() }];
            let dirty = eval_graph_with_mutations(&g, &inp, &muts);
            assert!(
                !dirty[0].allclose(&clean[0], 1e-4, 1e-4),
                "mutation {kind:?} did not perturb output"
            );
        }
    }

    #[test]
    fn mutation_is_deterministic() {
        let (g, inp) = mlp();
        let muts = vec![Mutation {
            node: 4,
            kind: MutationKind::RaceCorruption { scale: 0.1 },
        }];
        assert_eq!(
            eval_graph_with_mutations(&g, &inp, &muts),
            eval_graph_with_mutations(&g, &inp, &muts)
        );
    }

    #[test]
    fn skipped_op_identity_when_shapes_match() {
        let mut g = Graph::new("s");
        let x = g.input("x", &[3, 3]);
        let r = g.op(Op::Relu, &[x]);
        g.mark_output(r);
        let mut rng = Rng::new(2);
        let inp = vec![Tensor::randn(&[3, 3], &mut rng)];
        let muts = vec![Mutation { node: r, kind: MutationKind::SkippedOp }];
        let out = eval_graph_with_mutations(&g, &inp, &muts);
        assert_eq!(out[0], inp[0]);
    }
}
