//! FuseProducer / FuseEpilogue: merge two kernels across a dataflow edge,
//! eliminating the intermediate HBM round-trip.

use super::TransformError;
use crate::graph::{Graph, OpClass};
use crate::kir::Program;

/// `producer_mode`: true = FuseProducer (a cheap producer is folded into
/// the consumer's loop nest, keeping the *consumer's* schedule), false =
/// FuseEpilogue (the consumer is absorbed after the producer's store,
/// keeping the *producer's* schedule).
pub fn check_fuse(p: &Program, g: &Graph, producer: usize, consumer: usize,
                  producer_mode: bool) -> Result<(), TransformError> {
    if producer >= p.kernels.len() || consumer >= p.kernels.len() {
        return Err(TransformError::NotApplicable("stale edge".into()));
    }
    if producer == consumer {
        return Err(TransformError::NotApplicable("self edge".into()));
    }
    let pk = &p.kernels[producer];
    let ck = &p.kernels[consumer];
    let p_anchor_cls = g.nodes[pk.anchor(g)].op.class();
    if producer_mode {
        // folding the producer into the consumer re-computes it per
        // consumer tile: only cheap (elementwise/movement) producers
        if !matches!(p_anchor_cls, OpClass::Elementwise | OpClass::Movement) {
            return Err(TransformError::NotApplicable(
                "producer fusion requires a cheap producer".into(),
            ));
        }
    } else {
        // epilogue fusion: every op of the consumer must be epilogue-safe
        for &n in &ck.nodes {
            if !g.nodes[n].op.fusible_as_epilogue() {
                return Err(TransformError::NotApplicable(format!(
                    "`{}` cannot run as an epilogue",
                    g.nodes[n].op.mnemonic()
                )));
            }
        }
    }
    // the consumer must depend only on the producer among later kernels —
    // merging must not reorder other dataflow. Since kernels are stored in
    // topo order and we merge adjacent-in-dataflow kernels, it suffices
    // that no kernel strictly between them feeds the consumer.
    let lo = producer.min(consumer);
    let hi = producer.max(consumer);
    for mid in lo + 1..hi {
        let mk = &p.kernels[mid];
        for &n in &p.kernels[hi].nodes {
            for &inp in &g.nodes[n].inputs {
                if mk.nodes.contains(&inp) {
                    return Err(TransformError::NotApplicable(
                        "an intervening kernel feeds the consumer".into(),
                    ));
                }
            }
        }
    }
    Ok(())
}

pub fn fuse(p: &mut Program, producer: usize, consumer: usize,
            producer_mode: bool) {
    let (lo, hi) = (producer.min(consumer), producer.max(consumer));
    let hi_kernel = p.kernels.remove(hi);
    let lo_kernel = &mut p.kernels[lo];
    lo_kernel.nodes.extend(hi_kernel.nodes.iter().copied());
    lo_kernel.nodes.sort_unstable();
    // schedule of the "dominant" side survives
    let keep_consumer_schedule = producer_mode;
    let surviving = if keep_consumer_schedule == (hi == consumer) {
        // hi side's schedule should survive
        hi_kernel.schedule
    } else {
        lo_kernel.schedule.clone()
    };
    lo_kernel.schedule = surviving;
    lo_kernel.name = format!("{}+{}", lo_kernel.name, hi_kernel.name);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{infer_shapes, Graph, Op};
    use crate::gpusim::{program_time_us, GpuSpec};
    use crate::kir::lower_naive;

    fn gemm_relu() -> (Graph, Vec<Vec<usize>>) {
        let mut g = Graph::new("t");
        let x = g.input("x", &[1024, 1024]);
        let w = g.weight("w", &[1024, 1024]);
        let mm = g.op(Op::MatMul, &[x, w]);
        let r = g.op(Op::Relu, &[mm]);
        g.mark_output(r);
        let s = infer_shapes(&g);
        (g, s)
    }

    #[test]
    fn epilogue_fusion_merges_and_validates() {
        let (g, shapes) = gemm_relu();
        let mut p = lower_naive(&g);
        check_fuse(&p, &g, 0, 1, false).unwrap();
        fuse(&mut p, 0, 1, false);
        assert_eq!(p.kernels.len(), 1);
        p.validate(&g).unwrap();
        let t_fused = program_time_us(&p, &g, &shapes, &GpuSpec::a100());
        let t_unfused =
            program_time_us(&lower_naive(&g), &g, &shapes, &GpuSpec::a100());
        assert!(t_fused < t_unfused);
    }

    #[test]
    fn matmul_cannot_be_producer_fused() {
        let (g, _) = gemm_relu();
        let p = lower_naive(&g);
        // producer 0 anchor is a contraction -> producer fusion invalid
        assert!(check_fuse(&p, &g, 0, 1, true).is_err());
        // but epilogue fusion of relu into matmul is fine
        assert!(check_fuse(&p, &g, 0, 1, false).is_ok());
    }

    #[test]
    fn producer_fusion_keeps_consumer_schedule() {
        // relu -> matmul: fold relu into matmul's nest
        let mut g = Graph::new("t");
        let x = g.input("x", &[256, 256]);
        let w = g.weight("w", &[256, 256]);
        let r = g.op(Op::Relu, &[x]);
        let mm = g.op(Op::MatMul, &[r, w]);
        g.mark_output(mm);
        let mut p = lower_naive(&g);
        p.kernels[1].schedule.block_tile = Some((64, 64, 16));
        check_fuse(&p, &g, 0, 1, true).unwrap();
        fuse(&mut p, 0, 1, true);
        assert_eq!(p.kernels.len(), 1);
        assert_eq!(p.kernels[0].schedule.block_tile, Some((64, 64, 16)));
        p.validate(&g).unwrap();
    }

    #[test]
    fn epilogue_fusion_keeps_producer_schedule() {
        let (g, _) = gemm_relu();
        let mut p = lower_naive(&g);
        p.kernels[0].schedule.block_tile = Some((128, 64, 32));
        fuse(&mut p, 0, 1, false);
        assert_eq!(p.kernels[0].schedule.block_tile, Some((128, 64, 32)));
    }

    #[test]
    fn intervening_dependency_blocks_fusion() {
        // k0 -> k1 -> k2 and also k0 -> k2: fusing k0 into k2 across k1
        // must be rejected (k1 feeds k2).
        let mut g = Graph::new("t");
        let x = g.input("x", &[64, 64]);
        let a = g.op(Op::Relu, &[x]);
        let b = g.op(Op::Tanh, &[a]);
        let c = g.op(Op::Add, &[a, b]);
        g.mark_output(c);
        let p = lower_naive(&g);
        assert!(check_fuse(&p, &g, 0, 2, true).is_err());
        // adjacent fusion is fine
        assert!(check_fuse(&p, &g, 1, 2, true).is_ok());
    }
}
