//! Discrete action encoding: (opt type, region slot) <-> index.

use crate::kir::MAX_REGIONS;

/// The 8 refined optimization types — Tiling, Fusion, Pipeline, Reorder of
/// §3.2, each split into the two variants experts actually distinguish,
/// plus Vectorize ("refines and extends", §4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OptType {
    TileShared,
    TileReg,
    FuseProducer,
    FuseEpilogue,
    PipelineDouble,
    PipelineAsync,
    Reorder,
    Vectorize,
}

pub const NUM_OPT_TYPES: usize = 8;

/// Total policy action dimension: 8 × 8 + Stop = 65. Must equal the L2
/// model's `act_dim` (artifacts/meta.json is checked at runtime load).
pub const ACTION_DIM: usize = NUM_OPT_TYPES * MAX_REGIONS + 1;

/// Index of the terminal Stop action.
pub const STOP_ACTION: usize = ACTION_DIM - 1;

pub const ALL_OPT_TYPES: [OptType; NUM_OPT_TYPES] = [
    OptType::TileShared,
    OptType::TileReg,
    OptType::FuseProducer,
    OptType::FuseEpilogue,
    OptType::PipelineDouble,
    OptType::PipelineAsync,
    OptType::Reorder,
    OptType::Vectorize,
];

impl OptType {
    pub fn index(&self) -> usize {
        ALL_OPT_TYPES.iter().position(|t| t == self).unwrap()
    }

    pub fn label(&self) -> &'static str {
        match self {
            OptType::TileShared => "tile_shared",
            OptType::TileReg => "tile_reg",
            OptType::FuseProducer => "fuse_producer",
            OptType::FuseEpilogue => "fuse_epilogue",
            OptType::PipelineDouble => "pipeline_double",
            OptType::PipelineAsync => "pipeline_async",
            OptType::Reorder => "reorder",
            OptType::Vectorize => "vectorize",
        }
    }

    /// Relative implementation complexity (drives the micro-coder error
    /// model: pipelining is harder to get right than vectorizing).
    pub fn implementation_complexity(&self) -> f64 {
        match self {
            OptType::TileShared => 1.3,
            OptType::TileReg => 1.1,
            OptType::FuseProducer => 1.5,
            OptType::FuseEpilogue => 1.2,
            OptType::PipelineDouble => 1.7,
            OptType::PipelineAsync => 2.0,
            OptType::Reorder => 1.0,
            OptType::Vectorize => 0.8,
        }
    }
}

/// A semantic optimization action: what + where.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Action {
    pub opt: OptType,
    pub region: usize,
}

/// Encode to the policy's discrete index (Stop = STOP_ACTION).
pub fn encode_action(a: &Action) -> usize {
    a.opt.index() * MAX_REGIONS + a.region
}

/// Decode a non-Stop index.
pub fn decode_action(idx: usize) -> Action {
    assert!(idx < STOP_ACTION, "cannot decode Stop/{idx}");
    Action { opt: ALL_OPT_TYPES[idx / MAX_REGIONS], region: idx % MAX_REGIONS }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_match_l2_model() {
        assert_eq!(ACTION_DIM, 65);
        assert_eq!(STOP_ACTION, 64);
    }

    #[test]
    fn encode_decode_roundtrip() {
        for idx in 0..STOP_ACTION {
            let a = decode_action(idx);
            assert_eq!(encode_action(&a), idx);
            assert!(a.region < MAX_REGIONS);
        }
    }

    #[test]
    #[should_panic]
    fn stop_cannot_decode() {
        decode_action(STOP_ACTION);
    }

    #[test]
    fn complexity_ordering_sane() {
        assert!(OptType::PipelineAsync.implementation_complexity()
            > OptType::Vectorize.implementation_complexity());
    }
}
