//! Memoized program analysis: [`AnalysisCache`] de-duplicates
//! [`analyze_regions`](crate::kir::analyze_regions) and
//! [`action_mask`](super::action_mask) per *program state* instead of per
//! *call site*.
//!
//! Region analysis walks the whole program (kernel ranking, consumer
//! scans, fusion-edge discovery), and before this cache the stepping hot
//! path re-ran it several times per action: once for the env's validity
//! mask, once inside `apply_action`, once more for the micro-coder's bug
//! site — and the greedy lookahead repeated that for every candidate.
//! Keys are `(graph fingerprint, program fingerprint[, spec])`, so every
//! env step, lookahead candidate and observation encoder that revisits a
//! program state reuses one analysis. Like the
//! [`CostCache`](crate::gpusim::CostCache), the analysis functions are
//! pure: a hit returns exactly what a cold miss would compute, so cached
//! and fresh paths are interchangeable (guarded by
//! `prop_analysis_cache_mask_identical` in `rust/tests/properties.rs`).

use std::sync::Arc;

use super::{action_mask, action_mask_with};
use crate::gpusim::{combine, graph_fingerprint, program_fingerprint,
                    spec_tag, GpuSpec, MemoStats, ShardedMemo};
use crate::graph::Graph;
use crate::kir::{analyze_regions, Program, Region};

/// Salt distinguishing region keys from mask keys in the combined space.
const REGIONS_SALT: u64 = 0x5EC1_0A17_AB5E_0001;

/// Default total capacity (regions + masks counted separately). Distinct
/// program states per sweep number in the thousands, far below this; the
/// bound only guards runaway workloads.
const DEFAULT_MAX_ENTRIES: usize = 1 << 20;

/// Sharded, thread-safe memo for region analysis and action masks.
pub struct AnalysisCache {
    regions: ShardedMemo<Arc<Vec<Region>>>,
    masks: ShardedMemo<Arc<Vec<bool>>>,
}

impl Default for AnalysisCache {
    fn default() -> Self {
        Self::new()
    }
}

impl AnalysisCache {
    pub fn new() -> AnalysisCache {
        Self::with_capacity(DEFAULT_MAX_ENTRIES)
    }

    /// A cache bounded to `max_entries` regions and as many masks.
    pub fn with_capacity(max_entries: usize) -> AnalysisCache {
        AnalysisCache {
            regions: ShardedMemo::new(max_entries),
            masks: ShardedMemo::new(max_entries),
        }
    }

    /// Memoized [`analyze_regions`]. `ctx` is the task's
    /// [`graph_fingerprint`].
    pub fn regions(&self, ctx: u64, p: &Program, g: &Graph)
                   -> Arc<Vec<Region>> {
        self.regions_for_fp(ctx, program_fingerprint(p), p, g)
    }

    /// [`Self::regions`] with the [`program_fingerprint`] precomputed by
    /// the caller — the env caches it on its state
    /// ([`crate::env::EnvState::program_fp`]), so the mask and region
    /// lookups of one step share a single fingerprint hash.
    pub fn regions_for_fp(&self, ctx: u64, pfp: u64, p: &Program, g: &Graph)
                          -> Arc<Vec<Region>> {
        self.regions_keyed(combine(ctx, pfp, REGIONS_SALT), p, g)
    }

    /// Region lookup with the key precomputed — lets [`Self::action_mask`]
    /// fingerprint the program once per call, not once per memo layer.
    fn regions_keyed(&self, key: u64, p: &Program, g: &Graph)
                     -> Arc<Vec<Region>> {
        if let Some(hit) = self.regions.get(key) {
            return hit;
        }
        // compute outside the lock (same policy as the cost cache)
        let fresh = Arc::new(analyze_regions(p, g));
        self.regions.insert(key, Arc::clone(&fresh));
        fresh
    }

    /// Memoized [`action_mask`] (built on the memoized regions, so a mask
    /// miss still reuses a region hit; the program is fingerprinted once
    /// and the hash reused for both keys).
    pub fn action_mask(&self, ctx: u64, p: &Program, g: &Graph,
                       shapes: &[Vec<usize>], spec: &GpuSpec)
                       -> Arc<Vec<bool>> {
        self.action_mask_for_fp(ctx, program_fingerprint(p), p, g, shapes,
                                spec)
    }

    /// [`Self::action_mask`] with the [`program_fingerprint`] precomputed
    /// by the caller (see [`Self::regions_for_fp`]).
    pub fn action_mask_for_fp(&self, ctx: u64, pfp: u64, p: &Program,
                              g: &Graph, shapes: &[Vec<usize>],
                              spec: &GpuSpec) -> Arc<Vec<bool>> {
        let key = combine(ctx, pfp, spec_tag(spec));
        if let Some(hit) = self.masks.get(key) {
            return hit;
        }
        let regions = self.regions_keyed(combine(ctx, pfp, REGIONS_SALT), p, g);
        let fresh = Arc::new(action_mask_with(p, g, shapes, &regions, spec));
        self.masks.insert(key, Arc::clone(&fresh));
        fresh
    }

    /// Combined traffic counters (regions + masks).
    pub fn stats(&self) -> MemoStats {
        self.regions.stats().merged(&self.masks.stats())
    }

    pub fn len(&self) -> usize {
        self.regions.len() + self.masks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl std::fmt::Debug for AnalysisCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(f, "AnalysisCache {{ entries: {}, hits: {}, misses: {} }}",
               self.len(), s.hits, s.misses)
    }
}

/// An analysis handle for one task: couples an optional shared
/// [`AnalysisCache`] with the task's precomputed [`graph_fingerprint`]
/// (the analysis twin of [`crate::gpusim::Pricer`]). With `cache: None`
/// every method falls through to the direct analysis functions —
/// bit-identical either way.
#[derive(Clone, Copy, Debug)]
pub struct Analyzer<'a> {
    cache: Option<&'a AnalysisCache>,
    ctx: u64,
}

impl<'a> Analyzer<'a> {
    pub fn new(cache: Option<&'a AnalysisCache>, g: &Graph,
               shapes: &[Vec<usize>]) -> Analyzer<'a> {
        Self::from_ctx(cache, graph_fingerprint(g, shapes))
    }

    /// Build from an already-computed [`graph_fingerprint`].
    pub fn from_ctx(cache: Option<&'a AnalysisCache>, ctx: u64)
                    -> Analyzer<'a> {
        Analyzer { cache, ctx }
    }

    /// The cache this analyzer routes through, if any.
    pub fn cache(&self) -> Option<&'a AnalysisCache> {
        self.cache
    }

    /// Candidate regions of the current program (memoized when caching).
    pub fn regions(&self, p: &Program, g: &Graph) -> Arc<Vec<Region>> {
        match self.cache {
            Some(c) => c.regions(self.ctx, p, g),
            None => Arc::new(analyze_regions(p, g)),
        }
    }

    /// [`Self::regions`] with the program fingerprint precomputed by the
    /// caller; the uncached path ignores it (direct analysis needs no
    /// key). Must be the [`program_fingerprint`] of `p`, or cached and
    /// uncached paths diverge.
    pub fn regions_fp(&self, pfp: u64, p: &Program, g: &Graph)
                      -> Arc<Vec<Region>> {
        match self.cache {
            Some(c) => c.regions_for_fp(self.ctx, pfp, p, g),
            None => Arc::new(analyze_regions(p, g)),
        }
    }

    /// Validity mask of the current program (memoized when caching).
    pub fn mask(&self, p: &Program, g: &Graph, shapes: &[Vec<usize>],
                spec: &GpuSpec) -> Arc<Vec<bool>> {
        match self.cache {
            Some(c) => c.action_mask(self.ctx, p, g, shapes, spec),
            None => Arc::new(action_mask(p, g, shapes, spec)),
        }
    }

    /// [`Self::mask`] with the program fingerprint precomputed by the
    /// caller (see [`Self::regions_fp`]).
    pub fn mask_fp(&self, pfp: u64, p: &Program, g: &Graph,
                   shapes: &[Vec<usize>], spec: &GpuSpec) -> Arc<Vec<bool>> {
        match self.cache {
            Some(c) => c.action_mask_for_fp(self.ctx, pfp, p, g, shapes,
                                            spec),
            None => Arc::new(action_mask(p, g, shapes, spec)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{infer_shapes, Op};
    use crate::kir::lower_naive;

    fn demo() -> (Graph, Vec<Vec<usize>>) {
        let mut g = Graph::new("analysis_demo");
        let x = g.input("x", &[256, 256]);
        let w = g.weight("w", &[256, 64]);
        let b = g.weight("b", &[64]);
        let mm = g.op(Op::MatMul, &[x, w]);
        let ba = g.op(Op::BiasAdd, &[mm, b]);
        let r = g.op(Op::Relu, &[ba]);
        g.mark_output(r);
        let shapes = infer_shapes(&g);
        (g, shapes)
    }

    #[test]
    fn cached_mask_and_regions_match_fresh() {
        let (g, shapes) = demo();
        let spec = GpuSpec::a100();
        let p = lower_naive(&g);
        let cache = AnalysisCache::new();
        let az = Analyzer::new(Some(&cache), &g, &shapes);
        let fresh_mask = action_mask(&p, &g, &shapes, &spec);
        let fresh_regions = analyze_regions(&p, &g);
        for _ in 0..2 {
            assert_eq!(*az.mask(&p, &g, &shapes, &spec), fresh_mask);
            assert_eq!(*az.regions(&p, &g), fresh_regions);
        }
        let s = cache.stats();
        assert!(s.hits > 0, "second pass must hit");
        assert_eq!(s.hits + s.misses, s.lookups);
    }

    #[test]
    fn uncached_analyzer_is_transparent() {
        let (g, shapes) = demo();
        let spec = GpuSpec::v100();
        let p = lower_naive(&g);
        let az = Analyzer::new(None, &g, &shapes);
        assert!(az.cache().is_none());
        assert_eq!(*az.mask(&p, &g, &shapes, &spec),
                   action_mask(&p, &g, &shapes, &spec));
        assert_eq!(*az.regions(&p, &g), analyze_regions(&p, &g));
    }

    #[test]
    fn fp_variants_share_keys_with_plain_lookups() {
        let (g, shapes) = demo();
        let spec = GpuSpec::a100();
        let p = lower_naive(&g);
        let cache = AnalysisCache::new();
        let az = Analyzer::new(Some(&cache), &g, &shapes);
        let pfp = program_fingerprint(&p);
        assert_eq!(*az.mask_fp(pfp, &p, &g, &shapes, &spec),
                   *az.mask(&p, &g, &shapes, &spec));
        assert_eq!(*az.regions_fp(pfp, &p, &g), *az.regions(&p, &g));
        assert!(cache.stats().hits > 0,
                "fp and plain variants must share memo keys");
        let plain = Analyzer::new(None, &g, &shapes);
        assert_eq!(*plain.mask_fp(pfp, &p, &g, &shapes, &spec),
                   *plain.mask(&p, &g, &shapes, &spec));
        assert_eq!(*plain.regions_fp(pfp, &p, &g), *plain.regions(&p, &g));
    }

    #[test]
    fn distinct_program_states_do_not_alias() {
        let (g, shapes) = demo();
        let spec = GpuSpec::h100();
        let p = lower_naive(&g);
        let cache = AnalysisCache::new();
        let az = Analyzer::new(Some(&cache), &g, &shapes);
        let m0 = az.mask(&p, &g, &shapes, &spec);
        let mut tiled = p.clone();
        tiled.kernels[0].schedule.block_tile = Some((64, 64, 32));
        let m1 = az.mask(&tiled, &g, &shapes, &spec);
        assert_eq!(*m1, action_mask(&tiled, &g, &shapes, &spec));
        assert_ne!(*m0, *m1, "tiling unlocks pipeline actions");
    }

    #[test]
    fn specs_keyed_separately() {
        let (g, shapes) = demo();
        let p = lower_naive(&g);
        let cache = AnalysisCache::new();
        let az = Analyzer::new(Some(&cache), &g, &shapes);
        let a = az.mask(&p, &g, &shapes, &GpuSpec::a100());
        let v = az.mask(&p, &g, &shapes, &GpuSpec::v100());
        assert_eq!(*a, action_mask(&p, &g, &shapes, &GpuSpec::a100()));
        assert_eq!(*v, action_mask(&p, &g, &shapes, &GpuSpec::v100()));
        assert_eq!(cache.stats().hits, 0, "different specs must not hit");
    }
}
