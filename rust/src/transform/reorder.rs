//! Reorder: loop interchange for memory-access locality (coalescing).

use super::TransformError;
use crate::kir::{LoopOrder, Program};

pub fn check_reorder(p: &Program, kernel: usize) -> Result<(), TransformError> {
    let s = &p.kernels[kernel].schedule;
    match s.loop_order {
        LoopOrder::Naive => Ok(()),
        LoopOrder::Blocked if s.block_tile.is_none() => Ok(()),
        LoopOrder::Blocked => Err(TransformError::NotApplicable(
            "tiled kernel is already tile-major; interchange would break \
             the staging structure"
                .into(),
        )),
        LoopOrder::Coalesced => Err(TransformError::NotApplicable(
            "already fully coalesced".into(),
        )),
    }
}

/// Interchange to the coalesced order. Low quality lands on the blocked
/// (partially-coalesced) order instead — a correct but weaker interchange.
pub fn reorder(p: &mut Program, kernel: usize, quality: f32) {
    let s = &mut p.kernels[kernel].schedule;
    s.loop_order = if s.block_tile.is_some() || quality < 0.4 {
        LoopOrder::Blocked
    } else {
        LoopOrder::Coalesced
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, Op};
    use crate::kir::lower_naive;

    fn prog() -> Program {
        let mut g = Graph::new("t");
        let x = g.input("x", &[512, 512]);
        let r = g.op(Op::Relu, &[x]);
        g.mark_output(r);
        lower_naive(&g)
    }

    #[test]
    fn naive_to_coalesced() {
        let mut p = prog();
        check_reorder(&p, 0).unwrap();
        reorder(&mut p, 0, 1.0);
        assert_eq!(p.kernels[0].schedule.loop_order, LoopOrder::Coalesced);
        assert!(check_reorder(&p, 0).is_err());
    }

    #[test]
    fn tiled_kernel_reorders_to_blocked_only() {
        let mut p = prog();
        p.kernels[0].schedule.block_tile = Some((64, 64, 1));
        p.kernels[0].schedule.loop_order = LoopOrder::Naive;
        reorder(&mut p, 0, 1.0);
        assert_eq!(p.kernels[0].schedule.loop_order, LoopOrder::Blocked);
    }

    #[test]
    fn low_quality_lands_on_blocked() {
        let mut p = prog();
        reorder(&mut p, 0, 0.1);
        assert_eq!(p.kernels[0].schedule.loop_order, LoopOrder::Blocked);
    }
}
