//! TileShared / TileReg: block-tile a kernel's loop nest for shared-memory
//! (VMEM on TPU) reuse, then register-block under it.

use super::TransformError;
use crate::gpusim::GpuSpec;
use crate::graph::{Graph, OpClass};
use crate::kir::Program;

/// Candidate block tiles, best-first per smem budget class. (M, N, K).
const TILE_MENU: &[(usize, usize, usize)] = &[
    (128, 128, 32),
    (128, 64, 32),
    (64, 128, 32),
    (64, 64, 32),
    (64, 64, 16),
    (32, 64, 16),
    (32, 32, 16),
    (16, 32, 8),
];

pub fn check_tile_shared(p: &Program, g: &Graph, _shapes: &[Vec<usize>],
                         kernel: usize, _spec: &GpuSpec) -> Result<(), TransformError> {
    let k = &p.kernels[kernel];
    if k.schedule.block_tile.is_some() {
        return Err(TransformError::NotApplicable("already block-tiled".into()));
    }
    let cls = g.nodes[k.anchor(g)].op.class();
    if !matches!(cls, OpClass::Contraction | OpClass::Reduction) {
        return Err(TransformError::NotApplicable(format!(
            "tiling targets contraction/reduction nests, anchor is {cls:?}"
        )));
    }
    Ok(())
}

/// Pick a tile: ideal = largest menu entry whose smem footprint (at the
/// current pipeline depth) keeps >= 2 blocks per SM; `quality` < 1 walks
/// down the menu (the model chose a legal but under-sized tile).
pub fn tile_shared(p: &mut Program, g: &Graph, shapes: &[Vec<usize>],
                   kernel: usize, spec: &GpuSpec, quality: f32) {
    let anchor = p.kernels[kernel].anchor(g);
    let cls = g.nodes[anchor].op.class();
    let out_shape = &shapes[anchor];
    let ideal_pos = TILE_MENU
        .iter()
        .position(|&(m, n, k)| {
            let smem = (m * k + k * n) * 4;
            smem * 2 <= spec.smem_bytes()
        })
        .unwrap_or(TILE_MENU.len() - 1);
    // quality walks further down the menu: q=1 -> ideal, q=0 -> +3 entries
    let degrade = ((1.0 - quality.clamp(0.0, 1.0)) * 3.0).round() as usize;
    let pos = (ideal_pos + degrade).min(TILE_MENU.len() - 1);
    let (m, n, k) = TILE_MENU[pos];
    let tile = if cls == OpClass::Reduction {
        // reductions tile (rows, cols) — K slot unused; clamp cols to the
        // reduced extent so the "online" single-pass form is real
        let cols = out_shape.last().copied().unwrap_or(n).min(1024).max(16);
        (m, cols.min(n * 4), 1)
    } else {
        (m, n, k)
    };
    let sched = &mut p.kernels[kernel].schedule;
    sched.block_tile = Some(tile);
    // tiling restructures the loops tile-major as a side effect
    if sched.loop_order == crate::kir::LoopOrder::Naive {
        sched.loop_order = crate::kir::LoopOrder::Blocked;
    }
}

pub fn check_tile_reg(p: &Program, g: &Graph, kernel: usize) -> Result<(), TransformError> {
    let k = &p.kernels[kernel];
    if k.schedule.block_tile.is_none() {
        return Err(TransformError::NotApplicable(
            "register tiling requires an existing block tile".into(),
        ));
    }
    if k.schedule.reg_tile.is_some() {
        return Err(TransformError::NotApplicable("already register-tiled".into()));
    }
    if g.nodes[k.anchor(g)].op.class() != OpClass::Contraction {
        return Err(TransformError::NotApplicable(
            "register tiling pays off on contraction nests only".into(),
        ));
    }
    Ok(())
}

pub fn tile_reg(p: &mut Program, kernel: usize, quality: f32) {
    let reg = if quality > 0.66 {
        (8, 8)
    } else if quality > 0.33 {
        (4, 8)
    } else {
        (4, 4)
    };
    p.kernels[kernel].schedule.reg_tile = Some(reg);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{infer_shapes, Graph, Op};
    use crate::kir::lower_naive;

    fn mm() -> (Graph, Vec<Vec<usize>>) {
        let mut g = Graph::new("t");
        let x = g.input("x", &[2048, 2048]);
        let w = g.weight("w", &[2048, 2048]);
        let m = g.op(Op::MatMul, &[x, w]);
        g.mark_output(m);
        let s = infer_shapes(&g);
        (g, s)
    }

    #[test]
    fn tile_fits_smem_budget() {
        for spec in GpuSpec::all() {
            let (g, shapes) = mm();
            let mut p = lower_naive(&g);
            tile_shared(&mut p, &g, &shapes, 0, &spec, 1.0);
            let smem = p.kernels[0].schedule.smem_bytes();
            assert!(
                smem * 2 <= spec.smem_bytes(),
                "{}: {smem} bytes won't double-buffer",
                spec.name
            );
        }
    }

    #[test]
    fn reduction_tiling_clamps_cols() {
        let mut g = Graph::new("sm");
        let x = g.input("x", &[4096, 512]);
        let s = g.op(Op::Softmax, &[x]);
        g.mark_output(s);
        let shapes = infer_shapes(&g);
        let mut p = lower_naive(&g);
        tile_shared(&mut p, &g, &shapes, 0, &GpuSpec::a100(), 1.0);
        let t = p.kernels[0].schedule.block_tile.unwrap();
        assert!(t.1 <= 512);
        assert_eq!(t.2, 1);
    }

    #[test]
    fn reg_tile_requires_block_tile() {
        let (g, _shapes) = mm();
        let p = lower_naive(&g);
        assert!(check_tile_reg(&p, &g, 0).is_err());
    }

    #[test]
    fn elementwise_not_tileable() {
        let mut g = Graph::new("e");
        let x = g.input("x", &[128, 128]);
        let r = g.op(Op::Relu, &[x]);
        g.mark_output(r);
        let shapes = infer_shapes(&g);
        let p = lower_naive(&g);
        assert!(check_tile_shared(&p, &g, &shapes, 0, &GpuSpec::a100()).is_err());
    }

    #[test]
    fn tiling_switches_loop_order_to_blocked() {
        let (g, shapes) = mm();
        let mut p = lower_naive(&g);
        tile_shared(&mut p, &g, &shapes, 0, &GpuSpec::h100(), 1.0);
        assert_eq!(p.kernels[0].schedule.loop_order, crate::kir::LoopOrder::Blocked);
    }
}
