//! Vectorize: widen global loads/stores (float4-style / 128-bit lanes).

use super::TransformError;
use crate::kir::{LoopOrder, Program};

pub fn check_vectorize(p: &Program, kernel: usize) -> Result<(), TransformError> {
    let s = &p.kernels[kernel].schedule;
    if s.vector_width > 1 {
        return Err(TransformError::NotApplicable("already vectorized".into()));
    }
    if s.loop_order == LoopOrder::Naive {
        return Err(TransformError::NotApplicable(
            "vector loads need unit-stride innermost accesses: reorder or \
             tile first"
                .into(),
        ));
    }
    Ok(())
}

pub fn vectorize(p: &mut Program, kernel: usize, quality: f32) {
    p.kernels[kernel].schedule.vector_width =
        if quality > 0.5 { 4 } else { 2 };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, Op};
    use crate::kir::lower_naive;

    fn prog() -> Program {
        let mut g = Graph::new("t");
        let x = g.input("x", &[256, 256]);
        let r = g.op(Op::Relu, &[x]);
        g.mark_output(r);
        lower_naive(&g)
    }

    #[test]
    fn needs_non_naive_order() {
        let mut p = prog();
        assert!(check_vectorize(&p, 0).is_err());
        p.kernels[0].schedule.loop_order = LoopOrder::Coalesced;
        check_vectorize(&p, 0).unwrap();
        vectorize(&mut p, 0, 1.0);
        assert_eq!(p.kernels[0].schedule.vector_width, 4);
        assert!(check_vectorize(&p, 0).is_err());
    }

    #[test]
    fn low_quality_narrower_width() {
        let mut p = prog();
        p.kernels[0].schedule.loop_order = LoopOrder::Blocked;
        vectorize(&mut p, 0, 0.2);
        assert_eq!(p.kernels[0].schedule.vector_width, 2);
    }
}
