//! The semantic optimization action space (paper §4.2): 8 refined
//! optimization types × [`MAX_REGIONS`] candidate code regions + Stop =
//! 65 discrete actions. Each action *application* is a real schedule
//! transformation over the kernel IR with validity checking; parameter
//! choices (tile sizes, stage counts, widths) are derived from the target
//! GPU spec, degraded by the micro-coder's `quality` skill in [0,1].

mod actions;
mod analysis;
mod tiling;
mod fusion;
mod pipeline;
mod reorder;
mod vectorize;

pub use actions::{
    decode_action, encode_action, Action, OptType, ACTION_DIM, NUM_OPT_TYPES,
    STOP_ACTION,
};
pub use analysis::{AnalysisCache, Analyzer};

use crate::gpusim::GpuSpec;
use crate::graph::Graph;
use crate::kir::{analyze_regions, Program, Region, RegionKind};

/// Why a transform cannot apply.
#[derive(thiserror::Error, Debug, Clone, PartialEq)]
pub enum TransformError {
    #[error("region slot {0} is empty")]
    EmptyRegion(usize),
    #[error("not applicable: {0}")]
    NotApplicable(String),
}

/// Validity mask over the full action space for the current program
/// state. `mask[STOP_ACTION]` is always true.
pub fn action_mask(p: &Program, g: &Graph, shapes: &[Vec<usize>],
                   spec: &GpuSpec) -> Vec<bool> {
    action_mask_with(p, g, shapes, &analyze_regions(p, g), spec)
}

/// [`action_mask`] over already-analyzed regions — the hot-path variant
/// used by the [`AnalysisCache`] and the greedy lookahead, which analyze
/// a program state once and reuse the regions across every action.
pub fn action_mask_with(p: &Program, g: &Graph, shapes: &[Vec<usize>],
                        regions: &[Region], spec: &GpuSpec) -> Vec<bool> {
    let mut mask = vec![false; ACTION_DIM];
    mask[STOP_ACTION] = true;
    for (a, slot) in mask.iter_mut().enumerate().take(STOP_ACTION) {
        let action = decode_action(a);
        *slot = check_action(p, g, shapes, regions, &action, spec).is_ok();
    }
    mask
}

/// Check whether an action applies (without applying it).
pub fn check_action(p: &Program, g: &Graph, shapes: &[Vec<usize>],
                    regions: &[Region], action: &Action,
                    spec: &GpuSpec) -> Result<(), TransformError> {
    let region = regions
        .get(action.region)
        .ok_or(TransformError::EmptyRegion(action.region))?;
    match (action.opt, &region.kind) {
        (OptType::TileShared, RegionKind::Kernel { kernel }) => {
            tiling::check_tile_shared(p, g, shapes, *kernel, spec)
        }
        (OptType::TileReg, RegionKind::Kernel { kernel }) => {
            tiling::check_tile_reg(p, g, *kernel)
        }
        (OptType::FuseProducer, RegionKind::FusionEdge { producer, consumer }) => {
            fusion::check_fuse(p, g, *producer, *consumer, true)
        }
        (OptType::FuseEpilogue, RegionKind::FusionEdge { producer, consumer }) => {
            fusion::check_fuse(p, g, *producer, *consumer, false)
        }
        (OptType::PipelineDouble, RegionKind::Kernel { kernel }) => {
            pipeline::check_pipeline(p, *kernel, 2, spec)
        }
        (OptType::PipelineAsync, RegionKind::Kernel { kernel }) => {
            pipeline::check_pipeline(p, *kernel, 3, spec)
        }
        (OptType::Reorder, RegionKind::Kernel { kernel }) => {
            reorder::check_reorder(p, *kernel)
        }
        (OptType::Vectorize, RegionKind::Kernel { kernel }) => {
            vectorize::check_vectorize(p, *kernel)
        }
        _ => Err(TransformError::NotApplicable(format!(
            "{:?} does not target {:?}",
            action.opt, region.kind
        ))),
    }
}

/// Apply an action, producing the next program. `quality` in [0,1] is the
/// micro-coder's parameter skill (1.0 = ideal parameters).
pub fn apply_action(p: &Program, g: &Graph, shapes: &[Vec<usize>],
                    action: &Action, spec: &GpuSpec,
                    quality: f32) -> Result<Program, TransformError> {
    apply_action_with(p, g, shapes, &analyze_regions(p, g), action, spec,
                      quality)
}

/// [`apply_action`] over already-analyzed regions. `regions` must be
/// `analyze_regions(p, g)` for this exact program state (the
/// [`Analyzer`] guarantees that); results are identical to
/// [`apply_action`], minus the re-analysis.
pub fn apply_action_with(p: &Program, g: &Graph, shapes: &[Vec<usize>],
                         regions: &[Region], action: &Action, spec: &GpuSpec,
                         quality: f32) -> Result<Program, TransformError> {
    check_action(p, g, shapes, regions, action, spec)?;
    let region = &regions[action.region];
    let mut next = p.clone();
    match (action.opt, &region.kind) {
        (OptType::TileShared, RegionKind::Kernel { kernel }) => {
            tiling::tile_shared(&mut next, g, shapes, *kernel, spec, quality)
        }
        (OptType::TileReg, RegionKind::Kernel { kernel }) => {
            tiling::tile_reg(&mut next, *kernel, quality)
        }
        (OptType::FuseProducer, RegionKind::FusionEdge { producer, consumer }) => {
            fusion::fuse(&mut next, *producer, *consumer, true)
        }
        (OptType::FuseEpilogue, RegionKind::FusionEdge { producer, consumer }) => {
            fusion::fuse(&mut next, *producer, *consumer, false)
        }
        (OptType::PipelineDouble, RegionKind::Kernel { kernel }) => {
            pipeline::pipeline(&mut next, *kernel, 2)
        }
        (OptType::PipelineAsync, RegionKind::Kernel { kernel }) => {
            pipeline::pipeline(&mut next, *kernel, 3 + (quality > 0.8) as usize)
        }
        (OptType::Reorder, RegionKind::Kernel { kernel }) => {
            reorder::reorder(&mut next, *kernel, quality)
        }
        (OptType::Vectorize, RegionKind::Kernel { kernel }) => {
            vectorize::vectorize(&mut next, *kernel, quality)
        }
        _ => unreachable!("checked above"),
    }
    debug_assert_eq!(next.validate(g), Ok(()));
    Ok(next)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;
    use crate::kir::lower_naive;

    fn demo() -> (Graph, Vec<Vec<usize>>) {
        let mut g = Graph::new("t");
        let x = g.input("x", &[1024, 1024]);
        let w = g.weight("w", &[1024, 1024]);
        let b = g.weight("b", &[1024]);
        let mm = g.op(Op::MatMul, &[x, w]);
        let ba = g.op(Op::BiasAdd, &[mm, b]);
        let r = g.op(Op::Relu, &[ba]);
        g.mark_output(r);
        let shapes = crate::graph::infer_shapes(&g);
        (g, shapes)
    }

    #[test]
    fn mask_has_stop_and_some_actions() {
        let (g, shapes) = demo();
        let p = lower_naive(&g);
        let mask = action_mask(&p, &g, &shapes, &GpuSpec::a100());
        assert!(mask[STOP_ACTION]);
        assert!(mask.iter().filter(|&&m| m).count() > 3);
    }

    #[test]
    fn applying_every_valid_action_keeps_program_valid() {
        let (g, shapes) = demo();
        let p = lower_naive(&g);
        let spec = GpuSpec::h100();
        let mask = action_mask(&p, &g, &shapes, &spec);
        let mut applied = 0;
        for a in 0..STOP_ACTION {
            if !mask[a] {
                continue;
            }
            let next = apply_action(&p, &g, &shapes, &decode_action(a), &spec, 1.0)
                .unwrap_or_else(|e| panic!("action {a}: {e}"));
            next.validate(&g).unwrap();
            applied += 1;
        }
        assert!(applied >= 3);
    }

    #[test]
    fn invalid_action_is_rejected_not_panicking() {
        let (g, shapes) = demo();
        let p = lower_naive(&g);
        let spec = GpuSpec::a100();
        // PipelineDouble before any tiling must be rejected
        let regions = analyze_regions(&p, &g);
        let a = Action { opt: OptType::PipelineDouble, region: 0 };
        assert!(check_action(&p, &g, &shapes, &regions, &a, &spec).is_err());
    }

    #[test]
    fn async_pipeline_gated_on_volta() {
        let (g, shapes) = demo();
        let mut p = lower_naive(&g);
        // tile first so pipelining is otherwise legal
        p = apply_action(
            &p, &g, &shapes,
            &Action { opt: OptType::TileShared, region: 0 },
            &GpuSpec::v100(), 1.0,
        )
        .unwrap();
        let regions = analyze_regions(&p, &g);
        let a = Action { opt: OptType::PipelineAsync, region: 0 };
        assert!(check_action(&p, &g, &shapes, &regions, &a, &GpuSpec::v100()).is_err());
        assert!(check_action(&p, &g, &shapes, &regions, &a, &GpuSpec::a100()).is_ok());
    }

    #[test]
    fn quality_degrades_tile_choice() {
        let (g, shapes) = demo();
        let p = lower_naive(&g);
        let spec = GpuSpec::h100();
        let a = Action { opt: OptType::TileShared, region: 0 };
        let good = apply_action(&p, &g, &shapes, &a, &spec, 1.0).unwrap();
        let bad = apply_action(&p, &g, &shapes, &a, &spec, 0.1).unwrap();
        let tg = good.kernels[0].schedule.block_tile.unwrap();
        let tb = bad.kernels[0].schedule.block_tile.unwrap();
        assert!(tb.0 * tb.1 < tg.0 * tg.1, "bad {tb:?} vs good {tg:?}");
    }
}
