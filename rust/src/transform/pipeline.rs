//! PipelineDouble / PipelineAsync: software-pipeline the staged operand
//! loads of a tiled kernel (double buffering, then cp.async-style deeper
//! stages on Ampere+).

use super::TransformError;
use crate::gpusim::GpuSpec;
use crate::kir::Program;

pub fn check_pipeline(p: &Program, kernel: usize, target_depth: usize,
                      spec: &GpuSpec) -> Result<(), TransformError> {
    let k = &p.kernels[kernel];
    let s = &k.schedule;
    if s.block_tile.is_none() {
        return Err(TransformError::NotApplicable(
            "nothing to pipeline: no staged (tiled) loads".into(),
        ));
    }
    if target_depth >= 3 && !spec.supports_async_copy() {
        return Err(TransformError::NotApplicable(format!(
            "{} has no async-copy path (pre-Ampere)",
            spec.name
        )));
    }
    if s.pipeline_depth >= target_depth {
        return Err(TransformError::NotApplicable(format!(
            "already at pipeline depth {}",
            s.pipeline_depth
        )));
    }
    // the deeper buffer must still fit in shared memory
    let smem_at_depth = s.smem_bytes() / s.pipeline_depth.max(1) * target_depth;
    if smem_at_depth > spec.smem_bytes() {
        return Err(TransformError::NotApplicable(format!(
            "depth-{target_depth} staging needs {smem_at_depth}B > {}B smem",
            spec.smem_bytes()
        )));
    }
    Ok(())
}

pub fn pipeline(p: &mut Program, kernel: usize, depth: usize) {
    p.kernels[kernel].schedule.pipeline_depth = depth.max(2).min(4);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, Op};
    use crate::kir::lower_naive;

    fn tiled_program() -> (Graph, Program) {
        let mut g = Graph::new("t");
        let x = g.input("x", &[512, 512]);
        let w = g.weight("w", &[512, 512]);
        let m = g.op(Op::MatMul, &[x, w]);
        g.mark_output(m);
        let mut p = lower_naive(&g);
        p.kernels[0].schedule.block_tile = Some((64, 64, 32));
        (g, p)
    }

    #[test]
    fn requires_tile() {
        let (g, _) = tiled_program();
        let p = lower_naive(&g);
        assert!(check_pipeline(&p, 0, 2, &GpuSpec::a100()).is_err());
    }

    #[test]
    fn double_then_async_progression() {
        let (_g, mut p) = tiled_program();
        let spec = GpuSpec::a100();
        check_pipeline(&p, 0, 2, &spec).unwrap();
        pipeline(&mut p, 0, 2);
        assert_eq!(p.kernels[0].schedule.pipeline_depth, 2);
        check_pipeline(&p, 0, 3, &spec).unwrap();
        pipeline(&mut p, 0, 3);
        // cannot re-apply at same depth
        assert!(check_pipeline(&p, 0, 3, &spec).is_err());
    }

    #[test]
    fn volta_rejects_async() {
        let (_g, p) = tiled_program();
        assert!(check_pipeline(&p, 0, 3, &GpuSpec::v100()).is_err());
        assert!(check_pipeline(&p, 0, 2, &GpuSpec::v100()).is_ok());
    }

    #[test]
    fn smem_budget_enforced() {
        let (_g, mut p) = tiled_program();
        // giant tile: (256*128 + 128*256)*4 = 256KB per stage
        p.kernels[0].schedule.block_tile = Some((256, 256, 128));
        assert!(check_pipeline(&p, 0, 2, &GpuSpec::v100()).is_err());
    }
}
