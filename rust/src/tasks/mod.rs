//! Benchmark task suites mirroring the paper's Table 1:
//!
//! - KernelBench-like: Level 1 (100 single ops), Level 2 (100 fused
//!   subgraphs), Level 3 (50 small networks);
//! - TritonBench-like: G (184 real-world kernels), T (166 PyTorch-aligned
//!   interface kernels);
//! - a 200-task *training corpus* disjoint from both (different dimension
//!   draws and seed stream) used to build the offline RL trees.
//!
//! Each [`Task`] carries two graphs with identical topology: the **perf
//! graph** at paper-scale dimensions (what the analytic GPU cost model
//! prices) and the **verif graph** at small dimensions (what the
//! functional executor runs for correctness checks).

mod families;
mod kernelbench;
mod tritonbench;
mod corpus;

pub use families::{Family, Scale};
pub use corpus::training_corpus;
pub use kernelbench::{kernelbench_level, kernelbench_suite};
pub use tritonbench::{tritonbench_g, tritonbench_t};

use crate::graph::Graph;

/// Which suite a task belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Suite {
    KernelBenchL1,
    KernelBenchL2,
    KernelBenchL3,
    TritonG,
    TritonT,
    TrainCorpus,
}

impl Suite {
    pub fn label(&self) -> &'static str {
        match self {
            Suite::KernelBenchL1 => "KernelBench-L1",
            Suite::KernelBenchL2 => "KernelBench-L2",
            Suite::KernelBenchL3 => "KernelBench-L3",
            Suite::TritonG => "TritonBench-G",
            Suite::TritonT => "TritonBench-T",
            Suite::TrainCorpus => "TrainCorpus",
        }
    }
}

/// One benchmark task.
#[derive(Clone, Debug)]
pub struct Task {
    /// Stable id, e.g. "kb1_017_matmul".
    pub id: String,
    pub suite: Suite,
    pub family: Family,
    /// Paper-scale graph (costed by gpusim).
    pub graph: Graph,
    /// Small-shape twin (executed for correctness).
    pub verif_graph: Graph,
}

impl Task {
    /// Difficulty proxy used by the competence model: op count of the
    /// graph (L1 ~1-2, L2 ~2-5, L3 tens).
    pub fn complexity(&self) -> usize {
        self.graph.op_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;

    #[test]
    fn suite_sizes_match_paper() {
        assert_eq!(kernelbench_level(1).len(), 100);
        assert_eq!(kernelbench_level(2).len(), 100);
        assert_eq!(kernelbench_level(3).len(), 50);
        assert_eq!(tritonbench_g().len(), 184);
        assert_eq!(tritonbench_t().len(), 166);
        assert_eq!(training_corpus(200).len(), 200);
    }

    #[test]
    fn all_tasks_valid_and_shaped() {
        let mut all = kernelbench_suite();
        all.extend(tritonbench_g());
        all.extend(tritonbench_t());
        all.extend(training_corpus(40));
        for t in &all {
            t.graph.validate().unwrap_or_else(|e| panic!("{}: {e}", t.id));
            t.verif_graph
                .validate()
                .unwrap_or_else(|e| panic!("{}: {e}", t.id));
            infer_shapes(&t.graph);
            infer_shapes(&t.verif_graph);
            assert_eq!(
                t.graph.nodes.len(),
                t.verif_graph.nodes.len(),
                "{}: topology mismatch between perf and verif graphs",
                t.id
            );
        }
    }

    #[test]
    fn task_ids_unique() {
        let mut all = kernelbench_suite();
        all.extend(tritonbench_g());
        all.extend(tritonbench_t());
        let mut ids: Vec<&str> = all.iter().map(|t| t.id.as_str()).collect();
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n, "duplicate task ids");
    }

    #[test]
    fn verif_graphs_are_small() {
        for t in kernelbench_suite() {
            let shapes = infer_shapes(&t.verif_graph);
            let biggest = shapes.iter().map(|s| s.iter().product::<usize>()).max().unwrap();
            assert!(
                biggest <= 1 << 16,
                "{}: verif tensor too big ({biggest})",
                t.id
            );
        }
    }
}
