//! TritonBench-like suites: G (184 real-world kernels) and T (166
//! PyTorch-aligned interface kernels) — paper Table 1's mix
//! (FlashAttention, BMM, Cumsum / Adam, SGD, BatchNorm, Argmax, ...).

use super::families::Family;
use super::kernelbench::BENCH_SEED;
use super::{Suite, Task};

fn gen(suite: Suite, prefix: &str, mix: &[(Family, usize)], seed: u64) -> Vec<Task> {
    // reuse the kernelbench generator machinery
    super::kernelbench::gen_tasks_pub(suite, prefix, mix, seed)
}

/// TRITONBENCH-G: 184 real-world cases.
pub fn tritonbench_g() -> Vec<Task> {
    gen(
        Suite::TritonG,
        "tbg",
        &[
            (Family::FlashAttention, 28),
            (Family::BatchMatmul, 22),
            (Family::CumSum, 16),
            (Family::GemmSoftmax, 18),
            (Family::Geglu, 16),
            (Family::FusedLayerNorm, 20),
            (Family::CrossEntropy, 16),
            (Family::SoftmaxBwdish, 12),
            (Family::ResidualBlock, 12),
            (Family::GemmBiasAct, 14),
            (Family::Matmul, 10),
        ],
        BENCH_SEED + 10,
    )
}

/// TRITONBENCH-T: 166 PyTorch-aligned interface kernels.
pub fn tritonbench_t() -> Vec<Task> {
    gen(
        Suite::TritonT,
        "tbt",
        &[
            (Family::AdamStep, 20),
            (Family::SgdStep, 16),
            (Family::BatchNorm, 18),
            (Family::ArgMax, 14),
            (Family::Softmax, 18),
            (Family::LayerNorm, 16),
            (Family::ReduceRow, 16),
            (Family::Elementwise, 20),
            (Family::Matmul, 14),
            (Family::Conv2d, 14),
        ],
        BENCH_SEED + 11,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn g_has_real_world_mix() {
        let g = tritonbench_g();
        assert_eq!(g.len(), 184);
        assert!(g.iter().any(|t| t.family == Family::FlashAttention));
        assert!(g.iter().all(|t| t.suite == Suite::TritonG));
    }

    #[test]
    fn t_has_pytorch_aligned_mix() {
        let t = tritonbench_t();
        assert_eq!(t.len(), 166);
        assert!(t.iter().any(|t| t.family == Family::AdamStep));
        assert!(t.iter().all(|t| t.suite == Suite::TritonT));
    }
}
