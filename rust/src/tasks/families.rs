//! Task families: parameterized graph builders covering the op taxonomy of
//! KernelBench and TritonBench (Table 1). Every builder emits the *same
//! topology* at two [`Scale`]s — `Perf` (paper-scale dims, priced by
//! gpusim) and `Verif` (small dims, executed for correctness).

use crate::graph::{Graph, Op};
use crate::util::Rng;

/// Which dimension regime to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Perf,
    Verif,
}

/// Task family taxonomy (drives generation mixes and reports).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Family {
    // Level-1-style singles
    Matmul,
    BatchMatmul,
    Conv2d,
    Softmax,
    LayerNorm,
    BatchNorm,
    ReduceRow,
    ArgMax,
    CumSum,
    Elementwise,
    MaxPool,
    AvgPool,
    Transpose,
    // Level-2-style fusions
    GemmBiasAct,
    GemmReduce,
    ConvAct,
    ConvBnAct,
    AddNorm,
    GemmSoftmax,
    Geglu,
    ResidualBlock,
    // Level-3-style networks
    Mlp,
    ConvNet,
    LstmSeq,
    TransformerBlock,
    MiniGpt,
    VitBlock,
    // TritonBench-style
    FlashAttention,
    CrossEntropy,
    AdamStep,
    SgdStep,
    FusedLayerNorm,
    SoftmaxBwdish,
}

impl Family {
    pub fn label(&self) -> &'static str {
        use Family::*;
        match self {
            Matmul => "matmul",
            BatchMatmul => "bmm",
            Conv2d => "conv2d",
            Softmax => "softmax",
            LayerNorm => "layernorm",
            BatchNorm => "batchnorm",
            ReduceRow => "reduce",
            ArgMax => "argmax",
            CumSum => "cumsum",
            Elementwise => "eltwise",
            MaxPool => "maxpool",
            AvgPool => "avgpool",
            Transpose => "transpose",
            GemmBiasAct => "gemm_bias_act",
            GemmReduce => "gemm_reduce",
            ConvAct => "conv_act",
            ConvBnAct => "conv_bn_act",
            AddNorm => "add_norm",
            GemmSoftmax => "gemm_softmax",
            Geglu => "geglu",
            ResidualBlock => "residual",
            Mlp => "mlp",
            ConvNet => "convnet",
            LstmSeq => "lstm",
            TransformerBlock => "transformer",
            MiniGpt => "minigpt",
            VitBlock => "vit",
            FlashAttention => "flash_attention",
            CrossEntropy => "cross_entropy",
            AdamStep => "adam",
            SgdStep => "sgd",
            FusedLayerNorm => "fused_layernorm",
            SoftmaxBwdish => "softmax_bwd",
        }
    }
}

/// Pick perf-vs-verif dimension.
#[inline]
fn sz(scale: Scale, perf: usize, verif: usize) -> usize {
    match scale {
        Scale::Perf => perf,
        Scale::Verif => verif,
    }
}

/// Draw a power-of-two-ish dimension in [lo, hi] (perf scale).
fn dim(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    let lol = (lo as f64).log2();
    let hil = (hi as f64).log2();
    let l = rng.range_f64(lol, hil);
    let v = (2f64.powf(l)).round() as usize;
    // snap to a multiple of 16 for realism (library-friendly shapes)
    ((v + 15) / 16 * 16).clamp(lo, hi)
}

/// Build one family instance. `rng` drives the dimension draw — callers
/// must pass an rng in the same state for the Perf and Verif builds (use
/// `rng.clone()`), so both graphs share topology and draw lineage.
pub fn build(family: Family, scale: Scale, rng: &mut Rng) -> Graph {
    use Family::*;
    match family {
        Matmul => {
            let m = dim(rng, 512, 8192);
            let k = dim(rng, 512, 8192);
            let n = dim(rng, 512, 8192);
            let mut g = Graph::new("matmul");
            let x = g.input("x", &[sz(scale, m, 12), sz(scale, k, 8)]);
            let w = g.weight("w", &[sz(scale, k, 8), sz(scale, n, 10)]);
            let mm = g.op(Op::MatMul, &[x, w]);
            g.mark_output(mm);
            g
        }
        BatchMatmul => {
            let b = dim(rng, 16, 128);
            let m = dim(rng, 128, 1024);
            let k = dim(rng, 64, 512);
            let n = dim(rng, 128, 1024);
            let mut g = Graph::new("bmm");
            let x = g.input("x", &[sz(scale, b, 3), sz(scale, m, 6), sz(scale, k, 5)]);
            let y = g.input("y", &[sz(scale, b, 3), sz(scale, k, 5), sz(scale, n, 7)]);
            let o = g.op(Op::BatchMatMul, &[x, y]);
            g.mark_output(o);
            g
        }
        Conv2d => {
            let n = dim(rng, 16, 64);
            let c = dim(rng, 16, 256);
            let f = dim(rng, 32, 512);
            let hw = dim(rng, 16, 128);
            let k = *rng.choose(&[1usize, 3, 5]);
            let stride = *rng.choose(&[1usize, 2]);
            let pad = k / 2;
            let mut g = Graph::new("conv2d");
            let x = g.input(
                "x",
                &[sz(scale, n, 2), sz(scale, c, 3), sz(scale, hw, 8), sz(scale, hw, 8)],
            );
            let w = g.weight("w", &[sz(scale, f, 4), sz(scale, c, 3), k, k]);
            let o = g.op(Op::Conv2d { stride, pad }, &[x, w]);
            g.mark_output(o);
            g
        }
        Softmax => unary_rows(scale, rng, Op::Softmax, "softmax"),
        LayerNorm => unary_rows(scale, rng, Op::LayerNorm, "layernorm"),
        BatchNorm => {
            let n = dim(rng, 16, 64);
            let c = dim(rng, 32, 256);
            let hw = dim(rng, 16, 64);
            let mut g = Graph::new("batchnorm");
            let (cn, cv) = (sz(scale, c, 4), sz(scale, c, 4));
            let x = g.input(
                "x",
                &[sz(scale, n, 2), cn, sz(scale, hw, 6), sz(scale, hw, 6)],
            );
            let mean = g.weight("mean", &[cv]);
            let var = g.weight("var", &[cv]);
            let o = g.op(Op::BatchNorm2d, &[x, mean, var]);
            g.mark_output(o);
            g
        }
        ReduceRow => {
            let kind = *rng.choose(&[Op::ReduceSum, Op::ReduceMax, Op::ReduceMean]);
            unary_rows(scale, rng, kind, "reduce")
        }
        ArgMax => unary_rows(scale, rng, Op::ArgMax, "argmax"),
        CumSum => unary_rows(scale, rng, Op::CumSum, "cumsum"),
        Elementwise => {
            let rows = dim(rng, 1024, 16384);
            let cols = dim(rng, 512, 4096);
            let act = *rng.choose(&[Op::Relu, Op::Gelu, Op::Sigmoid, Op::Tanh]);
            let mut g = Graph::new("eltwise");
            let x = g.input("x", &[sz(scale, rows, 12), sz(scale, cols, 9)]);
            let y = g.input("y", &[sz(scale, rows, 12), sz(scale, cols, 9)]);
            let a = g.op(Op::Add, &[x, y]);
            let o = g.op(act, &[a]);
            g.mark_output(o);
            g
        }
        MaxPool => {
            let n = dim(rng, 16, 64);
            let c = dim(rng, 32, 256);
            let hw = dim(rng, 32, 128);
            let mut g = Graph::new("maxpool");
            let x = g.input(
                "x",
                &[sz(scale, n, 2), sz(scale, c, 3), sz(scale, hw, 8), sz(scale, hw, 8)],
            );
            let o = g.op(Op::MaxPool2d { k: 2, stride: 2 }, &[x]);
            g.mark_output(o);
            g
        }
        AvgPool => {
            let n = dim(rng, 16, 64);
            let c = dim(rng, 32, 256);
            let hw = dim(rng, 16, 64);
            let mut g = Graph::new("avgpool");
            let x = g.input(
                "x",
                &[sz(scale, n, 2), sz(scale, c, 3), sz(scale, hw, 6), sz(scale, hw, 6)],
            );
            let o = g.op(Op::GlobalAvgPool, &[x]);
            g.mark_output(o);
            g
        }
        Transpose => {
            let m = dim(rng, 1024, 8192);
            let n = dim(rng, 1024, 8192);
            let mut g = Graph::new("transpose");
            let x = g.input("x", &[sz(scale, m, 11), sz(scale, n, 13)]);
            let o = g.op(Op::Transpose2, &[x]);
            g.mark_output(o);
            g
        }
        GemmBiasAct => {
            let m = dim(rng, 512, 4096);
            let k = dim(rng, 512, 4096);
            let n = dim(rng, 512, 4096);
            let act = *rng.choose(&[Op::Relu, Op::Gelu, Op::Tanh, Op::Sigmoid]);
            let mut g = Graph::new("gemm_bias_act");
            let x = g.input("x", &[sz(scale, m, 9), sz(scale, k, 8)]);
            let w = g.weight("w", &[sz(scale, k, 8), sz(scale, n, 10)]);
            let b = g.weight("b", &[sz(scale, n, 10)]);
            let mm = g.op(Op::MatMul, &[x, w]);
            let ba = g.op(Op::BiasAdd, &[mm, b]);
            let o = g.op(act, &[ba]);
            g.mark_output(o);
            g
        }
        GemmReduce => {
            let m = dim(rng, 512, 4096);
            let k = dim(rng, 512, 4096);
            let n = dim(rng, 512, 4096);
            let red = *rng.choose(&[Op::ReduceMax, Op::ReduceSum, Op::ReduceMean]);
            let mut g = Graph::new("gemm_reduce");
            let x = g.input("x", &[sz(scale, m, 9), sz(scale, k, 8)]);
            let w = g.weight("w", &[sz(scale, k, 8), sz(scale, n, 10)]);
            let mm = g.op(Op::MatMul, &[x, w]);
            let o = g.op(red, &[mm]);
            g.mark_output(o);
            g
        }
        ConvAct => {
            let n = dim(rng, 16, 64);
            let c = dim(rng, 16, 128);
            let f = dim(rng, 32, 256);
            let hw = dim(rng, 16, 64);
            let mut g = Graph::new("conv_act");
            let x = g.input(
                "x",
                &[sz(scale, n, 2), sz(scale, c, 3), sz(scale, hw, 7), sz(scale, hw, 7)],
            );
            let w = g.weight("w", &[sz(scale, f, 4), sz(scale, c, 3), 3, 3]);
            let cv = g.op(Op::Conv2d { stride: 1, pad: 1 }, &[x, w]);
            let o = g.op(Op::Relu, &[cv]);
            g.mark_output(o);
            g
        }
        ConvBnAct => {
            let n = dim(rng, 16, 64);
            let c = dim(rng, 16, 128);
            let f = dim(rng, 32, 256);
            let hw = dim(rng, 16, 64);
            let mut g = Graph::new("conv_bn_act");
            let fc = sz(scale, f, 4);
            let x = g.input(
                "x",
                &[sz(scale, n, 2), sz(scale, c, 3), sz(scale, hw, 7), sz(scale, hw, 7)],
            );
            let w = g.weight("w", &[fc, sz(scale, c, 3), 3, 3]);
            let mean = g.weight("mean", &[fc]);
            let var = g.weight("var", &[fc]);
            let cv = g.op(Op::Conv2d { stride: 1, pad: 1 }, &[x, w]);
            let bn = g.op(Op::BatchNorm2d, &[cv, mean, var]);
            let o = g.op(Op::Relu, &[bn]);
            g.mark_output(o);
            g
        }
        AddNorm => {
            let rows = dim(rng, 1024, 8192);
            let cols = dim(rng, 512, 4096);
            let mut g = Graph::new("add_norm");
            let x = g.input("x", &[sz(scale, rows, 10), sz(scale, cols, 12)]);
            let y = g.input("y", &[sz(scale, rows, 10), sz(scale, cols, 12)]);
            let a = g.op(Op::Add, &[x, y]);
            let o = g.op(Op::LayerNorm, &[a]);
            g.mark_output(o);
            g
        }
        GemmSoftmax => {
            let m = dim(rng, 512, 4096);
            let k = dim(rng, 256, 2048);
            let n = dim(rng, 512, 4096);
            let mut g = Graph::new("gemm_softmax");
            let x = g.input("x", &[sz(scale, m, 8), sz(scale, k, 6)]);
            let w = g.weight("w", &[sz(scale, k, 6), sz(scale, n, 9)]);
            let mm = g.op(Op::MatMul, &[x, w]);
            let o = g.op(Op::Softmax, &[mm]);
            g.mark_output(o);
            g
        }
        Geglu => {
            let m = dim(rng, 512, 4096);
            let k = dim(rng, 512, 2048);
            let n = dim(rng, 512, 2048);
            let mut g = Graph::new("geglu");
            let x = g.input("x", &[sz(scale, m, 8), sz(scale, k, 7)]);
            let wa = g.weight("wa", &[sz(scale, k, 7), sz(scale, n, 9)]);
            let wb = g.weight("wb", &[sz(scale, k, 7), sz(scale, n, 9)]);
            let a = g.op(Op::MatMul, &[x, wa]);
            let b = g.op(Op::MatMul, &[x, wb]);
            let ga = g.op(Op::Gelu, &[a]);
            let o = g.op(Op::Mul, &[ga, b]);
            g.mark_output(o);
            g
        }
        ResidualBlock => {
            let rows = dim(rng, 512, 4096);
            let cols = dim(rng, 512, 2048);
            let mut g = Graph::new("residual");
            let x = g.input("x", &[sz(scale, rows, 9), sz(scale, cols, 8)]);
            let w = g.weight("w", &[sz(scale, cols, 8), sz(scale, cols, 8)]);
            let mm = g.op(Op::MatMul, &[x, w]);
            let r = g.op(Op::Relu, &[mm]);
            let a = g.op(Op::Add, &[r, x]);
            let o = g.op(Op::LayerNorm, &[a]);
            g.mark_output(o);
            g
        }
        Mlp => {
            let layers = 2 + rng.below(3); // 2-4 hidden layers
            let b = dim(rng, 256, 2048);
            let d = dim(rng, 512, 2048);
            let mut g = Graph::new("mlp");
            let mut cur = g.input("x", &[sz(scale, b, 8), sz(scale, d, 8)]);
            for li in 0..layers {
                let w = g.weight(&format!("w{li}"), &[sz(scale, d, 8), sz(scale, d, 8)]);
                let bias = g.weight(&format!("b{li}"), &[sz(scale, d, 8)]);
                let mm = g.op(Op::MatMul, &[cur, w]);
                let ba = g.op(Op::BiasAdd, &[mm, bias]);
                cur = g.op(Op::Relu, &[ba]);
            }
            g.mark_output(cur);
            g
        }
        ConvNet => {
            // VGG-style: (conv relu) x blocks + pool, then head
            let blocks = 2 + rng.below(2);
            let n = dim(rng, 16, 32);
            let mut c = 3usize;
            let mut hwp = 64usize;
            let mut hwv = 16usize;
            let mut g = Graph::new("convnet");
            let mut cur = g.input("x", &[sz(scale, n, 2), c, sz(scale, hwp, hwv), sz(scale, hwp, hwv)]);
            for bi in 0..blocks {
                let f = 32 << bi;
                let w = g.weight(&format!("w{bi}"), &[sz(scale, f, 4), sz(scale, c, if bi == 0 { 3 } else { 4 }), 3, 3]);
                let cv = g.op(Op::Conv2d { stride: 1, pad: 1 }, &[cur, w]);
                let r = g.op(Op::Relu, &[cv]);
                cur = g.op(Op::MaxPool2d { k: 2, stride: 2 }, &[r]);
                c = f;
                // spatial dims halve each block (the final values feed
                // the head's input shape via the pooled tensor)
                hwp /= 2;
                hwv /= 2;
                let _ = (hwp, hwv);
            }
            let ga = g.op(Op::GlobalAvgPool, &[cur]);
            let wh = g.weight("head", &[sz(scale, c, 4), sz(scale, 128, 6)]);
            let o = g.op(Op::MatMul, &[ga, wh]);
            g.mark_output(o);
            g
        }
        LstmSeq => {
            let steps = 2 + rng.below(3);
            let b = dim(rng, 64, 512);
            let i = dim(rng, 128, 512);
            let u = dim(rng, 128, 512);
            let (bp, ip, up) = (sz(scale, b, 4), sz(scale, i, 6), sz(scale, u, 5));
            let mut g = Graph::new("lstm");
            let h0 = g.input("h0", &[bp, up]);
            let c0 = g.input("c0", &[bp, up]);
            let w_ih = g.weight("w_ih", &[ip, 4 * up]);
            let w_hh = g.weight("w_hh", &[up, 4 * up]);
            let mut h = h0;
            for t in 0..steps {
                let xt = g.input(&format!("x{t}"), &[bp, ip]);
                h = g.op(Op::LstmCell, &[xt, h, c0, w_ih, w_hh]);
            }
            g.mark_output(h);
            g
        }
        TransformerBlock | MiniGpt | VitBlock => {
            // attention + residual + mlp; MiniGpt/Vit vary dims & depth
            let depth = match family {
                Family::MiniGpt => 2 + rng.below(2),
                _ => 1,
            };
            let s = dim(rng, 128, 1024);
            let d = dim(rng, 256, 1024);
            let (sp, dp) = (sz(scale, s, 8), sz(scale, d, 8));
            let mut g = Graph::new(family.label());
            let mut cur = g.input("x", &[sp, dp]);
            for li in 0..depth {
                let wq = g.weight(&format!("wq{li}"), &[dp, dp]);
                let wk = g.weight(&format!("wk{li}"), &[dp, dp]);
                let wv = g.weight(&format!("wv{li}"), &[dp, dp]);
                let wo = g.weight(&format!("wo{li}"), &[dp, dp]);
                let q = g.op(Op::MatMul, &[cur, wq]);
                let k = g.op(Op::MatMul, &[cur, wk]);
                let v = g.op(Op::MatMul, &[cur, wv]);
                let at = g.op(Op::Attention, &[q, k, v]);
                let proj = g.op(Op::MatMul, &[at, wo]);
                let res1 = g.op(Op::Add, &[proj, cur]);
                let ln1 = g.op(Op::LayerNorm, &[res1]);
                let w1 = g.weight(&format!("wf1_{li}"), &[dp, dp]);
                let w2 = g.weight(&format!("wf2_{li}"), &[dp, dp]);
                let f1 = g.op(Op::MatMul, &[ln1, w1]);
                let ge = g.op(Op::Gelu, &[f1]);
                let f2 = g.op(Op::MatMul, &[ge, w2]);
                let res2 = g.op(Op::Add, &[f2, ln1]);
                cur = g.op(Op::LayerNorm, &[res2]);
            }
            g.mark_output(cur);
            g
        }
        FlashAttention => {
            let s = dim(rng, 512, 4096);
            let d = dim(rng, 64, 128);
            let (sp, dp) = (sz(scale, s, 10), sz(scale, d, 8));
            let mut g = Graph::new("flash_attention");
            let q = g.input("q", &[sp, dp]);
            let k = g.input("k", &[sp, dp]);
            let v = g.input("v", &[sp, dp]);
            let o = g.op(Op::Attention, &[q, k, v]);
            g.mark_output(o);
            g
        }
        CrossEntropy => {
            let b = dim(rng, 512, 8192);
            let c = dim(rng, 1024, 32768);
            let mut g = Graph::new("cross_entropy");
            let x = g.input("logits", &[sz(scale, b, 8), sz(scale, c, 12)]);
            let sm = g.op(Op::Softmax, &[x]);
            let o = g.op(Op::ReduceMax, &[sm]);
            g.mark_output(o);
            g
        }
        AdamStep => {
            let n = dim(rng, 1 << 20, 1 << 24);
            let (rows, cols) = split2(n);
            let (rp, cp) = (sz(scale, rows, 12), sz(scale, cols, 10));
            let mut g = Graph::new("adam");
            let p = g.input("param", &[rp, cp]);
            let m = g.input("m", &[rp, cp]);
            let v = g.input("v", &[rp, cp]);
            let sq = g.op(Op::Sqrt, &[v]);
            let upd = g.op(Op::Div, &[m, sq]);
            let sc = g.op(Op::Scale(1e-3), &[upd]);
            let o = g.op(Op::Sub, &[p, sc]);
            g.mark_output(o);
            g
        }
        SgdStep => {
            let n = dim(rng, 1 << 20, 1 << 24);
            let (rows, cols) = split2(n);
            let (rp, cp) = (sz(scale, rows, 12), sz(scale, cols, 10));
            let mut g = Graph::new("sgd");
            let p = g.input("param", &[rp, cp]);
            let gr = g.input("grad", &[rp, cp]);
            let sc = g.op(Op::Scale(1e-2), &[gr]);
            let o = g.op(Op::Sub, &[p, sc]);
            g.mark_output(o);
            g
        }
        FusedLayerNorm => {
            let rows = dim(rng, 2048, 16384);
            let cols = dim(rng, 512, 8192);
            let mut g = Graph::new("fused_layernorm");
            let x = g.input("x", &[sz(scale, rows, 10), sz(scale, cols, 12)]);
            let b = g.weight("bias", &[sz(scale, cols, 12)]);
            let ln = g.op(Op::LayerNorm, &[x]);
            let ba = g.op(Op::BiasAdd, &[ln, b]);
            let o = g.op(Op::Gelu, &[ba]);
            g.mark_output(o);
            g
        }
        SoftmaxBwdish => {
            let rows = dim(rng, 1024, 8192);
            let cols = dim(rng, 512, 8192);
            let mut g = Graph::new("softmax_bwd");
            let y = g.input("y", &[sz(scale, rows, 9), sz(scale, cols, 11)]);
            let dy = g.input("dy", &[sz(scale, rows, 9), sz(scale, cols, 11)]);
            let prod = g.op(Op::Mul, &[y, dy]);
            let s = g.op(Op::ReduceSum, &[prod]);
            // broadcast (rows,) against (rows, cols) requires a trailing
            // axis; model as mul with transposed trick: use Sub on scaled
            // dy instead (keeps semantics "dy - y*sum" in spirit)
            let sc = g.op(Op::Exp, &[s]); // keep it unary; softmax-bwd-ish
            g.mark_output(prod);
            g.mark_output(sc);
            g
        }
    }
}

/// Split an element count into a 2-D (rows, cols) with cols ~ 1024.
fn split2(n: usize) -> (usize, usize) {
    let cols = 1024usize;
    ((n / cols).max(1), cols)
}

fn unary_rows(scale: Scale, rng: &mut Rng, op: Op, name: &str) -> Graph {
    let rows = dim(rng, 1024, 16384);
    let cols = dim(rng, 256, 8192);
    let mut g = Graph::new(name);
    let x = g.input("x", &[sz(scale, rows, 12), sz(scale, cols, 10)]);
    let o = g.op(op, &[x]);
    g.mark_output(o);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;

    const ALL: &[Family] = &[
        Family::Matmul, Family::BatchMatmul, Family::Conv2d, Family::Softmax,
        Family::LayerNorm, Family::BatchNorm, Family::ReduceRow, Family::ArgMax,
        Family::CumSum, Family::Elementwise, Family::MaxPool, Family::AvgPool,
        Family::Transpose, Family::GemmBiasAct, Family::GemmReduce,
        Family::ConvAct, Family::ConvBnAct, Family::AddNorm, Family::GemmSoftmax,
        Family::Geglu, Family::ResidualBlock, Family::Mlp, Family::ConvNet,
        Family::LstmSeq, Family::TransformerBlock, Family::MiniGpt,
        Family::VitBlock, Family::FlashAttention, Family::CrossEntropy,
        Family::AdamStep, Family::SgdStep, Family::FusedLayerNorm,
        Family::SoftmaxBwdish,
    ];

    #[test]
    fn every_family_builds_both_scales_with_same_topology() {
        for (fi, &fam) in ALL.iter().enumerate() {
            let mut r1 = Rng::new(100 + fi as u64);
            let mut r2 = r1.clone();
            let perf = build(fam, Scale::Perf, &mut r1);
            let verif = build(fam, Scale::Verif, &mut r2);
            perf.validate().unwrap_or_else(|e| panic!("{fam:?}: {e}"));
            verif.validate().unwrap_or_else(|e| panic!("{fam:?}: {e}"));
            assert_eq!(perf.nodes.len(), verif.nodes.len(), "{fam:?}");
            infer_shapes(&perf);
            infer_shapes(&verif);
        }
    }

    #[test]
    fn perf_graphs_are_big_verif_graphs_small() {
        let mut r1 = Rng::new(1);
        let mut r2 = r1.clone();
        let perf = build(Family::Matmul, Scale::Perf, &mut r1);
        let verif = build(Family::Matmul, Scale::Verif, &mut r2);
        let ps = infer_shapes(&perf);
        let vs = infer_shapes(&verif);
        let pmax: usize = ps.iter().map(|s| s.iter().product::<usize>()).max().unwrap();
        let vmax: usize = vs.iter().map(|s| s.iter().product::<usize>()).max().unwrap();
        assert!(pmax >= 512 * 512);
        assert!(vmax <= 4096);
    }

    #[test]
    fn dimension_draws_are_snapped() {
        let mut r = Rng::new(9);
        for _ in 0..50 {
            let d = dim(&mut r, 512, 8192);
            assert!(d >= 512 && d <= 8192);
            assert_eq!(d % 16, 0);
        }
    }
}
