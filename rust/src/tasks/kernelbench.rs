//! KernelBench-like suite generation: 100 L1 singles, 100 L2 fusions,
//! 50 L3 networks (paper Table 1). Deterministic from fixed seeds; the
//! training corpus (corpus.rs) uses a disjoint seed stream.

use super::families::{build, Family, Scale};
use super::{Suite, Task};
use crate::util::Rng;

/// Seed base for benchmark suites (corpus uses BASE+1 stream).
pub(crate) const BENCH_SEED: u64 = 0xBEAC4;

/// Shared generator (also used by tritonbench.rs / corpus.rs).
pub(crate) fn gen_tasks_pub(
    suite: Suite,
    prefix: &str,
    mix: &[(Family, usize)],
    seed: u64,
) -> Vec<Task> {
    gen_tasks(suite, prefix, mix, seed)
}

fn gen_tasks(
    suite: Suite,
    prefix: &str,
    mix: &[(Family, usize)],
    seed: u64,
) -> Vec<Task> {
    let mut out = Vec::new();
    let mut master = Rng::new(seed);
    for &(family, count) in mix {
        for i in 0..count {
            let mut r_perf = master.split((i as u64) << 8);
            let mut r_verif = r_perf.clone();
            let graph = build(family, Scale::Perf, &mut r_perf);
            let verif_graph = build(family, Scale::Verif, &mut r_verif);
            out.push(Task {
                id: format!("{prefix}_{:03}_{}", out.len(), family.label()),
                suite,
                family,
                graph,
                verif_graph,
            });
        }
    }
    out
}

/// KernelBench level 1/2/3 task lists.
pub fn kernelbench_level(level: usize) -> Vec<Task> {
    match level {
        1 => gen_tasks(
            Suite::KernelBenchL1,
            "kb1",
            &[
                (Family::Matmul, 18),
                (Family::BatchMatmul, 8),
                (Family::Conv2d, 18),
                (Family::Softmax, 10),
                (Family::LayerNorm, 8),
                (Family::BatchNorm, 6),
                (Family::ReduceRow, 8),
                (Family::ArgMax, 4),
                (Family::CumSum, 4),
                (Family::Elementwise, 8),
                (Family::MaxPool, 4),
                (Family::AvgPool, 2),
                (Family::Transpose, 2),
            ],
            BENCH_SEED,
        ),
        2 => gen_tasks(
            Suite::KernelBenchL2,
            "kb2",
            &[
                (Family::GemmBiasAct, 24),
                (Family::GemmReduce, 14),
                (Family::ConvAct, 14),
                (Family::ConvBnAct, 10),
                (Family::AddNorm, 10),
                (Family::GemmSoftmax, 10),
                (Family::Geglu, 8),
                (Family::ResidualBlock, 10),
            ],
            BENCH_SEED + 2,
        ),
        3 => gen_tasks(
            Suite::KernelBenchL3,
            "kb3",
            &[
                (Family::Mlp, 10),
                (Family::ConvNet, 10),
                (Family::LstmSeq, 8),
                (Family::TransformerBlock, 8),
                (Family::MiniGpt, 8),
                (Family::VitBlock, 6),
            ],
            BENCH_SEED + 3,
        ),
        _ => panic!("KernelBench has levels 1-3"),
    }
}

/// All 250 KernelBench tasks.
pub fn kernelbench_suite() -> Vec<Task> {
    let mut v = kernelbench_level(1);
    v.extend(kernelbench_level(2));
    v.extend(kernelbench_level(3));
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = kernelbench_level(1);
        let b = kernelbench_level(1);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.graph.nodes.len(), y.graph.nodes.len());
        }
    }

    #[test]
    fn level_complexity_ordering() {
        let c1: f64 = kernelbench_level(1).iter().map(|t| t.complexity() as f64).sum::<f64>() / 100.0;
        let c2: f64 = kernelbench_level(2).iter().map(|t| t.complexity() as f64).sum::<f64>() / 100.0;
        let c3: f64 = kernelbench_level(3).iter().map(|t| t.complexity() as f64).sum::<f64>() / 50.0;
        assert!(c1 < c2, "L1 {c1} should be simpler than L2 {c2}");
        assert!(c2 < c3, "L2 {c2} should be simpler than L3 {c3}");
    }

    #[test]
    #[should_panic]
    fn invalid_level_panics() {
        kernelbench_level(4);
    }
}
