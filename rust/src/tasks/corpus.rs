//! Training corpus: tasks for offline-trajectory generation and PPO,
//! **disjoint from the benchmark suites** (different seed stream =>
//! different dimension draws; the paper likewise trains on a curated
//! non-benchmark corpus). Mix spans all families so the policy sees every
//! hardware-exploitation pattern.

use super::families::Family;
use super::kernelbench::{gen_tasks_pub, BENCH_SEED};
use super::{Suite, Task};

/// Corpus seed stream is offset far from every benchmark seed.
const CORPUS_SEED: u64 = BENCH_SEED ^ 0x5EED_0DD;

/// Shape signature used for the disjointness filter.
fn sig(t: &Task) -> (Family, Vec<Vec<usize>>) {
    (t.family, crate::graph::infer_shapes(&t.graph))
}

/// Generate `n` training tasks (repeats cycle the mix with new dimension
/// draws). Any candidate whose (family, shape-signature) collides with a
/// benchmark task is dropped — the corpus contains **no benchmark
/// instances**, matching the paper's offline-dataset construction.
pub fn training_corpus(n: usize) -> Vec<Task> {
    let mut bench_sigs: Vec<(Family, Vec<Vec<usize>>)> = Vec::new();
    for t in super::kernelbench_suite() {
        bench_sigs.push(sig(&t));
    }
    for t in super::tritonbench_g().into_iter().chain(super::tritonbench_t()) {
        bench_sigs.push(sig(&t));
    }
    training_corpus_filtered(n, &bench_sigs)
}

fn training_corpus_filtered(
    n: usize,
    bench_sigs: &[(Family, Vec<Vec<usize>>)],
) -> Vec<Task> {
    let unit = [
        (Family::Matmul, 3),
        (Family::Conv2d, 3),
        (Family::Softmax, 2),
        (Family::LayerNorm, 1),
        (Family::ReduceRow, 1),
        (Family::Elementwise, 2),
        (Family::BatchMatmul, 1),
        (Family::GemmBiasAct, 4),
        (Family::GemmReduce, 2),
        (Family::ConvAct, 2),
        (Family::ConvBnAct, 1),
        (Family::AddNorm, 2),
        (Family::GemmSoftmax, 2),
        (Family::Geglu, 1),
        (Family::ResidualBlock, 2),
        (Family::Mlp, 2),
        (Family::ConvNet, 1),
        (Family::LstmSeq, 1),
        (Family::TransformerBlock, 2),
        (Family::FlashAttention, 2),
        (Family::FusedLayerNorm, 1),
        (Family::CrossEntropy, 1),
        (Family::AdamStep, 1),
    ]; // 40 per round
    let mut out = Vec::with_capacity(n);
    let mut round = 0u64;
    while out.len() < n {
        let tasks = gen_tasks_pub(
            Suite::TrainCorpus,
            &format!("tc{round}"),
            &unit,
            CORPUS_SEED + round * 7919,
        );
        for t in tasks {
            if out.len() >= n {
                break;
            }
            let ts = sig(&t);
            if bench_sigs.iter().any(|b| *b == ts) {
                continue; // would duplicate a benchmark instance
            }
            out.push(t);
        }
        round += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;

    #[test]
    fn corpus_disjoint_from_benchmarks_by_dims() {
        // Same family may appear, but the perf dimension draws must not
        // reproduce any benchmark task's shape signature.
        let corpus = training_corpus(80);
        let bench = crate::tasks::kernelbench_suite();
        let sig = |t: &Task| -> Vec<Vec<usize>> { infer_shapes(&t.graph) };
        let bench_sigs: Vec<_> = bench
            .iter()
            .map(|t| (t.family, sig(t)))
            .collect();
        let mut collisions = 0;
        for c in &corpus {
            let cs = sig(c);
            for (bf, bs) in &bench_sigs {
                if *bf == c.family && *bs == cs {
                    collisions += 1;
                }
            }
        }
        assert_eq!(collisions, 0, "corpus leaked benchmark shapes");
    }

    #[test]
    fn corpus_sized_and_valid() {
        let c = training_corpus(50);
        assert_eq!(c.len(), 50);
        for t in &c {
            assert_eq!(t.suite, Suite::TrainCorpus);
            t.graph.validate().unwrap();
        }
    }

    #[test]
    fn corpus_extends_beyond_one_round() {
        let c = training_corpus(90);
        assert_eq!(c.len(), 90);
        assert!(c.iter().any(|t| t.id.starts_with("tc1_")));
    }
}
