//! The learned policy: L2 network via PJRT, greedy-or-sample decoding.

use super::{Policy, PolicyDecision};
use crate::runtime::{ParamSet, PjrtRuntime};
use crate::util::Rng;

/// Learned Macro-Thinking policy backed by the `policy_fwd_b1` artifact.
pub struct PjrtPolicy<'r> {
    pub rt: &'r PjrtRuntime,
    pub params: ParamSet,
    /// Sample from the categorical (training/exploration) vs argmax
    /// (evaluation) decoding.
    pub sample: bool,
    pub label: String,
}

impl<'r> PjrtPolicy<'r> {
    pub fn new(rt: &'r PjrtRuntime, params: ParamSet, sample: bool) -> Self {
        PjrtPolicy { rt, params, sample, label: "mtmc-policy".into() }
    }
}

impl Policy for PjrtPolicy<'_> {
    fn act(&mut self, obs: &[f32], mask: &[bool], rng: &mut Rng)
           -> PolicyDecision {
        let mask_f: Vec<f32> =
            mask.iter().map(|&m| if m { 1.0 } else { 0.0 }).collect();
        let (logp, value) = self
            .rt
            .fwd_b1(&self.params, obs, &mask_f)
            .expect("policy forward failed");
        let action = if self.sample {
            rng.categorical_logp(&logp)
        } else {
            logp.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        };
        debug_assert!(mask[action], "policy sampled a masked action");
        PolicyDecision { action, logp: logp[action], value }
    }

    fn name(&self) -> String {
        self.label.clone()
    }
}

// Integration coverage lives in rust/tests/runtime_pjrt.rs (requires
// artifacts).
