//! Non-learned policies: random, heuristic-ladder (LLM-as-macro-thinker),
//! and freeform (no action space) — the Table 7 ablation arms.

use super::{Policy, PolicyDecision};
use crate::kir::MAX_REGIONS;
use crate::transform::{OptType, ACTION_DIM, STOP_ACTION};
use crate::util::Rng;

/// Uniform over valid actions.
pub struct RandomPolicy;

impl Policy for RandomPolicy {
    fn act(&mut self, _obs: &[f32], mask: &[bool], rng: &mut Rng)
           -> PolicyDecision {
        let valid: Vec<usize> = (0..ACTION_DIM).filter(|&a| mask[a]).collect();
        let action = *rng.choose(&valid);
        PolicyDecision {
            action,
            logp: -(valid.len() as f32).ln(),
            value: 0.0,
        }
    }

    fn name(&self) -> String {
        "random".into()
    }
}

/// Expert-preference ladder with mistakes: tries opt types in the order a
/// kernel engineer would (tile the hot nest, fuse, reorder, register-tile,
/// pipeline, vectorize), preferring region 0 (the hottest). With
/// probability `mistake_rate` it instead picks uniformly (a misjudged
/// proposal), and after `patience` successful picks it stops.
pub struct HeuristicPolicy {
    pub label: String,
    pub mistake_rate: f64,
    pub patience: usize,
    steps_taken: usize,
}

impl HeuristicPolicy {
    pub fn new(label: &str, mistake_rate: f64, patience: usize) -> Self {
        HeuristicPolicy {
            label: label.to_string(),
            mistake_rate,
            patience,
            steps_taken: 0,
        }
    }

    /// Profile-flavoured proposers used in the Table 7 ablation.
    pub fn gpt4o() -> Self {
        Self::new("GPT-4o-proposer", 0.50, 3)
    }
    pub fn deepseek_v3() -> Self {
        Self::new("DS-V3-proposer", 0.40, 4)
    }
    pub fn gemini_flash() -> Self {
        Self::new("GF-2.5-proposer", 0.32, 4)
    }

    const LADDER: [OptType; 8] = [
        OptType::TileShared,
        OptType::FuseEpilogue,
        OptType::Reorder,
        OptType::TileReg,
        OptType::PipelineDouble,
        OptType::FuseProducer,
        OptType::PipelineAsync,
        OptType::Vectorize,
    ];
}

impl Policy for HeuristicPolicy {
    fn act(&mut self, _obs: &[f32], mask: &[bool], rng: &mut Rng)
           -> PolicyDecision {
        self.steps_taken += 1;
        if self.steps_taken > self.patience + 1 && rng.bool(0.5) {
            return PolicyDecision { action: STOP_ACTION, logp: 0.0, value: 0.0 };
        }
        if rng.bool(self.mistake_rate) {
            let valid: Vec<usize> =
                (0..ACTION_DIM).filter(|&a| mask[a]).collect();
            return PolicyDecision {
                action: *rng.choose(&valid),
                logp: 0.0,
                value: 0.0,
            };
        }
        for opt in Self::LADDER {
            for region in 0..MAX_REGIONS {
                let idx = opt.index() * MAX_REGIONS + region;
                if mask[idx] {
                    return PolicyDecision { action: idx, logp: 0.0, value: 0.0 };
                }
            }
        }
        PolicyDecision { action: STOP_ACTION, logp: 0.0, value: 0.0 }
    }

    fn name(&self) -> String {
        self.label.clone()
    }
}

/// No action space at all: proposals are frequently outside what region
/// analysis supports — modelled as uniform draws over the *whole* action
/// set, valid or not (invalid ones are Rejected by the transform layer,
/// wasting the step, exactly what unconstrained text suggestions do).
pub struct FreeformPolicy {
    pub label: String,
    /// Probability of emitting an arbitrary (possibly invalid) proposal.
    pub wildness: f64,
    inner: HeuristicPolicy,
}

impl FreeformPolicy {
    pub fn new(label: &str, wildness: f64, mistake_rate: f64) -> Self {
        FreeformPolicy {
            label: label.to_string(),
            wildness,
            inner: HeuristicPolicy::new(label, mistake_rate, 3),
        }
    }

    pub fn gpt4o() -> Self {
        Self::new("GPT-4o-freeform", 0.65, 0.5)
    }
    pub fn deepseek_v3() -> Self {
        Self::new("DS-V3-freeform", 0.55, 0.4)
    }
    pub fn gemini_flash() -> Self {
        Self::new("GF-2.5-freeform", 0.45, 0.32)
    }
}

impl Policy for FreeformPolicy {
    fn act(&mut self, obs: &[f32], mask: &[bool], rng: &mut Rng)
           -> PolicyDecision {
        if rng.bool(self.wildness) {
            // unconstrained suggestion: ignores the mask entirely
            PolicyDecision {
                action: rng.below(ACTION_DIM),
                logp: 0.0,
                value: 0.0,
            }
        } else {
            self.inner.act(obs, mask, rng)
        }
    }

    fn name(&self) -> String {
        self.label.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mask_with(valid: &[usize]) -> Vec<bool> {
        let mut m = vec![false; ACTION_DIM];
        for &v in valid {
            m[v] = true;
        }
        m[STOP_ACTION] = true;
        m
    }

    #[test]
    fn random_respects_mask() {
        let mut p = RandomPolicy;
        let mask = mask_with(&[3, 17]);
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let d = p.act(&[], &mask, &mut rng);
            assert!(mask[d.action]);
        }
    }

    #[test]
    fn heuristic_prefers_tiling_first() {
        let mut p = HeuristicPolicy::new("test", 0.0, 10);
        // tile_shared region 0 = index 0
        let mask = mask_with(&[0, 8, 16]);
        let mut rng = Rng::new(2);
        let d = p.act(&[], &mask, &mut rng);
        assert_eq!(d.action, 0);
    }

    #[test]
    fn heuristic_eventually_stops() {
        let mut p = HeuristicPolicy::new("test", 0.0, 2);
        let mask = mask_with(&[0]);
        let mut rng = Rng::new(3);
        let mut stopped = false;
        for _ in 0..50 {
            if p.act(&[], &mask, &mut rng).action == STOP_ACTION {
                stopped = true;
                break;
            }
        }
        assert!(stopped);
    }

    #[test]
    fn freeform_emits_invalid_proposals() {
        let mut p = FreeformPolicy::new("t", 1.0, 0.0);
        let mask = mask_with(&[0]);
        let mut rng = Rng::new(4);
        let mut hit_invalid = false;
        for _ in 0..100 {
            let d = p.act(&[], &mask, &mut rng);
            if !mask[d.action] {
                hit_invalid = true;
            }
        }
        assert!(hit_invalid, "freeform never left the valid set");
    }
}
