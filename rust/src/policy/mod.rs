//! Macro-Thinking policies.
//!
//! - [`PjrtPolicy`] — the learned policy: featurized observation through
//!   the AOT-compiled L2 network (Pallas kernels inside) via PJRT. This is
//!   the paper's RL-finetuned lightweight LLM.
//! - [`RandomPolicy`] — uniform over valid actions (Table 7 "random").
//! - [`HeuristicPolicy`] — an expert-preference ladder with a per-model
//!   mistake rate: what a *prompted* general LLM does when asked to pick
//!   the next optimization within the structured action space (Table 7
//!   "w/o policy w/ AS").
//! - [`FreeformPolicy`] — proposals unconstrained by the action space,
//!   frequently invalid/unimplementable (Table 7 "w/o policy w/o AS").

mod kinds;
mod pjrt;

pub use kinds::{FreeformPolicy, HeuristicPolicy, RandomPolicy};
pub use pjrt::PjrtPolicy;

use crate::util::Rng;

/// One policy decision.
#[derive(Clone, Copy, Debug)]
pub struct PolicyDecision {
    pub action: usize,
    /// Behaviour log-probability of the chosen action (0.0 for
    /// non-probabilistic policies).
    pub logp: f32,
    /// Value estimate (0.0 for policies without a critic).
    pub value: f32,
}

/// A Macro-Thinking decision maker.
pub trait Policy {
    /// Choose an action given the observation and validity mask.
    fn act(&mut self, obs: &[f32], mask: &[bool], rng: &mut Rng)
           -> PolicyDecision;
    fn name(&self) -> String;
}
