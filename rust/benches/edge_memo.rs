//! Edge-memo transition-replay benchmark (the ISSUE-3 perf deliverable).
//!
//! Runs a table3-shaped slice — episode-heavy MTMC methods (the greedy
//! surrogate under two macro labels, so cross-method transition reuse is
//! real) plus a baseline over KernelBench levels 1-3 — through the
//! [`BatchRunner`] in two regimes:
//!
//! - **cold**: a session built with `edge_memo(false)`, re-timed on an
//!   already-run runner so the cost/analysis caches are warm — the
//!   delta isolates the transition memo itself;
//! - **warm**: a default session, second sweep over the same runner —
//!   every episode transition replays from the session-shared
//!   transposition table instead of re-running micro-coding +
//!   verification + pricing.
//!
//! Per-task outcomes are asserted byte-identical across *all* runs (both
//! regimes, both repetitions), and the warm shared-memo sweep must be
//! strictly faster than the cold one. Prints timings, speedup and the
//! memo's hit/miss/eviction stats.
//!
//! Env knobs: QIMENG_LIMIT (tasks per level, default 8), QIMENG_THREADS,
//! QIMENG_REPS (timed repetitions per mode, default 3; best time wins).

use qimeng_mtmc::engine::Session;
use qimeng_mtmc::eval::{
    roster_sweep, BatchCfg, BatchRunner, MacroKind, Method, SuiteResult,
};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::microcode::ProfileId;
use qimeng_mtmc::tasks::{kernelbench_level, Task};

fn main() {
    let limit: usize = std::env::var("QIMENG_LIMIT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let threads: usize = std::env::var("QIMENG_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(qimeng_mtmc::util::parallel::default_threads);
    let reps: usize = std::env::var("QIMENG_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3)
        .max(1);

    // the episode-heavy slice of the Table 3 roster; the two MTMC rows
    // drive identical greedy-surrogate episodes, so a shared memo pays
    // even within a single cold sweep
    let methods = vec![
        Method::Mtmc {
            macro_kind: MacroKind::GreedyLookahead,
            micro: ProfileId::GeminiPro25,
        },
        Method::Mtmc {
            macro_kind: MacroKind::LearnedOrGreedy { params_path: None },
            micro: ProfileId::GeminiPro25,
        },
        Method::Mtmc {
            macro_kind: MacroKind::GreedyLookahead,
            micro: ProfileId::GeminiFlash25,
        },
        Method::Baseline { profile: ProfileId::Gpt4o },
    ];
    let blocks: Vec<(GpuSpec, Vec<Task>)> = (1..=3usize)
        .map(|level| {
            let mut tasks = kernelbench_level(level);
            tasks.truncate(limit);
            (GpuSpec::a100(), tasks)
        })
        .collect();
    let units: usize =
        blocks.iter().map(|(_, t)| t.len()).sum::<usize>() * methods.len();
    println!(
        "== edge-memo bench: table3-shaped slice, {units} units, \
         {threads} threads, best of {reps} =="
    );

    // one session + runner per regime; in both, sweep 0 warms the
    // cost/analysis caches so the timed sweeps differ only in
    // transition replay
    let cold_session = Session::builder().edge_memo(false).build();
    let warm_session = Session::default();
    let cold_runner =
        BatchRunner::new(
            BatchCfg { threads, ..Default::default() },
            &cold_session,
        )
        .expect("batch runner");
    let warm_runner =
        BatchRunner::new(
            BatchCfg { threads, ..Default::default() },
            &warm_session,
        )
        .expect("batch runner");
    let sweep_jobs = roster_sweep(&methods, &blocks);
    let mut reference: Option<Vec<SuiteResult>> = None;
    let mut check = |results: Vec<SuiteResult>| match &reference {
        None => reference = Some(results),
        Some(base) => assert_outcomes_identical(base, &results),
    };
    check(cold_runner.run(&sweep_jobs)); // warm the cost/analysis caches
    check(warm_runner.run(&sweep_jobs)); // populate the edge memo

    let mut cold_best = f64::INFINITY;
    let mut warm_best = f64::INFINITY;
    for rep in 0..reps {
        let t0 = std::time::Instant::now();
        check(cold_runner.run(&sweep_jobs));
        let cold = t0.elapsed().as_secs_f64();
        cold_best = cold_best.min(cold);
        let t0 = std::time::Instant::now();
        check(warm_runner.run(&sweep_jobs));
        let warm = t0.elapsed().as_secs_f64();
        warm_best = warm_best.min(warm);
        println!("rep {rep}: cold {cold:.3}s, warm shared-memo {warm:.3}s");
    }
    let s = warm_session.edges().expect("warm session has a memo").stats();
    println!(
        "cold {cold_best:.3}s, warm {warm_best:.3}s -> {:.2}x faster; \
         edge-memo {} hits / {} misses ({:.1}% hit rate, {} evictions)",
        cold_best / warm_best,
        s.hits, s.misses, 100.0 * s.hit_rate(), s.evictions
    );
    assert!(
        cold_session.edges().is_none(),
        "cold regime must not even build a transition memo"
    );
    assert!(s.hits > 0, "warm regime must replay transitions");
    assert!(
        warm_best < cold_best,
        "warm shared-memo sweep must be strictly faster than cold \
         (warm {warm_best:.3}s vs cold {cold_best:.3}s)"
    );
    println!("per-task outcomes byte-identical across all runs");
}

/// Memoized and cold sweeps must agree bit-for-bit, outcome-for-outcome.
fn assert_outcomes_identical(a: &[SuiteResult], b: &[SuiteResult]) {
    assert_eq!(a.len(), b.len());
    for (ra, rb) in a.iter().zip(b) {
        assert_eq!(ra.metrics, rb.metrics, "{} metrics diverged", ra.method);
        assert_eq!(ra.outcomes.len(), rb.outcomes.len());
        for (x, y) in ra.outcomes.iter().zip(&rb.outcomes) {
            assert_eq!(x.task_id, y.task_id);
            assert_eq!(x.compiled, y.compiled);
            assert_eq!(x.correct, y.correct);
            assert_eq!(
                x.speedup.to_bits(),
                y.speedup.to_bits(),
                "{}: warm vs cold speedup bits diverged",
                x.task_id
            );
        }
    }
}
