//! Regenerates **Figure 1** (paradigm comparison) as a table: one
//! representative task slice per KernelBench level through the four
//! paradigms — (a) expert libraries (PyTorch Eager), (b) general-purpose
//! LLM, (c) domain-finetuned LLM, (d) MTMC. The LLM paradigms run as one
//! [`BatchRunner`] sweep.

use qimeng_mtmc::engine::Session;
use qimeng_mtmc::eval::{BatchCfg, BatchJob, BatchRunner, MacroKind, Method};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::microcode::ProfileId;
use qimeng_mtmc::report::{append_report, Table};
use qimeng_mtmc::tasks::kernelbench_level;

fn main() {
    let t0 = std::time::Instant::now();
    let spec = GpuSpec::a100();
    let session = Session::default();
    let runner = BatchRunner::new(BatchCfg::default(), &session)
        .expect("batch runner");
    let paradigms: Vec<(&str, Option<Method>)> = vec![
        ("(a) expert libraries (Eager)", None),
        ("(b) general-purpose LLM (Claude-4)",
         Some(Method::Baseline { profile: ProfileId::Claude4Sonnet })),
        ("(c) finetuned LLM (Kevin-32B)",
         Some(Method::Baseline { profile: ProfileId::Kevin32B })),
        ("(d) MTMC (ours)",
         Some(Method::Mtmc {
             macro_kind: MacroKind::GreedyLookahead,
             micro: ProfileId::GeminiPro25,
         })),
    ];

    // one job per (LLM paradigm, level), in paradigm-major order
    let mut jobs = Vec::new();
    for (_, method) in &paradigms {
        let Some(m) = method else { continue };
        for level in 1..=3usize {
            let tasks: Vec<_> =
                kernelbench_level(level).into_iter().step_by(8).collect();
            jobs.push(BatchJob::new(m.clone(), spec.clone(), tasks));
        }
    }
    let results = runner.run(&jobs);

    let mut table = Table::new(
        "Figure 1 — kernel generation paradigms (12 tasks/level, A100)",
        &["Paradigm", "L1 Acc/Speedup", "L2 Acc/Speedup", "L3 Acc/Speedup"],
    );
    let mut ri = 0usize;
    for (name, method) in &paradigms {
        let mut cells = vec![name.to_string()];
        match method {
            None => {
                for _ in 1..=3 {
                    cells.push("100% / 1.00 (def)".into());
                }
            }
            Some(_) => {
                for _ in 1..=3 {
                    let r = &results[ri];
                    ri += 1;
                    cells.push(format!(
                        "{:.0}% / {:.2}",
                        r.metrics.exec_acc * 100.0,
                        r.metrics.mean_speedup
                    ));
                }
            }
        }
        table.row(cells);
    }
    let text = table.render();
    println!("{text}");
    println!(
        "paper's Figure 1 story: (a) correct but generic, (b) often wrong \
         and slow, (c) correct-ish but slow, (d) correct AND fast."
    );
    println!("fig1 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    let _ = append_report(std::path::Path::new("data/reports/fig1.txt"), &text);
}
