//! Regenerates **Table 7** (Macro-Thinking ablation) on 10% of
//! KernelBench: learned policy w/ action space; prompted-LLM proposers w/
//! action space (random, GPT-4o, DS-V3, GF-2.5); and unconstrained
//! proposers w/o action space.

use qimeng_mtmc::eval::{evaluate, EvalCfg, MacroKind, Method};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::microcode::ProfileId;
use qimeng_mtmc::paths;
use qimeng_mtmc::report::{append_report, Table};
use qimeng_mtmc::tasks::{kernelbench_level, Task};

fn ten_percent(level: usize) -> Vec<Task> {
    // every 10th task: 10/10/5 across levels
    kernelbench_level(level)
        .into_iter()
        .step_by(10)
        .collect()
}

fn main() {
    let t0 = std::time::Instant::now();
    let spec = GpuSpec::a100();
    let cfg = EvalCfg::default();
    let micro = ProfileId::GeminiFlash25;

    // the three lightweight-LLM policy variants of the paper map to three
    // training seeds of the same policy class; without trained params the
    // greedy surrogate (with distinct eval seeds) stands in
    let settings: Vec<(&str, String, MacroKind)> = vec![
        ("w/ policy w/ AS", "DS-Coder".into(), MacroKind::LearnedOrGreedy {
            params_path: Some(paths::default_policy_path()),
        }),
        ("w/ policy w/ AS", "Llama".into(), MacroKind::GreedyLookahead),
        ("w/ policy w/ AS", "Qwen".into(), MacroKind::GreedyLookahead),
        ("w/o policy w/ AS", "random".into(), MacroKind::Random),
        ("w/o policy w/ AS", "GPT-4o".into(), MacroKind::Heuristic {
            label: "GPT-4o".into(), mistake_rate: 0.50,
        }),
        ("w/o policy w/ AS", "DS-V3".into(), MacroKind::Heuristic {
            label: "DS-V3".into(), mistake_rate: 0.40,
        }),
        ("w/o policy w/ AS", "GF-2.5".into(), MacroKind::Heuristic {
            label: "GF-2.5".into(), mistake_rate: 0.32,
        }),
        ("w/o policy w/o AS", "GPT-4o".into(), MacroKind::Freeform {
            label: "GPT-4o".into(), wildness: 0.65, mistake_rate: 0.50,
        }),
        ("w/o policy w/o AS", "DS-V3".into(), MacroKind::Freeform {
            label: "DS-V3".into(), wildness: 0.55, mistake_rate: 0.40,
        }),
        ("w/o policy w/o AS", "GF-2.5".into(), MacroKind::Freeform {
            label: "GF-2.5".into(), wildness: 0.45, mistake_rate: 0.32,
        }),
    ];

    let mut table = Table::new(
        "Table 7 — Macro-Thinking ablation (10% of KernelBench, A100)",
        &["Setting", "Method", "L1 Acc/Speedup", "L2 Acc/Speedup",
          "L3 Acc/Speedup"],
    );
    for (i, (setting, name, kind)) in settings.iter().enumerate() {
        let mut cells = vec![setting.to_string(), name.clone()];
        for level in 1..=3 {
            let tasks = ten_percent(level);
            let mut c = cfg.clone();
            c.seed = cfg.seed ^ ((i as u64) << 40); // variant seeds
            let method = Method::Mtmc { macro_kind: kind.clone(), micro };
            let r = evaluate(&method, &tasks, &spec, &c);
            cells.push(format!(
                "{:.0}% / {:.2}",
                r.metrics.exec_acc * 100.0,
                r.metrics.mean_speedup
            ));
        }
        table.row(cells);
    }
    let text = table.render();
    println!("{text}");
    println!(
        "paper reference: w/ policy 80-100% acc with ~1x-1.8x speedups; \
         w/o policy w/ AS drops to 40-70% acc, ~0.15-0.8x; w/o AS drops \
         further to 10-50% acc, 0.02-0.5x."
    );
    println!("table7 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    let _ = append_report(std::path::Path::new("data/reports/table7.txt"),
                          &text);
}
