//! Regenerates **Table 3**: Triton kernel generation on KernelBench —
//! execute accuracy, fast_1/fast_2 and mean speedup vs PyTorch Eager,
//! across V100/A100/H100 and the full method roster.
//!
//! The whole gpu × level × method × task sweep runs as one
//! [`BatchRunner`] unit queue, so workers stay busy across cell
//! boundaries.
//!
//! Env knobs: QIMENG_GPUS="A100" (comma list), QIMENG_LIMIT=20 (tasks per
//! level), QIMENG_THREADS=N, QIMENG_JSONL=path (stream per-task records,
//! enriched with cached eager baselines).

use qimeng_mtmc::engine::Session;
use qimeng_mtmc::eval::{roster_sweep, table3_methods, BatchCfg, BatchRunner};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::paths;
use qimeng_mtmc::report::{append_report, metric_cells, Table};
use qimeng_mtmc::tasks::{kernelbench_level, Task};

fn main() {
    let t0 = std::time::Instant::now();
    let gpus: Vec<GpuSpec> = std::env::var("QIMENG_GPUS")
        .map(|s| {
            s.split(',')
                .filter_map(|n| GpuSpec::by_name(n.trim()))
                .collect()
        })
        .unwrap_or_else(|_| GpuSpec::all());
    let limit: usize = std::env::var("QIMENG_LIMIT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(usize::MAX);
    let mut batch_cfg = BatchCfg::default();
    if let Ok(t) = std::env::var("QIMENG_THREADS") {
        batch_cfg.threads = t.parse().unwrap_or(batch_cfg.threads);
    }
    if let Ok(path) = std::env::var("QIMENG_JSONL") {
        batch_cfg.sink = Some(std::path::PathBuf::from(path));
    }
    let session = Session::default();
    let runner = BatchRunner::new(batch_cfg, &session).expect("batch runner");
    let params = Some(paths::default_policy_path());
    let methods = table3_methods(params);

    let mut blocks: Vec<(GpuSpec, Vec<Task>)> = Vec::new();
    let mut cells = Vec::new(); // (spec name, level, #tasks) per job block
    for spec in &gpus {
        for level in 1..=3usize {
            let mut tasks = kernelbench_level(level);
            tasks.truncate(limit);
            cells.push((spec.name, level, tasks.len()));
            blocks.push((spec.clone(), tasks));
        }
    }
    let jobs = roster_sweep(&methods, &blocks);
    let results = runner.run(&jobs);

    let mut report = String::new();
    for (ci, (gpu_name, level, n_tasks)) in cells.iter().enumerate() {
        let mut table = Table::new(
            &format!(
                "Table 3 — KernelBench Level {level} on {gpu_name} \
                 ({n_tasks} tasks)"
            ),
            &["Method", "Accuracy(%)", "fast1/fast2(%)", "Mean Speedup"],
        );
        for r in &results[ci * methods.len()..(ci + 1) * methods.len()] {
            table.row(metric_cells(r, false));
        }
        let text = table.render();
        println!("{text}");
        report.push_str(&text);
        report.push('\n');
    }
    println!(
        "paper reference (H100, Gemini-2.5-Pro + Ours): L1 100% acc, 67/13 \
         fast1/fast2; L2 99%, 86/12; L3 70%, 34/2; all >1x mean speedup at \
         L1-2 — compare shapes, not absolutes (simulated substrate)."
    );
    println!(
        "table3 regenerated in {:.1}s ({} units)",
        t0.elapsed().as_secs_f64(),
        jobs.iter().map(|j| j.tasks.len()).sum::<usize>()
    );
    let (hits, misses) =
        session.cost().map_or((0, 0), |c| c.stats());
    if hits + misses > 0 {
        println!("cost-cache: {hits} hits / {misses} misses");
    }
    let _ = append_report(std::path::Path::new("data/reports/table3.txt"),
                          &report);
    if runner.sink_failed() {
        eprintln!("JSONL sink reported I/O failures; output is truncated");
        std::process::exit(1);
    }
}
