//! Regenerates **Table 3**: Triton kernel generation on KernelBench —
//! execute accuracy, fast_1/fast_2 and mean speedup vs PyTorch Eager,
//! across V100/A100/H100 and the full method roster.
//!
//! Env knobs: QIMENG_GPUS="A100" (comma list), QIMENG_LIMIT=20 (tasks per
//! level), QIMENG_THREADS=N.

use qimeng_mtmc::eval::{evaluate, table3_methods, EvalCfg};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::paths;
use qimeng_mtmc::report::{append_report, metric_cells, Table};
use qimeng_mtmc::tasks::kernelbench_level;

fn main() {
    let t0 = std::time::Instant::now();
    let gpus: Vec<GpuSpec> = std::env::var("QIMENG_GPUS")
        .map(|s| {
            s.split(',')
                .filter_map(|n| GpuSpec::by_name(n.trim()))
                .collect()
        })
        .unwrap_or_else(|_| GpuSpec::all());
    let limit: usize = std::env::var("QIMENG_LIMIT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(usize::MAX);
    let mut cfg = EvalCfg::default();
    if let Ok(t) = std::env::var("QIMENG_THREADS") {
        cfg.threads = t.parse().unwrap_or(cfg.threads);
    }
    let params = Some(paths::default_policy_path());
    let methods = table3_methods(params);

    let mut report = String::new();
    for spec in &gpus {
        for level in 1..=3usize {
            let mut tasks = kernelbench_level(level);
            tasks.truncate(limit);
            let mut table = Table::new(
                &format!(
                    "Table 3 — KernelBench Level {level} on {} ({} tasks)",
                    spec.name,
                    tasks.len()
                ),
                &["Method", "Accuracy(%)", "fast1/fast2(%)", "Mean Speedup"],
            );
            for method in &methods {
                let r = evaluate(method, &tasks, spec, &cfg);
                table.row(metric_cells(&r, false));
            }
            let text = table.render();
            println!("{text}");
            report.push_str(&text);
            report.push('\n');
        }
    }
    println!(
        "paper reference (H100, Gemini-2.5-Pro + Ours): L1 100% acc, 67/13 \
         fast1/fast2; L2 99%, 86/12; L3 70%, 34/2; all >1x mean speedup at \
         L1-2 — compare shapes, not absolutes (simulated substrate)."
    );
    println!("table3 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    let _ = append_report(std::path::Path::new("data/reports/table3.txt"),
                          &report);
}
