//! Hot-path micro-benchmarks (the §Perf deliverable): the macro-thinking
//! inference step and its components. Regenerates the EXPERIMENTS.md
//! §Perf numbers.

use qimeng_mtmc::env::{EnvConfig, OptimEnv};
use qimeng_mtmc::gpusim::{program_time_us, GpuSpec};
use qimeng_mtmc::microcode::{LlmProfile, ProfileId};
use qimeng_mtmc::paths;
use qimeng_mtmc::runtime::{ParamSet, PjrtRuntime};
use qimeng_mtmc::tasks::kernelbench_level;
use qimeng_mtmc::transform::action_mask;
use qimeng_mtmc::util::stats::bench;
use qimeng_mtmc::util::Rng;

fn main() {
    let spec = GpuSpec::a100();
    let tasks = kernelbench_level(2);
    let task = &tasks[0];
    let l3 = kernelbench_level(3);
    let big = &l3[l3.len() - 1];
    let shapes = qimeng_mtmc::graph::infer_shapes(&task.graph);
    let env = OptimEnv::new(task, spec.clone(),
                            LlmProfile::get(ProfileId::GeminiPro25),
                            EnvConfig::default(), 1);

    println!("== hotpath micro-benchmarks ==");

    let s = bench(200, 300, || {
        std::hint::black_box(program_time_us(
            &env.state.program, &task.graph, &shapes, &spec,
        ));
    });
    println!("cost_model(L2 task, {} kernels): {s}", env.state.program.kernels.len());

    let big_shapes = qimeng_mtmc::graph::infer_shapes(&big.graph);
    let big_env = OptimEnv::new(big, spec.clone(),
                                LlmProfile::get(ProfileId::GeminiPro25),
                                EnvConfig::default(), 1);
    let s = bench(50, 300, || {
        std::hint::black_box(program_time_us(
            &big_env.state.program, &big.graph, &big_shapes, &spec,
        ));
    });
    println!("cost_model(L3 task, {} kernels): {s}",
             big_env.state.program.kernels.len());

    let s = bench(100, 300, || {
        std::hint::black_box(action_mask(
            &env.state.program, &task.graph, &shapes, &spec,
        ));
    });
    println!("action_mask(L2 task): {s}");

    let mask = env.mask();
    let s = bench(100, 300, || {
        std::hint::black_box(env.observe(&mask));
    });
    println!("featurize(L2 task): {s}");

    // full env step (micro_step incl. transform + competence + pricing)
    let s = bench(50, 500, || {
        let mut e = OptimEnv::new(task, spec.clone(),
                                  LlmProfile::get(ProfileId::GeminiPro25),
                                  EnvConfig::default(), 2);
        std::hint::black_box(e.step(0));
    });
    println!("env_step incl. setup (L2 task): {s}");

    // learned-policy inference (needs artifacts)
    match PjrtRuntime::load(&paths::artifacts_dir()) {
        Ok(rt) => {
            let params = ParamSet::init(&rt.meta.raw, 3).unwrap();
            let mut rng = Rng::new(4);
            let obs: Vec<f32> =
                (0..rt.meta.obs_dim).map(|_| rng.normal() as f32).collect();
            let maskf = vec![1.0f32; rt.meta.act_dim];
            let s = bench(200, 500, || {
                std::hint::black_box(rt.fwd_b1(&params, &obs, &maskf).unwrap());
            });
            println!("pjrt fwd_b1 (policy inference): {s}");
        }
        Err(_) => println!("pjrt fwd_b1: SKIP (run `make artifacts`)"),
    }

    // end-to-end macro-thinking episode (greedy surrogate)
    let s = bench(10, 1000, || {
        let mut e = OptimEnv::new(task, spec.clone(),
                                  LlmProfile::get(ProfileId::GeminiPro25),
                                  EnvConfig::default(), 5);
        let mut guard = 0;
        while !e.state.done && guard < 20 {
            let mask = e.mask();
            let a = (0..mask.len()).find(|&a| mask[a]).unwrap();
            e.step(a);
            guard += 1;
        }
        std::hint::black_box(e.state.best_speedup);
    });
    println!("full episode (first-valid policy, L2 task): {s}");
}
