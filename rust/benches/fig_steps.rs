//! Regenerates the appendix **steps ablation** (referenced in §5.3):
//! MTMC accuracy/speedup vs optimization-step budget, against baseline
//! LLM re-sampling (best-of-n single-pass draws). MTMC saturates within a
//! few steps; re-sampling plateaus almost immediately.

use qimeng_mtmc::env::EnvConfig;
use qimeng_mtmc::eval::{evaluate, EvalCfg, MacroKind, Method};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::microcode::{
    check_correct, single_pass_generate, CheckOutcome, LlmProfile,
    ProfileId, SinglePassMode, SinglePassOutcome,
};
use qimeng_mtmc::report::{append_report, Table};
use qimeng_mtmc::tasks::kernelbench_level;
use qimeng_mtmc::util::Rng;

fn main() {
    let t0 = std::time::Instant::now();
    let spec = GpuSpec::a100();
    let tasks: Vec<_> =
        kernelbench_level(2).into_iter().step_by(5).collect(); // 20 tasks

    let mut table = Table::new(
        "Steps ablation — MTMC step budget vs LLM re-sampling (20 L2 tasks)",
        &["Budget", "MTMC Acc/Speedup", "Resample Acc/Speedup"],
    );
    for budget in [1usize, 2, 4, 6, 8, 12] {
        // MTMC with a budget of exactly `budget` attempted actions (the
        // env used to need a +1 here to compensate for truncating the
        // final attempt away; it no longer does)
        let cfg = EvalCfg {
            env: EnvConfig { max_steps: budget, ..Default::default() },
            ..Default::default()
        };
        let r = evaluate(
            &Method::Mtmc {
                macro_kind: MacroKind::GreedyLookahead,
                micro: ProfileId::GeminiFlash25,
            },
            &tasks, &spec, &cfg,
        );
        // best-of-`budget` re-sampling of single-pass generation
        let profile = LlmProfile::get(ProfileId::GeminiFlash25);
        let mut correct = 0usize;
        let mut speedups = 0.0f64;
        for (ti, task) in tasks.iter().enumerate() {
            let shapes = qimeng_mtmc::graph::infer_shapes(&task.graph);
            let aff = qimeng_mtmc::gpusim::library_affinity(&task.id);
            let eager = qimeng_mtmc::gpusim::eager_time_us(
                &task.graph, &shapes, &spec, aff,
            );
            let mut best = 0.0f64;
            let mut any_correct = false;
            let mut rng = Rng::new(0x5EED ^ (ti as u64) << 8);
            for _ in 0..budget {
                if let SinglePassOutcome::Generated(p) = single_pass_generate(
                    &task.graph, &shapes, &profile, &spec,
                    &SinglePassMode::Freeform, false, &mut rng,
                ) {
                    if check_correct(&p, &task.verif_graph, 2, ti as u64)
                        == CheckOutcome::Correct
                    {
                        any_correct = true;
                        let s = eager
                            / qimeng_mtmc::gpusim::program_time_us(
                                &p, &task.graph, &shapes, &spec,
                            );
                        best = best.max(s);
                    }
                }
            }
            if any_correct {
                correct += 1;
                speedups += best;
            }
        }
        table.row(vec![
            format!("{budget}"),
            format!("{:.0}% / {:.2}", r.metrics.exec_acc * 100.0,
                    r.metrics.mean_speedup),
            format!("{:.0}% / {:.2}", correct as f64 / tasks.len() as f64 * 100.0,
                    speedups / tasks.len() as f64),
        ]);
    }
    let text = table.render();
    println!("{text}");
    println!(
        "paper reference (appendix): MTMC reaches peak within a few steps; \
         LLM re-sampling cannot promote through more samples."
    );
    println!("fig_steps regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    let _ = append_report(std::path::Path::new("data/reports/fig_steps.txt"),
                          &text);
}
