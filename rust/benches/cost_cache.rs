//! Cost-cache pricing benchmark (the ISSUE-2 perf deliverable).
//!
//! Runs a table3-shaped slice — the pricing-heavy methods (greedy
//! lookahead MTMC and the greedy-plan ablation) plus one baseline over
//! KernelBench levels 1-3 — twice through the [`BatchRunner`]: once with
//! pricing routed through the session's `CostCache` and once priced cold
//! (a session built with `cost_cache(false)`). Per-task outcomes must be
//! byte-identical; only wall-clock may differ. Prints both timings, the
//! speedup, and the cache hit rate.
//!
//! Env knobs: QIMENG_LIMIT (tasks per level, default 8), QIMENG_THREADS,
//! QIMENG_REPS (timed repetitions per mode, default 3; best time wins).

use qimeng_mtmc::engine::Session;
use qimeng_mtmc::eval::{
    roster_sweep, BatchCfg, BatchRunner, MacroKind, Method, SuiteResult,
};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::microcode::ProfileId;
use qimeng_mtmc::tasks::{kernelbench_level, Task};

fn sweep_results(use_cache: bool, threads: usize,
                 blocks: &[(GpuSpec, Vec<Task>)], methods: &[Method])
                 -> (Vec<SuiteResult>, f64, (usize, usize)) {
    let session = Session::builder().cost_cache(use_cache).build();
    let runner = BatchRunner::new(
        BatchCfg { threads, ..Default::default() },
        &session,
    )
    .expect("batch runner");
    let jobs = roster_sweep(methods, blocks);
    let t0 = std::time::Instant::now();
    let results = runner.run(&jobs);
    let stats = session.cost().map_or((0, 0), |c| c.stats());
    (results, t0.elapsed().as_secs_f64(), stats)
}

fn main() {
    let limit: usize = std::env::var("QIMENG_LIMIT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let threads: usize = std::env::var("QIMENG_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(qimeng_mtmc::util::parallel::default_threads);
    let reps: usize = std::env::var("QIMENG_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3)
        .max(1);

    // the pricing-heavy slice of the Table 3 roster: every episode step
    // prices all valid lookahead candidates
    let methods = vec![
        Method::Mtmc {
            macro_kind: MacroKind::GreedyLookahead,
            micro: ProfileId::GeminiPro25,
        },
        Method::Mtmc {
            macro_kind: MacroKind::GreedyLookahead,
            micro: ProfileId::GeminiFlash25,
        },
        Method::MtmcNoHier { micro: ProfileId::GeminiFlash25 },
        Method::Baseline { profile: ProfileId::Gpt4o },
    ];
    let blocks: Vec<(GpuSpec, Vec<Task>)> = (1..=3usize)
        .map(|level| {
            let mut tasks = kernelbench_level(level);
            tasks.truncate(limit);
            (GpuSpec::a100(), tasks)
        })
        .collect();
    let units: usize =
        blocks.iter().map(|(_, t)| t.len()).sum::<usize>() * methods.len();
    println!(
        "== cost-cache bench: table3-shaped slice, {units} units, \
         {threads} threads, best of {reps} =="
    );

    let mut cold_best = f64::INFINITY;
    let mut warm_best = f64::INFINITY;
    let mut warm_stats = (0usize, 0usize);
    let mut reference: Option<Vec<SuiteResult>> = None;
    for rep in 0..reps {
        for use_cache in [false, true] {
            let (results, dt, stats) =
                sweep_results(use_cache, threads, &blocks, &methods);
            if use_cache {
                warm_best = warm_best.min(dt);
                warm_stats = stats;
            } else {
                cold_best = cold_best.min(dt);
            }
            match &reference {
                None => reference = Some(results),
                Some(base) => assert_outcomes_identical(base, &results),
            }
            println!(
                "rep {rep} {}: {dt:.3}s",
                if use_cache { "cached" } else { "cold  " }
            );
        }
    }
    let (hits, misses) = warm_stats;
    println!(
        "cold {cold_best:.3}s, cached {warm_best:.3}s -> {:.2}x faster; \
         cache {hits} hits / {misses} misses ({:.1}% hit rate)",
        cold_best / warm_best,
        100.0 * hits as f64 / (hits + misses).max(1) as f64
    );
    println!("per-task outcomes byte-identical across all runs");
}

/// Cached and cold sweeps must agree bit-for-bit, outcome-for-outcome.
fn assert_outcomes_identical(a: &[SuiteResult], b: &[SuiteResult]) {
    assert_eq!(a.len(), b.len());
    for (ra, rb) in a.iter().zip(b) {
        assert_eq!(ra.metrics, rb.metrics, "{} metrics diverged", ra.method);
        assert_eq!(ra.outcomes.len(), rb.outcomes.len());
        for (x, y) in ra.outcomes.iter().zip(&rb.outcomes) {
            assert_eq!(x.task_id, y.task_id);
            assert_eq!(x.compiled, y.compiled);
            assert_eq!(x.correct, y.correct);
            assert_eq!(
                x.speedup.to_bits(),
                y.speedup.to_bits(),
                "{}: cached vs cold speedup bits diverged",
                x.task_id
            );
        }
    }
}
