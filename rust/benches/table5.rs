//! Regenerates **Table 5**: MTMC execution time (ms) on KernelBench
//! matmul-family operators with Triton vs CUDA generation targets. The
//! paper's point: MTMC scales to CUDA on operators the LLM knows well
//! (matmul family); the gap vs Triton reflects language proficiency, not
//! the framework.

use qimeng_mtmc::eval::{evaluate, EvalCfg, MacroKind, Method};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::microcode::ProfileId;
use qimeng_mtmc::report::{append_report, Table};
use qimeng_mtmc::tasks::{kernelbench_level, Family, Task};

fn main() {
    let t0 = std::time::Instant::now();
    let spec = GpuSpec::a100();
    // 7 matmul-family operators (the paper's task ids 1,2,6,7,8,9,13 are
    // matmul variants; we take the first 7 matmul/bmm tasks of L1)
    let tasks: Vec<Task> = kernelbench_level(1)
        .into_iter()
        .filter(|t| matches!(t.family, Family::Matmul | Family::BatchMatmul))
        .take(7)
        .collect();
    let method = Method::Mtmc {
        macro_kind: MacroKind::GreedyLookahead,
        micro: ProfileId::GeminiPro25,
    };
    let mut triton_cfg = EvalCfg::default();
    triton_cfg.seed = 0x7AB5;
    let mut cuda_cfg = triton_cfg.clone();
    cuda_cfg.cuda = true;

    let r_triton = evaluate(&method, &tasks, &spec, &triton_cfg);
    let r_cuda = evaluate(&method, &tasks, &spec, &cuda_cfg);

    let mut table = Table::new(
        "Table 5 — MTMC execution time (ms) per matmul operator, by target",
        &["Task", "MTMC (Triton)", "MTMC (CUDA)"],
    );
    let shapes_ms = |r: &qimeng_mtmc::eval::SuiteResult, i: usize| -> String {
        let o = &r.outcomes[i];
        if !o.correct {
            return "fail".into();
        }
        let task = &tasks[i];
        let shapes = qimeng_mtmc::graph::infer_shapes(&task.graph);
        let aff = qimeng_mtmc::gpusim::library_affinity(&task.id);
        let eager_us =
            qimeng_mtmc::gpusim::eager_time_us(&task.graph, &shapes, &spec, aff);
        format!("{:.2}", eager_us / o.speedup / 1000.0)
    };
    for (i, task) in tasks.iter().enumerate() {
        table.row(vec![
            task.id.clone(),
            shapes_ms(&r_triton, i),
            shapes_ms(&r_cuda, i),
        ]);
    }
    let text = table.render();
    println!("{text}");
    println!(
        "paper reference: CUDA within ~0.7-1.2x of Triton on matmul ops \
         (1.38 vs 1.38, 1.66 vs 1.36 ms, ...) — both targets produce \
         working high-performance kernels."
    );
    println!("table5 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    let _ = append_report(std::path::Path::new("data/reports/table5.txt"),
                          &text);
}
