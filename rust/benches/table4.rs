//! Regenerates **Table 4**: TritonBench (G and T) on A100 — call
//! accuracy, execute accuracy, fast_1/fast_2, mean speedup. Runs the
//! suite × method sweep through one [`BatchRunner`] unit queue.
//!
//! Env knobs: QIMENG_LIMIT, QIMENG_THREADS.

use qimeng_mtmc::engine::Session;
use qimeng_mtmc::eval::{roster_sweep, table4_methods, BatchCfg, BatchRunner};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::paths;
use qimeng_mtmc::report::{append_report, metric_cells, Table};
use qimeng_mtmc::tasks::{tritonbench_g, tritonbench_t, Task};

fn main() {
    let t0 = std::time::Instant::now();
    let limit: usize = std::env::var("QIMENG_LIMIT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(usize::MAX);
    let mut batch_cfg = BatchCfg::default();
    if let Ok(t) = std::env::var("QIMENG_THREADS") {
        batch_cfg.threads = t.parse().unwrap_or(batch_cfg.threads);
    }
    if let Ok(path) = std::env::var("QIMENG_JSONL") {
        batch_cfg.sink = Some(std::path::PathBuf::from(path));
    }
    let session = Session::default();
    let runner = BatchRunner::new(batch_cfg, &session).expect("batch runner");
    let spec = GpuSpec::a100();
    let methods = table4_methods(Some(paths::default_policy_path()));

    let suites = [
        ("TRITONBENCH-G", tritonbench_g()),
        ("TRITONBENCH-T", tritonbench_t()),
    ];
    let mut blocks: Vec<(GpuSpec, Vec<Task>)> = Vec::new();
    let mut labels = Vec::new(); // (suite name, #tasks)
    for (name, tasks) in &suites {
        let mut tasks = tasks.clone();
        tasks.truncate(limit);
        labels.push((*name, tasks.len()));
        blocks.push((spec.clone(), tasks));
    }
    let results = runner.run(&roster_sweep(&methods, &blocks));

    let mut report = String::new();
    for (bi, (name, n_tasks)) in labels.iter().enumerate() {
        let mut table = Table::new(
            &format!("Table 4 — {name} on A100 ({n_tasks} tasks)"),
            &["Method", "CallAcc(%)", "ExecAcc(%)", "fast1/fast2(%)",
              "Mean Speedup"],
        );
        for r in &results[bi * methods.len()..(bi + 1) * methods.len()] {
            table.row(metric_cells(r, true));
        }
        let text = table.render();
        println!("{text}");
        report.push_str(&text);
        report.push('\n');
    }
    println!(
        "paper reference (GF-2.5 + Ours): G 32.61/22.83 call/exec acc, \
         9.78/1.63 fast, 0.34x; T 64.46/54.82, 19.28/3.01, 0.64x; \
         KernelLLM collapses to 1-4% exec acc on both."
    );
    println!("table4 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    let (hits, misses) =
        session.cost().map_or((0, 0), |c| c.stats());
    if hits + misses > 0 {
        println!("cost-cache: {hits} hits / {misses} misses");
    }
    let _ = append_report(std::path::Path::new("data/reports/table4.txt"),
                          &report);
    if runner.sink_failed() {
        eprintln!("JSONL sink reported I/O failures; output is truncated");
        std::process::exit(1);
    }
}
