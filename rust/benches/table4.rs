//! Regenerates **Table 4**: TritonBench (G and T) on A100 — call
//! accuracy, execute accuracy, fast_1/fast_2, mean speedup.
//!
//! Env knobs: QIMENG_LIMIT, QIMENG_THREADS.

use qimeng_mtmc::eval::{evaluate, table4_methods, EvalCfg};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::paths;
use qimeng_mtmc::report::{append_report, metric_cells, Table};
use qimeng_mtmc::tasks::{tritonbench_g, tritonbench_t};

fn main() {
    let t0 = std::time::Instant::now();
    let limit: usize = std::env::var("QIMENG_LIMIT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(usize::MAX);
    let mut cfg = EvalCfg::default();
    if let Ok(t) = std::env::var("QIMENG_THREADS") {
        cfg.threads = t.parse().unwrap_or(cfg.threads);
    }
    let spec = GpuSpec::a100();
    let methods = table4_methods(Some(paths::default_policy_path()));

    let mut report = String::new();
    for (name, mut tasks) in [
        ("TRITONBENCH-G", tritonbench_g()),
        ("TRITONBENCH-T", tritonbench_t()),
    ] {
        tasks.truncate(limit);
        let mut table = Table::new(
            &format!("Table 4 — {name} on A100 ({} tasks)", tasks.len()),
            &["Method", "CallAcc(%)", "ExecAcc(%)", "fast1/fast2(%)",
              "Mean Speedup"],
        );
        for method in &methods {
            let r = evaluate(method, &tasks, &spec, &cfg);
            table.row(metric_cells(&r, true));
        }
        let text = table.render();
        println!("{text}");
        report.push_str(&text);
        report.push('\n');
    }
    println!(
        "paper reference (GF-2.5 + Ours): G 32.61/22.83 call/exec acc, \
         9.78/1.63 fast, 0.34x; T 64.46/54.82, 19.28/3.01, 0.64x; \
         KernelLLM collapses to 1-4% exec acc on both."
    );
    println!("table4 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    let _ = append_report(std::path::Path::new("data/reports/table4.txt"),
                          &report);
}
