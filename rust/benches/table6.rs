//! Regenerates **Table 6** (Micro-Coding ablation): multi-step MTMC vs
//! handing the full optimization plan to the LLM in one prompt
//! ("w/o Hier") for Gemini-2.5-Flash and DeepSeek-V3 micro-coders.
//! The variant × level sweep runs through one [`BatchRunner`] queue.

use qimeng_mtmc::engine::Session;
use qimeng_mtmc::eval::{table6_variants, BatchCfg, BatchJob, BatchRunner};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::report::{append_report, Table};
use qimeng_mtmc::tasks::kernelbench_level;

fn main() {
    let t0 = std::time::Instant::now();
    let spec = GpuSpec::a100();
    let limit: usize = std::env::var("QIMENG_LIMIT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(usize::MAX);
    let mut batch_cfg = BatchCfg::default();
    if let Ok(t) = std::env::var("QIMENG_THREADS") {
        batch_cfg.threads = t.parse().unwrap_or(batch_cfg.threads);
    }
    if let Ok(path) = std::env::var("QIMENG_JSONL") {
        batch_cfg.sink = Some(std::path::PathBuf::from(path));
    }
    let session = Session::default();
    let runner = BatchRunner::new(batch_cfg, &session).expect("batch runner");

    let variants = table6_variants();

    let mut jobs = Vec::new();
    for (_, method) in &variants {
        for level in 1..=3usize {
            let mut tasks = kernelbench_level(level);
            tasks.truncate(limit);
            jobs.push(BatchJob::new(method.clone(), spec.clone(), tasks));
        }
    }
    let results = runner.run(&jobs);

    let mut table = Table::new(
        "Table 6 — multi-step (ours) vs single-pass (w/o Hier), A100",
        &["Method", "L1 Acc/Speedup", "L2 Acc/Speedup", "L3 Acc/Speedup"],
    );
    for (vi, (name, _)) in variants.iter().enumerate() {
        let mut cells = vec![name.clone()];
        for r in &results[vi * 3..(vi + 1) * 3] {
            cells.push(format!(
                "{:.0}% / {:.2}",
                r.metrics.exec_acc * 100.0,
                r.metrics.mean_speedup
            ));
        }
        table.row(cells);
    }
    let text = table.render();
    println!("{text}");
    println!(
        "paper reference: GF-2.5 w/o Hier 60/32/10% acc vs + Ours 94/97/64%; \
         DS-V3 w/o Hier 41/16/6% vs + Ours 78/59/36% — single-pass craters \
         at L2/L3."
    );
    println!("table6 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    let _ = append_report(std::path::Path::new("data/reports/table6.txt"),
                          &text);
    if runner.sink_failed() {
        eprintln!("JSONL sink reported I/O failures; output is truncated");
        std::process::exit(1);
    }
}
