//! Regenerates **Table 6** (Micro-Coding ablation): multi-step MTMC vs
//! handing the full optimization plan to the LLM in one prompt
//! ("w/o Hier") for Gemini-2.5-Flash and DeepSeek-V3 micro-coders.

use qimeng_mtmc::eval::{evaluate, EvalCfg, MacroKind, Method};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::microcode::ProfileId;
use qimeng_mtmc::report::{append_report, Table};
use qimeng_mtmc::tasks::kernelbench_level;

fn main() {
    let t0 = std::time::Instant::now();
    let spec = GpuSpec::a100();
    let limit: usize = std::env::var("QIMENG_LIMIT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(usize::MAX);
    let cfg = EvalCfg::default();
    let mut table = Table::new(
        "Table 6 — multi-step (ours) vs single-pass (w/o Hier), A100",
        &["Method", "L1 Acc/Speedup", "L2 Acc/Speedup", "L3 Acc/Speedup"],
    );
    let micros =
        [("GF-2.5", ProfileId::GeminiFlash25), ("DS-V3", ProfileId::DeepSeekV3)];
    let mut report_rows = Vec::new();
    for (name, micro) in micros {
        for (suffix, method) in [
            ("w/o Hier", Method::MtmcNoHier { micro }),
            ("+ Ours", Method::Mtmc {
                macro_kind: MacroKind::GreedyLookahead,
                micro,
            }),
        ] {
            let mut cells = vec![format!("{name} {suffix}")];
            for level in 1..=3 {
                let mut tasks = kernelbench_level(level);
                tasks.truncate(limit);
                let r = evaluate(&method, &tasks, &spec, &cfg);
                cells.push(format!(
                    "{:.0}% / {:.2}",
                    r.metrics.exec_acc * 100.0,
                    r.metrics.mean_speedup
                ));
            }
            report_rows.push(cells.clone());
            table.row(cells);
        }
    }
    let text = table.render();
    println!("{text}");
    println!(
        "paper reference: GF-2.5 w/o Hier 60/32/10% acc vs + Ours 94/97/64%; \
         DS-V3 w/o Hier 41/16/6% vs + Ours 78/59/36% — single-pass craters \
         at L2/L3."
    );
    println!("table6 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    let _ = append_report(std::path::Path::new("data/reports/table6.txt"),
                          &text);
}
