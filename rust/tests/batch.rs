//! BatchRunner integration: thread-count determinism (the guard for the
//! sharded-queue refactor of `par_map` + `BatchRunner`), equivalence with
//! the one-shot `evaluate`, and the JSONL sink contract. Cache policy
//! lives on the [`Session`] each runner borrows.

use qimeng_mtmc::engine::Session;
use qimeng_mtmc::env::{CachedEdge, EdgeMemo, StepSignal};
use qimeng_mtmc::eval::{
    evaluate, BatchCfg, BatchJob, BatchRunner, EvalCfg, MacroKind, Method,
};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::microcode::ProfileId;
use qimeng_mtmc::tasks::kernelbench_level;
use qimeng_mtmc::util::json::Json;

fn mtmc() -> Method {
    Method::Mtmc {
        macro_kind: MacroKind::GreedyLookahead,
        micro: ProfileId::GeminiFlash25,
    }
}

/// The headline guard: `evaluate` with `threads = 1` and `threads = 8`
/// must produce byte-identical `Metrics` for a fixed seed on a
/// KernelBench level-1 slice. Seeds derive from (cfg.seed, task index),
/// never from thread identity, so the sharded queue cannot perturb them.
#[test]
fn evaluate_threads_1_vs_8_byte_identical_metrics() {
    let tasks = kernelbench_level(1)[..12].to_vec();
    let spec = GpuSpec::a100();
    for method in [
        mtmc(),
        Method::Baseline { profile: ProfileId::DeepSeekR1 },
        Method::MtmcNoHier { micro: ProfileId::GeminiFlash25 },
    ] {
        let cfg1 = EvalCfg { threads: 1, seed: 0xD00D, ..Default::default() };
        let cfg8 = EvalCfg { threads: 8, seed: 0xD00D, ..Default::default() };
        let a = evaluate(&method, &tasks, &spec, &cfg1);
        let b = evaluate(&method, &tasks, &spec, &cfg8);
        assert_eq!(a.metrics, b.metrics, "{}", a.method);
        assert_eq!(
            format!("{:?}", a.metrics),
            format!("{:?}", b.metrics),
            "{}: Metrics must be byte-identical across thread counts",
            a.method
        );
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.task_id, y.task_id);
            assert_eq!(x.compiled, y.compiled);
            assert_eq!(x.correct, y.correct);
            assert_eq!(x.speedup.to_bits(), y.speedup.to_bits(),
                       "{}: speedup bits differ", x.task_id);
        }
    }
}

#[test]
fn batch_runner_threads_1_vs_8_byte_identical_metrics() {
    let tasks = kernelbench_level(1)[..12].to_vec();
    let jobs = |seed: u64| -> Vec<BatchJob> {
        let mut job = BatchJob::new(mtmc(), GpuSpec::h100(), tasks.clone());
        job.cfg = EvalCfg { seed, ..Default::default() };
        vec![job]
    };
    let s1 = Session::default();
    let r1 = BatchRunner::new(
        BatchCfg { threads: 1, ..Default::default() },
        &s1,
    )
    .unwrap()
    .run(&jobs(0xFEED));
    let s8 = Session::default();
    let r8 = BatchRunner::new(
        BatchCfg { threads: 8, ..Default::default() },
        &s8,
    )
    .unwrap()
    .run(&jobs(0xFEED));
    assert_eq!(r1[0].metrics, r8[0].metrics);
    assert_eq!(format!("{:?}", r1[0].metrics), format!("{:?}", r8[0].metrics));
}

#[test]
fn batch_sweep_matches_per_suite_evaluate() {
    let kb1 = kernelbench_level(1)[..8].to_vec();
    let kb2 = kernelbench_level(2)[..8].to_vec();
    let jobs = vec![
        BatchJob::new(mtmc(), GpuSpec::a100(), kb1),
        BatchJob::new(
            Method::Baseline { profile: ProfileId::GeminiPro25 },
            GpuSpec::v100(),
            kb2,
        ),
    ];
    let session = Session::default();
    let runner =
        BatchRunner::new(BatchCfg { threads: 6, ..Default::default() },
                         &session)
            .unwrap();
    let batched = runner.run(&jobs);
    for (job, got) in jobs.iter().zip(&batched) {
        let direct = evaluate(&job.method, &job.tasks, &job.gpu, &job.cfg);
        assert_eq!(got.metrics, direct.metrics, "{}", got.method);
    }
}

/// The pricing cache must be invisible in results: a greedy-lookahead
/// MTMC sweep (the cache's hottest consumer) produces byte-identical
/// per-task outcomes with the session's cost tier on and off, at any
/// thread count.
#[test]
fn cost_cache_on_off_byte_identical_across_thread_counts() {
    let tasks = kernelbench_level(2)[..8].to_vec();
    let mk_jobs = || -> Vec<BatchJob> {
        let mut job = BatchJob::new(mtmc(), GpuSpec::a100(), tasks.clone());
        job.cfg = EvalCfg { seed: 0xCAFE, ..Default::default() };
        vec![job]
    };
    let mut runs = Vec::new();
    for threads in [1, 8] {
        for use_cache in [true, false] {
            let session = Session::builder().cost_cache(use_cache).build();
            let runner =
                BatchRunner::new(
                    BatchCfg { threads, ..Default::default() },
                    &session,
                )
                .unwrap();
            let r = runner.run(&mk_jobs());
            if use_cache {
                let (hits, _) = session.cost().unwrap().stats();
                assert!(hits > 0,
                        "greedy lookahead must hit the pricing cache");
            } else {
                assert!(session.cost().is_none(),
                        "cost_cache(false) must not build the cache");
            }
            runs.push(r.into_iter().next().unwrap());
        }
    }
    let base = &runs[0];
    for r in &runs[1..] {
        assert_eq!(base.metrics, r.metrics);
        assert_eq!(base.outcomes.len(), r.outcomes.len());
        for (x, y) in base.outcomes.iter().zip(&r.outcomes) {
            assert_eq!(x.task_id, y.task_id);
            assert_eq!(x.compiled, y.compiled);
            assert_eq!(x.correct, y.correct);
            assert_eq!(x.speedup.to_bits(), y.speedup.to_bits(),
                       "{}: cached vs cold speedup bits differ", x.task_id);
        }
    }
}

#[test]
fn jsonl_sink_records_are_parseable_and_complete() {
    let dir = std::env::temp_dir().join("qimeng_batch_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("kb1.jsonl");
    let tasks = kernelbench_level(1)[..6].to_vec();
    let session = Session::default();
    let runner = BatchRunner::new(
        BatchCfg { threads: 4, sink: Some(path.clone()), ..Default::default() },
        &session,
    )
    .unwrap();
    let results = runner.run(&[BatchJob::new(mtmc(), GpuSpec::a100(), tasks)]);
    let text = std::fs::read_to_string(&path).unwrap();
    let mut seen: Vec<String> = Vec::new();
    for line in text.lines() {
        let v = Json::parse(line).unwrap_or_else(|e| panic!("bad line: {e}"));
        seen.push(v.get("task").and_then(|j| j.as_str()).unwrap().to_string());
        assert_eq!(v.get("gpu").and_then(|j| j.as_str()), Some("A100"));
        assert!(v.get("method").and_then(|j| j.as_str()).is_some());
    }
    seen.sort();
    let mut expect: Vec<String> =
        results[0].outcomes.iter().map(|o| o.task_id.clone()).collect();
    expect.sort();
    assert_eq!(seen, expect, "one record per unit, no dupes/losses");
}

/// The tentpole guard at the BatchRunner level: a sweep whose methods
/// walk identical episode trees (the greedy surrogate under two macro
/// labels) through one session-shared [`EdgeMemo`] must stream
/// byte-identical JSONL outcomes at every thread count — the memo is
/// populated in whatever order the threads race, but replays are
/// deterministic.
#[test]
fn edge_memo_shared_across_threads_identical_jsonl() {
    let dir = std::env::temp_dir().join("qimeng_edge_memo_threads");
    std::fs::create_dir_all(&dir).unwrap();
    let tasks = kernelbench_level(2)[..6].to_vec();
    let jobs = vec![
        BatchJob::new(mtmc(), GpuSpec::a100(), tasks.clone()),
        // LearnedOrGreedy with no params falls back to the greedy
        // surrogate: identical episodes, so every transition the first
        // job paid for replays from the shared memo here
        BatchJob::new(
            Method::Mtmc {
                macro_kind: MacroKind::LearnedOrGreedy { params_path: None },
                micro: ProfileId::GeminiFlash25,
            },
            GpuSpec::a100(),
            tasks,
        ),
    ];
    let mut sorted_lines: Vec<Vec<String>> = Vec::new();
    for (i, threads) in [1usize, 2, 8].into_iter().enumerate() {
        let path = dir.join(format!("t{threads}.jsonl"));
        let session = Session::default();
        let runner = BatchRunner::new(
            BatchCfg { threads, sink: Some(path.clone()),
                       ..Default::default() },
            &session,
        )
        .unwrap();
        runner.run(&jobs);
        let stats = session.edges().unwrap().stats();
        assert_eq!(stats.hits + stats.misses, stats.lookups,
                   "stats identity broken at {threads} threads");
        assert!(stats.hits > 0,
                "cross-method episode reuse must hit the shared memo");
        let mut lines: Vec<String> = std::fs::read_to_string(&path)
            .unwrap()
            .lines()
            .map(String::from)
            .collect();
        lines.sort();
        sorted_lines.push(lines);
        assert_eq!(sorted_lines[0], sorted_lines[i],
                   "JSONL outcomes diverged at {threads} threads");
    }
    assert_eq!(sorted_lines[0].len(), 12, "one record per unit");
}

/// Sweep outcomes must be byte-identical with the edge memo and analysis
/// cache on and off (mirroring the cost-cache guard above).
#[test]
fn edge_memo_and_analysis_cache_on_off_byte_identical() {
    let tasks = kernelbench_level(2)[..6].to_vec();
    let mk_jobs = || -> Vec<BatchJob> {
        let mut job = BatchJob::new(mtmc(), GpuSpec::h100(), tasks.clone());
        job.cfg = EvalCfg { seed: 0xBEEF, ..Default::default() };
        vec![job]
    };
    let mut runs = Vec::new();
    for (edge, analysis) in [(true, true), (true, false), (false, true),
                             (false, false)] {
        let session = Session::builder()
            .edge_memo(edge)
            .analysis_cache(analysis)
            .build();
        let runner =
            BatchRunner::new(BatchCfg { threads: 4, ..Default::default() },
                             &session)
                .unwrap();
        let r = runner.run(&mk_jobs());
        if !edge {
            assert!(session.edges().is_none(),
                    "edge_memo(false) must not build the table");
        }
        if !analysis {
            assert!(session.analysis().is_none(),
                    "analysis_cache(false) must not build the cache");
        } else {
            assert!(session.analysis().unwrap().stats().hits > 0,
                    "episodes revisit states; analysis must hit");
        }
        runs.push(r.into_iter().next().unwrap());
    }
    let base = &runs[0];
    for r in &runs[1..] {
        assert_eq!(base.metrics, r.metrics);
        for (x, y) in base.outcomes.iter().zip(&r.outcomes) {
            assert_eq!(x.task_id, y.task_id);
            assert_eq!(x.compiled, y.compiled);
            assert_eq!(x.correct, y.correct);
            assert_eq!(x.speedup.to_bits(), y.speedup.to_bits(),
                       "{}: cache combo changed the outcome", x.task_id);
        }
    }
}

/// Stats sanity: `hits + misses == lookups` always, and eviction counts
/// are monotone across repeated sweeps over one session.
#[test]
fn edge_memo_stats_sane_and_evictions_monotone() {
    let tasks = kernelbench_level(1)[..6].to_vec();
    let jobs = vec![BatchJob::new(mtmc(), GpuSpec::a100(), tasks)];
    let session = Session::default();
    let runner =
        BatchRunner::new(BatchCfg { threads: 3, ..Default::default() },
                         &session)
            .unwrap();
    runner.run(&jobs);
    let s1 = session.edges().unwrap().stats();
    assert_eq!(s1.hits + s1.misses, s1.lookups);
    runner.run(&jobs);
    let s2 = session.edges().unwrap().stats();
    assert_eq!(s2.hits + s2.misses, s2.lookups);
    assert!(s2.lookups > s1.lookups, "second sweep must look edges up");
    assert_eq!(s2.misses, s1.misses,
               "a repeated sweep replays entirely from the warm memo");
    assert!(s2.evictions >= s1.evictions, "eviction count must be monotone");

    // direct eviction pressure: same-shard keys (identical high bits)
    // against a 2-entry table
    let tiny = EdgeMemo::with_capacity(2);
    let edge = CachedEdge {
        program: None,
        signal: StepSignal::Rejected,
        speedup: 1.0,
        from_disk: false,
    };
    let mut last_evictions = 0;
    for k in 0..10u64 {
        tiny.insert(k, edge.clone());
        let e = tiny.stats().evictions;
        assert!(e >= last_evictions, "evictions must never decrease");
        last_evictions = e;
    }
    assert!(last_evictions >= 9, "cap-1 shard must evict on every insert");
    assert_eq!(tiny.len(), 1);
    let s = tiny.stats();
    assert_eq!(s.hits + s.misses, s.lookups);
}
