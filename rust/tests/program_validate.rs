//! Unit tests for every reachable `Program::validate` error path, with
//! exact error-string assertions — these messages are wrapped verbatim
//! into `Structure` diagnostics by `kir::verify` and into the
//! `lower_checked` error, so their wording is observable output.
//!
//! (The `input node {n} assigned to a kernel` arm of the coverage sweep
//! is defensive dead code: the per-kernel scan returns `kernel {ki}
//! contains input node {n}` before any input's coverage count can move,
//! so that is the string asserted here.)

use qimeng_mtmc::graph::{Graph, Op};
use qimeng_mtmc::kir::{lower_naive, Kernel, Program, Schedule};

/// x @ w -> relu, with the node ids of all four graph nodes.
fn gemm_relu() -> (Graph, usize, usize, usize, usize) {
    let mut g = Graph::new("t");
    let x = g.input("x", &[64, 64]);
    let w = g.weight("w", &[64, 64]);
    let mm = g.op(Op::MatMul, &[x, w]);
    let r = g.op(Op::Relu, &[mm]);
    g.mark_output(r);
    (g, x, w, mm, r)
}

fn kernel(nodes: Vec<usize>) -> Kernel {
    Kernel { nodes, schedule: Schedule::default(), name: "k".into() }
}

fn program(kernels: Vec<Kernel>) -> Program {
    Program { kernels, mutations: Vec::new(), compile_broken: false }
}

#[test]
fn naive_lowering_validates() {
    let (g, ..) = gemm_relu();
    assert_eq!(lower_naive(&g).validate(&g), Ok(()));
}

#[test]
fn empty_kernel_is_rejected() {
    let (g, ..) = gemm_relu();
    let mut p = lower_naive(&g);
    p.kernels[0].nodes.clear();
    assert_eq!(p.validate(&g), Err("kernel 0 is empty".to_string()));
}

#[test]
fn unsorted_kernel_nodes_are_rejected() {
    let (g, _, _, mm, r) = gemm_relu();
    let p = program(vec![kernel(vec![r, mm])]);
    assert_eq!(
        p.validate(&g),
        Err("kernel 0 nodes not topo-sorted".to_string())
    );
}

#[test]
fn input_node_in_a_kernel_is_rejected() {
    let (g, x, _, mm, r) = gemm_relu();
    let p = program(vec![kernel(vec![x, mm]), kernel(vec![r])]);
    assert_eq!(
        p.validate(&g),
        Err(format!("kernel 0 contains input node {x}"))
    );
}

#[test]
fn pipeline_without_block_tile_is_rejected() {
    let (g, ..) = gemm_relu();
    let mut p = lower_naive(&g);
    assert!(p.kernels[0].schedule.block_tile.is_none());
    p.kernels[0].schedule.pipeline_depth = 2;
    assert_eq!(
        p.validate(&g),
        Err("kernel 0 pipelined without block tile (nothing to stage)"
            .to_string())
    );
}

#[test]
fn double_covered_node_is_rejected() {
    let (g, _, _, mm, r) = gemm_relu();
    let p = program(vec![kernel(vec![mm]), kernel(vec![mm, r])]);
    let name = &g.nodes[mm].name;
    assert_eq!(
        p.validate(&g),
        Err(format!("node {mm} ({name}) covered 2 times"))
    );
}

#[test]
fn uncovered_node_is_rejected() {
    let (g, _, _, mm, r) = gemm_relu();
    let p = program(vec![kernel(vec![mm])]);
    let name = &g.nodes[r].name;
    assert_eq!(
        p.validate(&g),
        Err(format!("node {r} ({name}) covered 0 times"))
    );
}

#[test]
fn consumer_before_producer_is_rejected() {
    let (g, _, _, mm, r) = gemm_relu();
    // each kernel is internally fine; the execution order is not
    let p = program(vec![kernel(vec![r]), kernel(vec![mm])]);
    assert_eq!(
        p.validate(&g),
        Err(format!("kernel 0 consumes node {mm} from later kernel 1"))
    );
}
