//! PJRT integration tests: artifacts -> rust runtime -> numbers.
//! Require `make artifacts` (skipped with a clear message otherwise).

use qimeng_mtmc::env::OBS_DIM;
use qimeng_mtmc::runtime::{ParamSet, PjrtRuntime, TrainBatch, TrainState};
use qimeng_mtmc::transform::ACTION_DIM;
use qimeng_mtmc::util::Rng;
use std::path::Path;

fn runtime() -> Option<PjrtRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("meta.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(PjrtRuntime::load(&dir).expect("artifact load"))
}

fn params(rt: &PjrtRuntime, seed: u64) -> ParamSet {
    ParamSet::init(&rt.meta.raw, seed).unwrap()
}

#[test]
fn meta_matches_rust_constants() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.meta.obs_dim, OBS_DIM);
    assert_eq!(rt.meta.act_dim, ACTION_DIM);
    assert_eq!(rt.platform(), "cpu");
}

#[test]
fn fwd_b1_distribution_is_masked_and_normalised() {
    let Some(rt) = runtime() else { return };
    let p = params(&rt, 1);
    let mut rng = Rng::new(2);
    let obs: Vec<f32> = (0..OBS_DIM).map(|_| rng.normal() as f32).collect();
    let mut mask = vec![0.0f32; ACTION_DIM];
    for i in [0usize, 7, 13, ACTION_DIM - 1] {
        mask[i] = 1.0;
    }
    let (logp, value) = rt.fwd_b1(&p, &obs, &mask).unwrap();
    assert_eq!(logp.len(), ACTION_DIM);
    assert!(value.is_finite());
    // probabilities over the valid set sum to 1
    let psum: f32 = logp
        .iter()
        .zip(&mask)
        .filter(|(_, &m)| m > 0.0)
        .map(|(&lp, _)| lp.exp())
        .sum();
    assert!((psum - 1.0).abs() < 1e-4, "masked prob mass = {psum}");
    // masked lanes are un-sampleable
    for (i, &lp) in logp.iter().enumerate() {
        if mask[i] == 0.0 {
            assert!(lp < -1e8, "masked lane {i} has logp {lp}");
        }
    }
}

#[test]
fn fwd_batch_agrees_with_b1() {
    let Some(rt) = runtime() else { return };
    let p = params(&rt, 3);
    let b = rt.meta.eval_batch;
    let mut rng = Rng::new(4);
    let obs: Vec<f32> = (0..b * OBS_DIM).map(|_| rng.normal() as f32).collect();
    let mask = vec![1.0f32; b * ACTION_DIM];
    let (logp_b, value_b) = rt.fwd_batch(&p, &obs, &mask).unwrap();
    for row in [0usize, b / 2, b - 1] {
        let (logp_1, value_1) = rt
            .fwd_b1(&p, &obs[row * OBS_DIM..(row + 1) * OBS_DIM],
                    &mask[row * ACTION_DIM..(row + 1) * ACTION_DIM])
            .unwrap();
        for a in 0..ACTION_DIM {
            let d = (logp_b[row * ACTION_DIM + a] - logp_1[a]).abs();
            assert!(d < 1e-4, "row {row} action {a} differs by {d}");
        }
        assert!((value_b[row] - value_1).abs() < 1e-4);
    }
}

#[test]
fn train_step_reduces_loss_on_fixed_batch() {
    let Some(rt) = runtime() else { return };
    let mut state = TrainState::new(params(&rt, 5));
    let b = rt.meta.train_batch;
    let mut rng = Rng::new(6);
    let obs: Vec<f32> = (0..b * OBS_DIM).map(|_| rng.normal() as f32).collect();
    let mut mask = vec![1.0f32; b * ACTION_DIM];
    for i in 0..b {
        // random sparsity, Stop always valid
        for a in 0..ACTION_DIM - 1 {
            if rng.bool(0.4) {
                mask[i * ACTION_DIM + a] = 0.0;
            }
        }
    }
    let act: Vec<i32> = (0..b)
        .map(|i| {
            (0..ACTION_DIM)
                .find(|&a| mask[i * ACTION_DIM + a] > 0.0)
                .unwrap() as i32
        })
        .collect();
    let old_logp: Vec<f32> =
        (0..b).map(|_| -2.0 + 0.1 * rng.normal() as f32).collect();
    let adv: Vec<f32> = (0..b).map(|_| rng.normal() as f32).collect();
    let ret: Vec<f32> = (0..b).map(|_| rng.normal() as f32).collect();
    let batch = TrainBatch {
        obs: &obs, mask: &mask, act: &act, old_logp: &old_logp,
        adv: &adv, ret: &ret,
    };
    let m0 = rt.train_step(&mut state, &batch).unwrap();
    let mut last = m0.clone();
    for _ in 0..8 {
        last = rt.train_step(&mut state, &batch).unwrap();
    }
    assert_eq!(m0.len(), 6);
    assert!(last[0] < m0[0], "loss did not decrease: {} -> {}", m0[0], last[0]);
    assert!(state.t > 8.0);
    for m in &last {
        assert!(m.is_finite());
    }
}

#[test]
fn macro_thinking_hot_path_under_budget() {
    // DESIGN.md §Perf: featurize + fwd + decode — fwd_b1 p50 < 5ms hard
    // bound (target < 1ms; tracked in EXPERIMENTS.md §Perf)
    let Some(rt) = runtime() else { return };
    let p = params(&rt, 7);
    let mut rng = Rng::new(8);
    let obs: Vec<f32> = (0..OBS_DIM).map(|_| rng.normal() as f32).collect();
    let mask = vec![1.0f32; ACTION_DIM];
    let stats = qimeng_mtmc::util::stats::bench(50, 300, || {
        let (logp, _v) = rt.fwd_b1(&p, &obs, &mask).unwrap();
        std::hint::black_box(logp);
    });
    eprintln!("fwd_b1: {stats}");
    assert!(
        stats.p50_ns < 5_000_000.0,
        "inference step way over budget: {stats}"
    );
}
