//! Property-based tests (testkit, proptest-style) over coordinator
//! invariants: schedule legality after arbitrary action sequences, cost
//! model sanity, reward shaping, serialization round-trips, region
//! analysis stability.

use qimeng_mtmc::dataset::{load_trajectories, save_trajectories, TrajStep,
                           Trajectory};
use qimeng_mtmc::engine::Session;
use qimeng_mtmc::env::{EnvConfig, OptimEnv};
use qimeng_mtmc::gpusim::{graph_fingerprint, kernel_time_us,
                          program_time_us, CostCache, GpuSpec};
use qimeng_mtmc::graph::infer_shapes;
use qimeng_mtmc::kir::{analyze_regions, lower_naive, Program, MAX_REGIONS};
use qimeng_mtmc::microcode::{LlmProfile, ProfileId};
use qimeng_mtmc::tasks::{kernelbench_suite, Task};
use qimeng_mtmc::testkit::gens::{gen_episode_case, gen_program_case,
                                 EpisodeCase, ProgramCase};
use qimeng_mtmc::testkit::{check, default_cases, Shrink};
use qimeng_mtmc::transform::{
    action_mask, apply_action, decode_action, AnalysisCache, Analyzer,
    ACTION_DIM, STOP_ACTION,
};
use qimeng_mtmc::util::parallel::par_map;
use qimeng_mtmc::util::Rng;
use qimeng_mtmc::prop_assert;

/// A random (task index, action sequence) pair.
#[derive(Clone, Debug)]
struct ActionSeq {
    task_idx: usize,
    actions: Vec<usize>,
    quality_milli: usize, // quality * 1000
}

impl Shrink for ActionSeq {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.actions.is_empty() {
            let mut half = self.clone();
            half.actions.truncate(self.actions.len() / 2);
            out.push(half);
            let mut minus = self.clone();
            minus.actions.pop();
            out.push(minus);
        }
        out
    }
}

fn tasks() -> &'static [Task] {
    use std::sync::OnceLock;
    static TASKS: OnceLock<Vec<Task>> = OnceLock::new();
    TASKS.get_or_init(|| {
        kernelbench_suite().into_iter().step_by(9).collect()
    })
}

fn gen_seq(rng: &mut Rng) -> ActionSeq {
    ActionSeq {
        task_idx: rng.below(tasks().len()),
        actions: (0..rng.below(10) + 1)
            .map(|_| rng.below(ACTION_DIM))
            .collect(),
        quality_milli: rng.below(1001),
    }
}

#[test]
fn prop_programs_stay_valid_under_any_action_sequence() {
    check(101, default_cases(), gen_seq, |seq: &ActionSeq| {
        let task = &tasks()[seq.task_idx % tasks().len()];
        let shapes = infer_shapes(&task.graph);
        let spec = GpuSpec::a100();
        let mut p = lower_naive(&task.graph);
        for &a in &seq.actions {
            if a >= STOP_ACTION {
                continue;
            }
            if let Ok(next) = apply_action(
                &p, &task.graph, &shapes, &decode_action(a), &spec,
                seq.quality_milli as f32 / 1000.0,
            ) {
                p = next;
            }
        }
        p.validate(&task.graph).map_err(|e| format!("{}: {e}", task.id))
    });
}

#[test]
fn prop_masked_actions_always_apply_and_unmasked_always_reject() {
    check(202, 64, gen_seq, |seq: &ActionSeq| {
        let task = &tasks()[seq.task_idx % tasks().len()];
        let shapes = infer_shapes(&task.graph);
        let spec = GpuSpec::h100();
        let mut p = lower_naive(&task.graph);
        // advance a few random valid steps, verifying mask soundness
        for &a in &seq.actions {
            let mask = action_mask(&p, &task.graph, &shapes, &spec);
            let pick = a % STOP_ACTION;
            let result = apply_action(&p, &task.graph, &shapes,
                                      &decode_action(pick), &spec, 1.0);
            prop_assert!(
                mask[pick] == result.is_ok(),
                "{}: mask[{pick}]={} but apply {:?}",
                task.id, mask[pick], result.as_ref().err()
            );
            if let Ok(next) = result {
                p = next;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_transforms_never_slow_the_cost_model_catastrophically() {
    // any legal transform changes time by at most 50x in either direction
    // (sanity: no overflow/NaN/degenerate pricing)
    check(303, default_cases(), gen_seq, |seq: &ActionSeq| {
        let task = &tasks()[seq.task_idx % tasks().len()];
        let shapes = infer_shapes(&task.graph);
        let spec = GpuSpec::v100();
        let mut p = lower_naive(&task.graph);
        let mut t_prev = program_time_us(&p, &task.graph, &shapes, &spec);
        for &a in &seq.actions {
            if a >= STOP_ACTION {
                continue;
            }
            if let Ok(next) = apply_action(&p, &task.graph, &shapes,
                                           &decode_action(a), &spec, 0.9) {
                let t = program_time_us(&next, &task.graph, &shapes, &spec);
                prop_assert!(t.is_finite() && t > 0.0, "bad time {t}");
                prop_assert!(
                    t < t_prev * 50.0 && t > t_prev / 50.0,
                    "{}: pathological jump {t_prev} -> {t}", task.id
                );
                p = next;
                t_prev = t;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_region_analysis_bounded_and_stable() {
    check(404, default_cases(), gen_seq, |seq: &ActionSeq| {
        let task = &tasks()[seq.task_idx % tasks().len()];
        let shapes = infer_shapes(&task.graph);
        let spec = GpuSpec::a100();
        let mut p = lower_naive(&task.graph);
        for &a in &seq.actions {
            let regions = analyze_regions(&p, &task.graph);
            prop_assert!(regions.len() <= MAX_REGIONS, "too many regions");
            let again = analyze_regions(&p, &task.graph);
            prop_assert!(
                regions.len() == again.len(),
                "region analysis not deterministic"
            );
            if a < STOP_ACTION {
                if let Ok(next) = apply_action(&p, &task.graph, &shapes,
                                               &decode_action(a), &spec, 1.0) {
                    p = next;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_env_episodes_bounded_and_consistent() {
    check(505, 48, gen_seq, |seq: &ActionSeq| {
        let task = &tasks()[seq.task_idx % tasks().len()];
        let mut env = OptimEnv::new(
            task,
            GpuSpec::a100(),
            LlmProfile::get(ProfileId::GeminiFlash25),
            EnvConfig::default(),
            seq.quality_milli as u64,
        );
        let mut steps = 0;
        for &a in seq.actions.iter().cycle().take(env.cfg.max_steps + 2) {
            if env.state.done {
                break;
            }
            let mask = env.mask();
            let pick = if mask[a % ACTION_DIM] { a % ACTION_DIM } else { STOP_ACTION };
            let r = env.step(pick);
            prop_assert!(r.reward.is_finite(), "reward not finite");
            steps += 1;
        }
        prop_assert!(
            env.state.done || steps <= env.cfg.max_steps + 2,
            "episode exceeded bounds"
        );
        prop_assert!(
            env.state.best_speedup >= env.state.speedup * 0.999
                || env.state.best_speedup > 0.0,
            "best speedup below current"
        );
        Ok(())
    });
}

/// `par_map` must behave exactly like a sequential `map` for any
/// (length, thread count) — including empty input, single item, and
/// `threads > len` — with order preserved and every index delivered to
/// the correct slot. Guards the sharded-chunk-queue rewrite.
#[test]
fn prop_par_map_matches_sequential_map() {
    #[derive(Clone, Debug)]
    struct Case {
        len: usize,
        threads: usize,
    }
    impl Shrink for Case {
        fn shrink(&self) -> Vec<Self> {
            let mut out = Vec::new();
            if self.len > 0 {
                out.push(Case { len: self.len / 2, threads: self.threads });
                out.push(Case { len: 0, threads: self.threads });
            }
            if self.threads > 1 {
                out.push(Case { len: self.len, threads: 1 });
            }
            out
        }
    }
    check(
        707,
        default_cases(),
        |rng: &mut Rng| Case {
            // lengths span empty / single / chunk-boundary regimes;
            // threads routinely exceed len
            len: rng.below(200),
            threads: rng.below(24) + 1,
        },
        |case: &Case| {
            let items: Vec<u64> = (0..case.len as u64).map(|x| x * 3 + 1).collect();
            let expect: Vec<(usize, u64)> =
                items.iter().enumerate().map(|(i, &x)| (i, x * 2)).collect();
            let got = par_map(&items, case.threads, |i, &x| (i, x * 2));
            prop_assert!(
                got == expect,
                "par_map(len={}, threads={}) diverged from sequential map",
                case.len, case.threads
            );
            Ok(())
        },
    );
}

/// Cost-cache soundness over arbitrary action-derived programs: a warm
/// hit returns a `CostBreakdown` identical to both the cold miss and the
/// direct (uncached) computation, for every kernel of the program.
#[test]
fn prop_cost_cache_hit_identical_to_cold_miss() {
    check(808, 48, gen_seq, |seq: &ActionSeq| {
        let task = &tasks()[seq.task_idx % tasks().len()];
        let shapes = infer_shapes(&task.graph);
        let spec = GpuSpec::a100();
        let mut p = lower_naive(&task.graph);
        for &a in &seq.actions {
            if a >= STOP_ACTION {
                continue;
            }
            if let Ok(next) = apply_action(
                &p, &task.graph, &shapes, &decode_action(a), &spec,
                seq.quality_milli as f32 / 1000.0,
            ) {
                p = next;
            }
        }
        let cache = CostCache::new();
        let ctx = graph_fingerprint(&task.graph, &shapes);
        for k in &p.kernels {
            let cold = cache.kernel_time_us(ctx, k, &task.graph, &shapes, &spec);
            let warm = cache.kernel_time_us(ctx, k, &task.graph, &shapes, &spec);
            let direct = kernel_time_us(k, &task.graph, &shapes, &spec);
            prop_assert!(
                cold == direct && warm == direct,
                "{}: cached cost diverged from direct computation", task.id
            );
        }
        let (hits, misses) = cache.stats();
        prop_assert!(
            hits + misses == 2 * p.kernels.len(),
            "{}: unexpected cache traffic ({hits} hits, {misses} misses \
             for {} kernels)",
            task.id, p.kernels.len()
        );
        Ok(())
    });
}

/// End-to-end pricing-cache parity: a full MTMC-style episode driven
/// through an [`OptimEnv`] with a shared `CostCache` attached must be
/// bit-identical (rewards, speedups, best program) to the same episode
/// priced cold — including a second warm episode replayed over the
/// already-populated cache.
#[test]
fn prop_cached_episode_bitwise_identical_to_cold() {
    fn mk<'a>(task: &'a Task, seed: u64, session: &'a Session)
              -> OptimEnv<'a> {
        OptimEnv::with_session(
            task,
            GpuSpec::a100(),
            LlmProfile::get(ProfileId::GeminiFlash25),
            EnvConfig::default(),
            seed,
            session,
        )
    }
    check(909, 24, gen_seq, |seq: &ActionSeq| {
        let task = &tasks()[seq.task_idx % tasks().len()];
        let off = Session::builder()
            .cost_cache(false)
            .analysis_cache(false)
            .edge_memo(false)
            .build();
        let cached = Session::builder()
            .analysis_cache(false)
            .edge_memo(false)
            .build();
        // two warm passes: the second prices everything from the
        // cached session's persistent CostCache
        for _pass in 0..2 {
            let mut cold = mk(task, seq.quality_milli as u64, &off);
            let mut warm = mk(task, seq.quality_milli as u64, &cached);
            prop_assert!(
                cold.eager_us.to_bits() == warm.eager_us.to_bits(),
                "{}: eager baseline diverged", task.id
            );
            for &a in seq.actions.iter().cycle().take(cold.cfg.max_steps) {
                if cold.state.done {
                    break;
                }
                let mask = cold.mask();
                let pick = if mask[a % ACTION_DIM] {
                    a % ACTION_DIM
                } else {
                    STOP_ACTION
                };
                let rc = cold.step(pick);
                let rw = warm.step(pick);
                prop_assert!(
                    rc.reward.to_bits() == rw.reward.to_bits()
                        && rc.done == rw.done,
                    "{}: step result diverged", task.id
                );
                prop_assert!(
                    cold.state.speedup.to_bits()
                        == warm.state.speedup.to_bits(),
                    "{}: speedup diverged", task.id
                );
            }
            prop_assert!(
                cold.state.best_speedup.to_bits()
                    == warm.state.best_speedup.to_bits()
                    && cold.state.best_program == warm.state.best_program,
                "{}: episode outcome diverged", task.id
            );
        }
        Ok(())
    });
}

/// AnalysisCache differential: on arbitrary generated programs, the
/// cached `action_mask` / `analyze_regions` must equal the fresh
/// computation field-for-field — on the cold miss, on the warm hit, and
/// again after the program state moves.
#[test]
fn prop_analysis_cache_mask_identical() {
    check(1111, default_cases(), gen_program_case, |case: &ProgramCase| {
        let spec = GpuSpec::a100();
        let (g, shapes, p) = case.build(&spec);
        let cache = AnalysisCache::new();
        let az = Analyzer::new(Some(&cache), &g, &shapes);
        // walk a couple of states: the initial one, then the first valid
        // action applied (mask/regions change with the program)
        let mut states = vec![p];
        let mask0 = action_mask(&states[0], &g, &shapes, &spec);
        if let Some(a) = (0..STOP_ACTION).find(|&a| mask0[a]) {
            if let Ok(next) = apply_action(&states[0], &g, &shapes,
                                           &decode_action(a), &spec, 1.0) {
                states.push(next);
            }
        }
        for (si, state) in states.iter().enumerate() {
            let fresh_mask = action_mask(state, &g, &shapes, &spec);
            let fresh_regions = analyze_regions(state, &g);
            for pass in 0..2 {
                let cached_mask = az.mask(state, &g, &shapes, &spec);
                prop_assert!(
                    *cached_mask == fresh_mask,
                    "cached mask diverged (state {si}, pass {pass})"
                );
                let cached_regions = az.regions(state, &g);
                prop_assert!(
                    *cached_regions == fresh_regions,
                    "cached regions diverged (state {si}, pass {pass})"
                );
            }
        }
        let s = cache.stats();
        prop_assert!(s.hits + s.misses == s.lookups,
                     "stats identity broken: {s:?}");
        prop_assert!(s.hits > 0, "second pass never hit the cache");
        Ok(())
    });
}

/// Everything observable about one episode, bit-exact.
#[derive(PartialEq, Debug)]
struct EpisodeTrace {
    eager_bits: u64,
    rewards: Vec<u64>,
    signals: Vec<String>,
    speedups: Vec<u64>,
    best_bits: u64,
    best_program: Program,
}

fn run_episode(task: &Task, case: &EpisodeCase, session: &Session)
               -> EpisodeTrace {
    let mut env = OptimEnv::with_session(
        task,
        GpuSpec::a100(),
        LlmProfile::get(ProfileId::GeminiFlash25),
        case.env.to_cfg(),
        case.seed,
        session,
    );
    let mut trace = EpisodeTrace {
        eager_bits: env.eager_us.to_bits(),
        rewards: Vec::new(),
        signals: Vec::new(),
        speedups: Vec::new(),
        best_bits: 0,
        best_program: Program::default(),
    };
    for &a in case.actions.iter().cycle().take(env.cfg.max_steps) {
        if env.state.done {
            break;
        }
        let mask = env.mask();
        let pick = if mask[a % ACTION_DIM] { a % ACTION_DIM } else { STOP_ACTION };
        let r = env.step(pick);
        trace.rewards.push(r.reward.to_bits());
        trace.signals.push(format!("{:?}", r.signal));
        trace.speedups.push(env.state.speedup.to_bits());
    }
    trace.best_bits = env.state.best_speedup.to_bits();
    trace.best_program = env.state.best_program.clone();
    trace
}

/// EdgeMemo differential (the headline tentpole guard): on generated
/// tasks, configs and action streams, episodes must be byte-identical
/// across every cache on/off combination — cold, each cache alone, all
/// three together, a *warm shared* memo replaying a second run, and an
/// edge memo under eviction pressure (`with_capacity(2)`).
#[test]
fn prop_edge_memo_episode_bitwise_identical() {
    check(2222, default_cases(), gen_episode_case, |case: &EpisodeCase| {
        let task = case.recipe.task();
        let cold = Session::builder()
            .cost_cache(false)
            .analysis_cache(false)
            .edge_memo(false)
            .build();
        let baseline = run_episode(&task, case, &cold);
        prop_assert!(
            !baseline.signals.is_empty(),
            "episode must take at least one step"
        );
        // every on/off combination of (cost, analysis, edges)
        for combo in 1..8u8 {
            let session = Session::builder()
                .cost_cache(combo & 1 != 0)
                .analysis_cache(combo & 2 != 0)
                .edge_memo(combo & 4 != 0)
                .build();
            // two passes through one session: the second replays from
            // whatever warmed up
            for pass in 0..2 {
                let got = run_episode(&task, case, &session);
                prop_assert!(
                    got == baseline,
                    "combo {combo:#05b} pass {pass} diverged from cold \
                     episode:\n  got {:?}\n  want {:?}",
                    got.signals, baseline.signals
                );
            }
            if combo & 4 != 0 {
                let s = session.edges().unwrap().stats();
                prop_assert!(s.hits + s.misses == s.lookups,
                             "edge-memo stats identity broken: {s:?}");
                // Stop steps bypass the memo, so only a real transition
                // guarantees a replay on the warm pass
                let has_transition = baseline
                    .signals
                    .iter()
                    .any(|s| !s.starts_with("Stop"));
                prop_assert!(
                    !has_transition || s.hits > 0,
                    "warm pass never replayed from the edge memo"
                );
            }
        }
        // eviction pressure: a 2-entry table thrashes constantly but must
        // never change outcomes (misses just recompute)
        let tiny = Session::builder()
            .cost_cache(false)
            .analysis_cache(false)
            .edge_capacity(2)
            .build();
        for _ in 0..2 {
            let got = run_episode(&task, case, &tiny);
            prop_assert!(
                got == baseline,
                "eviction pressure changed the episode outcome"
            );
        }
        Ok(())
    });
}

/// Non-empty segment files of a segmented store (`seg_NN.bin` larger
/// than the 20-byte header), sorted by name for determinism.
fn nonempty_segments(store: &std::path::Path) -> Vec<std::path::PathBuf> {
    let mut segs: Vec<_> = std::fs::read_dir(store)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            let name = p.file_name().unwrap().to_string_lossy();
            name.starts_with("seg_")
                && std::fs::metadata(p).unwrap().len() > 20
        })
        .collect();
    segs.sort();
    segs
}

/// Persistence differential (the segmented `--memo-store` tier, owned by
/// the [`Session`]): replaying an episode through a second session that
/// warm-started from the store the first session flushed must be
/// bit-identical to the cold episode, the restored session must account
/// for its disk state, and corrupting exactly one segment must degrade
/// only that shard — the surviving segments still warm-start and the
/// replay stays bit-identical (the lost edges are recomputed live).
#[test]
fn prop_edge_memo_persistence_roundtrip() {
    let dir = std::env::temp_dir().join("qimeng_prop_memo_store");
    std::fs::create_dir_all(&dir).unwrap();
    let case_no = std::sync::atomic::AtomicUsize::new(0);
    check(3333, 24, gen_episode_case, |case: &EpisodeCase| {
        let task = case.recipe.task();
        let cold = Session::builder()
            .cost_cache(false)
            .analysis_cache(false)
            .edge_memo(false)
            .build();
        let baseline = run_episode(&task, case, &cold);
        let path = dir.join(format!(
            "roundtrip_{}.store",
            case_no.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&path);
        // warm a session's memo with one episode, then persist it
        let warm = Session::builder()
            .cost_cache(false)
            .analysis_cache(false)
            .memo_store(Some(path.clone()))
            .build();
        prop_assert!(warm.warm_loaded() == 0,
                     "missing store must cold-start silently");
        run_episode(&task, case, &warm);
        let saved = warm.finish();
        prop_assert!(saved == warm.edges().unwrap().len(),
                     "flush must cover every live entry");
        // a second session warm-starts from the store and replays:
        // bit-identical episode, hits attributed to disk entries
        let restored = Session::builder()
            .cost_cache(false)
            .analysis_cache(false)
            .memo_store(Some(path.clone()))
            .build();
        prop_assert!(restored.warm_loaded() == saved,
                     "load restored {} of {saved} entries",
                     restored.warm_loaded());
        prop_assert!(restored.edges().unwrap().disk_loaded() == saved,
                     "disk_loaded must count the warm-started entries");
        prop_assert!(restored.warm_report().degraded_segments == 0,
                     "an intact store must not report degraded segments");
        let got = run_episode(&task, case, &restored);
        prop_assert!(
            got == baseline,
            "disk-replayed episode diverged from cold episode:\n  got \
             {:?}\n  want {:?}",
            got.signals, baseline.signals
        );
        // Stop steps bypass the memo, so only a real transition
        // guarantees the replay was served from disk entries
        let has_transition =
            baseline.signals.iter().any(|s| !s.starts_with("Stop"));
        prop_assert!(
            !has_transition || restored.edges().unwrap().stats().disk_hits > 0,
            "replay from a loaded store must report disk hits"
        );
        if saved == 0 {
            return Ok(());
        }
        // corrupt exactly one non-empty segment (drop its last byte):
        // only that shard degrades, the others still warm-start, and the
        // replay stays bit-identical — the lost edges recompute live
        let segs = nonempty_segments(&path);
        prop_assert!(!segs.is_empty(), "a non-empty store has segments");
        let victim = segs.last().unwrap();
        let bytes = std::fs::read(victim).map_err(|e| e.to_string())?;
        std::fs::write(victim, &bytes[..bytes.len() - 1])
            .map_err(|e| e.to_string())?;
        let partial = Session::builder()
            .cost_cache(false)
            .analysis_cache(false)
            .memo_store(Some(path.clone()))
            .build();
        let report = partial.warm_report();
        prop_assert!(report.degraded_segments == 1,
                     "exactly the corrupted segment degrades, got {report:?}");
        prop_assert!(partial.warm_loaded() < saved,
                     "the degraded shard's edges must not load");
        prop_assert!(
            partial.edges().unwrap().disk_loaded() == partial.warm_loaded(),
            "disk_loaded must count the surviving entries"
        );
        let got = run_episode(&task, case, &partial);
        prop_assert!(
            got == baseline,
            "partially-recovered episode diverged from cold episode:\n  \
             got {:?}\n  want {:?}",
            got.signals, baseline.signals
        );
        // with at least one surviving non-empty segment, the replay is
        // still served partly from disk
        prop_assert!(
            segs.len() < 2
                || partial.edges().unwrap().stats().disk_hits > 0,
            "surviving shards must still serve disk hits"
        );
        let _ = std::fs::remove_dir_all(&path);
        Ok(())
    });
}

/// Dirty-skip property: a flush after a clean (pure-replay) run rewrites
/// **zero** segments and leaves every store file byte-identical, across
/// whatever segment counts the generated episodes produce. The replay
/// itself stays bit-identical to the warm run.
#[test]
fn prop_clean_flush_writes_zero_segments() {
    let dir = std::env::temp_dir().join("qimeng_prop_clean_flush");
    std::fs::create_dir_all(&dir).unwrap();
    let case_no = std::sync::atomic::AtomicUsize::new(0);
    check(4747, 16, gen_episode_case, |case: &EpisodeCase| {
        let task = case.recipe.task();
        let path = dir.join(format!(
            "clean_{}.store",
            case_no.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&path);
        let warm = Session::builder()
            .cost_cache(false)
            .analysis_cache(false)
            .memo_store(Some(path.clone()))
            .build();
        let baseline = run_episode(&task, case, &warm);
        warm.finish();
        let before: std::collections::BTreeMap<String, Vec<u8>> =
            std::fs::read_dir(&path)
                .map_err(|e| e.to_string())?
                .map(|e| {
                    let p = e.unwrap().path();
                    (
                        p.file_name().unwrap().to_string_lossy().into_owned(),
                        std::fs::read(&p).unwrap(),
                    )
                })
                .collect();
        // replay-only session: no inserts, every shard stays clean
        let replay = Session::builder()
            .cost_cache(false)
            .analysis_cache(false)
            .memo_store(Some(path.clone()))
            .build();
        let got = run_episode(&task, case, &replay);
        prop_assert!(got == baseline, "replay diverged from the warm run");
        replay.finish();
        let store = replay.stats().store.unwrap();
        prop_assert!(
            store.written_segments == Some(0),
            "clean run must rewrite zero segments, wrote {:?}",
            store.written_segments
        );
        let after: std::collections::BTreeMap<String, Vec<u8>> =
            std::fs::read_dir(&path)
                .map_err(|e| e.to_string())?
                .map(|e| {
                    let p = e.unwrap().path();
                    (
                        p.file_name().unwrap().to_string_lossy().into_owned(),
                        std::fs::read(&p).unwrap(),
                    )
                })
                .collect();
        prop_assert!(before == after,
                     "a clean flush must leave every store file untouched");
        let _ = std::fs::remove_dir_all(&path);
        Ok(())
    });
}

#[test]
fn prop_trajectory_store_roundtrips() {
    #[derive(Clone, Debug)]
    struct TrajVec(Vec<Trajectory>);
    impl Shrink for TrajVec {
        fn shrink(&self) -> Vec<Self> {
            let mut out = Vec::new();
            if !self.0.is_empty() {
                out.push(TrajVec(self.0[..self.0.len() / 2].to_vec()));
            }
            out
        }
    }
    let gen = |rng: &mut Rng| {
        TrajVec(
            (0..rng.below(6))
                .map(|i| Trajectory {
                    task_idx: rng.below(1000) as u32,
                    seed: rng.next_u64(),
                    steps: (0..rng.below(15))
                        .map(|_| TrajStep {
                            action: rng.below(ACTION_DIM) as u16,
                            signal_code: rng.below(5) as u8,
                            reward: rng.normal_f32(0.0, 1.0),
                            speedup: rng.f32() * 3.0,
                        })
                        .collect(),
                })
                .collect::<Vec<_>>(),
        )
    };
    let dir = std::env::temp_dir().join("qimeng_prop_store");
    std::fs::create_dir_all(&dir).unwrap();
    check(606, 32, gen, |tv: &TrajVec| {
        let path = dir.join("prop.bin");
        save_trajectories(&tv.0, &path).map_err(|e| e.to_string())?;
        let back = load_trajectories(&path).map_err(|e| e.to_string())?;
        prop_assert!(back == tv.0, "roundtrip mismatch");
        Ok(())
    });
}
