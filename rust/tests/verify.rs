//! Differential soundness suite for the static schedule verifier
//! (`kir::verify`), the pre-verif gate's contract:
//!
//! 1. **Closure**: every transform maps statically-legal programs to
//!    statically-legal programs, on every simulated GPU — so the
//!    Error-severity rules never fire on the normal optimization path.
//! 2. **Soundness**: a statically-legal program carrying no semantic
//!    mutations passes the dynamic correctness check (`check_correct`
//!    on the executable verif twin returns `Correct`) — the static
//!    tier never admits a program the dynamic tier would catch.
//! 3. **Gate transparency**: episodes driven through a gate-enabled
//!    session are byte-identical to ungated episodes, while the gate
//!    counts its checks and rejects nothing.
//!
//! Nightly CI runs this suite at `QIMENG_PROP_CASES=1024`.

use qimeng_mtmc::engine::Session;
use qimeng_mtmc::env::OptimEnv;
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::graph::infer_shapes;
use qimeng_mtmc::kir::{
    has_errors, is_statically_legal, lower_checked, verify, Program,
};
use qimeng_mtmc::microcode::{check_correct, CheckOutcome, LlmProfile,
                             ProfileId};
use qimeng_mtmc::prop_assert;
use qimeng_mtmc::tasks::{kernelbench_suite, tritonbench_g, tritonbench_t};
use qimeng_mtmc::testkit::gens::{gen_episode_case, gen_program_case,
                                 EpisodeCase, ProgramCase};
use qimeng_mtmc::testkit::{check, default_cases};
use qimeng_mtmc::transform::{ACTION_DIM, STOP_ACTION};

/// The lint acceptance bar as a test: the naive lowering of the entire
/// benchmark corpus is diagnostic-free on every simulated GPU.
#[test]
fn whole_corpus_naive_lowering_is_diagnostic_free() {
    let tasks: Vec<_> = kernelbench_suite()
        .into_iter()
        .chain(tritonbench_g())
        .chain(tritonbench_t())
        .collect();
    assert!(!tasks.is_empty());
    for spec in GpuSpec::all() {
        for t in &tasks {
            let shapes = infer_shapes(&t.graph);
            let p = lower_checked(&t.graph)
                .unwrap_or_else(|e| panic!("{}: {e}", t.id));
            let diags = verify(&p, &t.graph, &shapes, &spec);
            assert!(diags.is_empty(), "{} on {}: {diags:?}", t.id, spec.name);
        }
    }
}

/// Closure: on generated graphs and arbitrary action streams, the
/// program that falls out of the transform layer stays free of
/// Error-severity diagnostics on the spec it was scheduled for.
#[test]
fn prop_transforms_preserve_static_legality() {
    check(5150, default_cases(), gen_program_case, |case: &ProgramCase| {
        for spec in GpuSpec::all() {
            let (g, shapes, p) = case.build(&spec);
            let diags = verify(&p, &g, &shapes, &spec);
            prop_assert!(
                !has_errors(&diags),
                "transformed program statically illegal on {}: {:?}",
                spec.name,
                diags
            );
        }
        Ok(())
    });
}

/// Soundness: statically legal + no injected mutations ⇒ the dynamic
/// verifier agrees the program is correct. The static tier must never
/// pass something the (authoritative) dynamic tier rejects.
#[test]
fn prop_static_legal_unmutated_programs_check_correct() {
    check(5251, default_cases(), gen_program_case, |case: &ProgramCase| {
        let spec = GpuSpec::a100();
        let (g, shapes, p) = case.build(&spec);
        prop_assert!(
            is_statically_legal(&p, &g, &shapes, &spec),
            "generated program must be statically legal"
        );
        prop_assert!(p.mutations.is_empty() && !p.compile_broken,
                     "ProgramCase::build never injects bugs");
        let task = case.recipe.task();
        let outcome =
            check_correct(&p, &task.verif_graph, 2, case.quality_milli as u64);
        prop_assert!(
            outcome == CheckOutcome::Correct,
            "statically-legal unmutated program failed dynamic verif: \
             {outcome:?}"
        );
        Ok(())
    });
}

/// Everything observable about one episode, bit-exact.
#[derive(PartialEq, Debug)]
struct EpisodeTrace {
    rewards: Vec<u64>,
    signals: Vec<String>,
    speedups: Vec<u64>,
    best_bits: u64,
    best_program: Program,
}

fn run_episode(case: &EpisodeCase, session: &Session) -> EpisodeTrace {
    let task = case.recipe.task();
    let mut env = OptimEnv::with_session(
        &task,
        GpuSpec::a100(),
        LlmProfile::get(ProfileId::GeminiFlash25),
        case.env.to_cfg(),
        case.seed,
        session,
    );
    let mut trace = EpisodeTrace {
        rewards: Vec::new(),
        signals: Vec::new(),
        speedups: Vec::new(),
        best_bits: 0,
        best_program: Program::default(),
    };
    for &a in case.actions.iter().cycle().take(env.cfg.max_steps) {
        if env.state.done {
            break;
        }
        let mask = env.mask();
        let pick =
            if mask[a % ACTION_DIM] { a % ACTION_DIM } else { STOP_ACTION };
        let r = env.step(pick);
        trace.rewards.push(r.reward.to_bits());
        trace.signals.push(format!("{:?}", r.signal));
        trace.speedups.push(env.state.speedup.to_bits());
    }
    trace.best_bits = env.state.best_speedup.to_bits();
    trace.best_program = env.state.best_program.clone();
    trace
}

/// Gate transparency: the pre-verif static gate checks every candidate
/// and rejects none of them on the normal path, so gated and ungated
/// episodes are byte-identical. (Rules with Error severity are closed
/// under the transform layer — that is what the two properties above
/// pin down — so the gate can only be a no-op filter here.)
#[test]
fn prop_gated_episode_bitwise_identical_to_ungated() {
    check(5352, default_cases(), gen_episode_case, |case: &EpisodeCase| {
        let ungated = Session::builder()
            .cost_cache(false)
            .analysis_cache(false)
            .edge_memo(false)
            .static_gate(false)
            .build();
        prop_assert!(ungated.gate().is_none(),
                     "static_gate(false) must drop the gate");
        let baseline = run_episode(case, &ungated);
        let gated = Session::builder()
            .cost_cache(false)
            .analysis_cache(false)
            .edge_memo(false)
            .build();
        let got = run_episode(case, &gated);
        prop_assert!(
            got == baseline,
            "gated episode diverged from ungated:\n  got {:?}\n  want {:?}",
            got.signals,
            baseline.signals
        );
        let gate = gated.gate().expect("gate is on by default");
        prop_assert!(
            gate.rejects() == 0,
            "gate rejected {} transform-produced candidates",
            gate.rejects()
        );
        // only candidates that survive micro-coding reach the gate:
        // a Correct step came through it, and (with zero rejects) so
        // did every WrongResult — Stop/Rejected/CompileFail bypass it
        let has_candidate = baseline
            .signals
            .iter()
            .any(|s| s.starts_with("Correct") || s == "WrongResult");
        prop_assert!(
            !has_candidate || gate.checks() > 0,
            "an episode with surviving candidates never consulted the gate"
        );
        Ok(())
    });
}
