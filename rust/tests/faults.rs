//! Fault-tolerance integration: unit isolation (a panicking unit never
//! aborts the sweep or perturbs sibling outcomes), deterministic fault
//! injection converging to fault-free bytes within the retry budget, and
//! crash-plus-`--resume` byte identity. Companion to `rust/tests/batch.rs`
//! (which guards the no-fault determinism contract).

use qimeng_mtmc::engine::Session;
use qimeng_mtmc::eval::{
    unit_fault_key, BatchCfg, BatchJob, BatchRunner, MacroKind, Method,
};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::microcode::ProfileId;
use qimeng_mtmc::tasks::kernelbench_level;
use qimeng_mtmc::util::faults::{FaultPlan, FaultSite};
use qimeng_mtmc::util::json::Json;

fn greedy() -> Method {
    Method::Mtmc {
        macro_kind: MacroKind::GreedyLookahead,
        micro: ProfileId::GeminiFlash25,
    }
}

fn jobs_two_methods() -> Vec<BatchJob> {
    let tasks = kernelbench_level(1)[..6].to_vec();
    vec![
        BatchJob::new(
            Method::Baseline { profile: ProfileId::GeminiPro25 },
            GpuSpec::a100(),
            tasks.clone(),
        ),
        BatchJob::new(greedy(), GpuSpec::v100(), tasks),
    ]
}

fn run_to_sink(session: &Session, jobs: &[BatchJob], path: &std::path::Path,
               threads: usize, resume: bool)
               -> Vec<qimeng_mtmc::eval::SuiteResult> {
    let runner = BatchRunner::new(
        BatchCfg {
            threads,
            sink: Some(path.to_path_buf()),
            resume,
            ..Default::default()
        },
        session,
    )
    .unwrap();
    let results = runner.run(jobs);
    assert!(!runner.sink_failed(), "sink reported I/O failures");
    results
}

fn sorted_lines(path: &std::path::Path) -> Vec<String> {
    let mut lines: Vec<String> = std::fs::read_to_string(path)
        .unwrap()
        .lines()
        .map(String::from)
        .collect();
    lines.sort();
    lines
}

/// The isolation property (one injected-panic unit amid N clean units):
/// every clean unit's sink record is byte-identical to the no-fault
/// run's, at `threads = 1` and `threads = 8`, and the panicking unit
/// becomes a `status: "panicked"` record instead of a dead sweep.
#[test]
fn panicking_unit_is_isolated_across_thread_counts() {
    let dir = std::env::temp_dir().join("qimeng_faults_isolation");
    std::fs::create_dir_all(&dir).unwrap();
    let jobs = jobs_two_methods();
    let victim_job = &jobs[1];
    let victim_task = &victim_job.tasks[2];
    let victim_method = victim_job.method.label();
    let is_victim = |line: &str| {
        let v = Json::parse(line).unwrap();
        v.get("task").and_then(|j| j.as_str())
            == Some(victim_task.id.as_str())
            && v.get("method").and_then(|j| j.as_str())
                == Some(victim_method.as_str())
    };

    let ref_path = dir.join("reference.jsonl");
    let ref_results = {
        let session = Session::default();
        run_to_sink(&session, &jobs, &ref_path, 1, false)
    };
    let (ref_clean, ref_victim): (Vec<String>, Vec<String>) =
        sorted_lines(&ref_path).into_iter().partition(|l| !is_victim(l));
    assert_eq!(ref_victim.len(), 1);

    let key = unit_fault_key(&victim_method, victim_task.suite.label(),
                             victim_job.gpu.name, &victim_task.id,
                             victim_job.cfg.seed);
    for threads in [1usize, 8] {
        let path = dir.join(format!("panic_t{threads}.jsonl"));
        let session = Session::builder()
            .faults(Some(FaultPlan::new(0).with_panic_unit(key)))
            .build();
        let results = run_to_sink(&session, &jobs, &path, threads, false);

        let (clean, victim): (Vec<String>, Vec<String>) =
            sorted_lines(&path).into_iter().partition(|l| !is_victim(l));
        assert_eq!(clean, ref_clean,
                   "sibling records perturbed at {threads} threads");
        assert_eq!(victim.len(), 1, "panicked unit must still be recorded");
        let v = Json::parse(&victim[0]).unwrap();
        assert_eq!(v.get("status").and_then(|j| j.as_str()),
                   Some("panicked"));
        assert_eq!(v.get("compiled").and_then(|j| j.as_bool()), Some(false));
        assert_eq!(v.get("correct").and_then(|j| j.as_bool()), Some(false));
        assert_eq!(v.get("speedup").and_then(|j| j.as_f64()), Some(0.0));
        assert!(v.get("error").and_then(|j| j.as_str())
            .is_some_and(|e| e.contains("injected unit panic")));

        // the untouched job's aggregate metrics are bit-equal to the
        // reference; the victim's own job sees it zeroed
        assert_eq!(results[0].metrics, ref_results[0].metrics);
        let victim_outcome = results[1]
            .outcomes
            .iter()
            .find(|o| o.task_id == victim_task.id)
            .unwrap();
        assert!(!victim_outcome.compiled && !victim_outcome.correct);
        assert_eq!(victim_outcome.speedup, 0.0);
        assert_eq!(session.fault_stats().panicked(), 1);
        assert_eq!(session.fault_stats().exhausted(), 0);
        assert_eq!(
            session.faults().unwrap().injected(FaultSite::UnitPanic),
            1
        );
    }
}

/// Injected transient faults (the seeded rate gates) recover within the
/// default retry budget: a fault-injected sweep streams the exact bytes
/// a fault-free one does, while the retry counters show real activity.
#[test]
fn injected_faults_converge_to_fault_free_bytes() {
    let dir = std::env::temp_dir().join("qimeng_faults_transient");
    std::fs::create_dir_all(&dir).unwrap();
    let jobs =
        vec![BatchJob::new(greedy(), GpuSpec::a100(),
                           kernelbench_level(2)[..4].to_vec())];
    let ref_path = dir.join("reference.jsonl");
    {
        let session = Session::default();
        run_to_sink(&session, &jobs, &ref_path, 1, false);
    }
    let reference = std::fs::read(&ref_path).unwrap();

    // fault opportunities per run: verif flakes fire on ~1/16 of buggy
    // transitions, sink-write faults on ~1/8 of the 4 records — scan
    // plan seeds (deterministically) until one shows activity rather
    // than bet the suite on a single seed
    let mut saw_activity = false;
    for plan_seed in 0..8u64 {
        let path = dir.join(format!("faulty_{plan_seed}.jsonl"));
        let session = Session::builder()
            .faults(Some(FaultPlan::new(plan_seed)))
            .build();
        run_to_sink(&session, &jobs, &path, 1, false);
        assert_eq!(std::fs::read(&path).unwrap(), reference,
                   "plan seed {plan_seed} changed the sweep bytes");
        let stats = session.fault_stats();
        assert_eq!(stats.exhausted(), 0,
                   "burst (2) must stay within the retry budget (2)");
        assert_eq!(stats.panicked(), 0);
        if stats.retried() > 0 {
            assert!(stats.recovered() > 0,
                    "every retried unit must eventually recover");
        }
        if stats.retried() + stats.sink_retries() > 0 {
            assert!(session.faults().unwrap().injected_total() > 0);
            saw_activity = true;
        }
    }
    assert!(saw_activity,
            "no plan seed in 0..8 injected a single fault — the rate \
             gates are miswired");
}

/// Crash-then-resume: truncate the sink mid-record (what an abort looks
/// like on disk), resume with faults armed, and end byte-identical to
/// the uninterrupted fault-free reference.
#[test]
fn kill_and_resume_reproduces_reference_bytes() {
    let dir = std::env::temp_dir().join("qimeng_faults_resume");
    std::fs::create_dir_all(&dir).unwrap();
    let jobs = jobs_two_methods();
    let path = dir.join("sweep.jsonl");
    let ref_results = {
        let session = Session::default();
        run_to_sink(&session, &jobs, &path, 1, false)
    };
    let reference = std::fs::read(&path).unwrap();

    // keep 4 whole records and a torn fifth — a crash between the 4th
    // and 5th flush
    let text = String::from_utf8(reference.clone()).unwrap();
    let prefix: String =
        text.lines().take(4).map(|l| format!("{l}\n")).collect();
    let torn = text.lines().nth(4).unwrap();
    std::fs::write(&path, format!("{prefix}{}", &torn[..torn.len() / 2]))
        .unwrap();

    let session = Session::builder()
        .faults(Some(FaultPlan::new(11)))
        .build();
    let resumed = run_to_sink(&session, &jobs, &path, 1, true);
    assert_eq!(std::fs::read(&path).unwrap(), reference,
               "resumed sink diverged from the uninterrupted run");
    for (a, b) in ref_results.iter().zip(&resumed) {
        assert_eq!(a.metrics, b.metrics, "{}: resumed metrics diverged",
                   a.method);
    }
    assert_eq!(session.fault_stats().exhausted(), 0);
    assert_eq!(session.fault_stats().panicked(), 0);
}
