//! Full-pipeline integration: dataset generation → tree replay, and the
//! headline comparisons the paper's ablations rest on (hierarchical vs
//! single-pass, action space vs freeform), at test-sized scales.

use qimeng_mtmc::dataset::{generate, load_trajectories, save_trajectories,
                           DatasetCfg};
use qimeng_mtmc::engine::Session;
use qimeng_mtmc::env::{EnvConfig, TreeEnv};
use qimeng_mtmc::eval::{evaluate, EvalCfg, MacroKind, Method};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::microcode::{LlmProfile, ProfileId};
use qimeng_mtmc::tasks::{kernelbench_level, training_corpus};

#[test]
fn dataset_roundtrips_and_replays_through_tree_env() {
    let corpus = training_corpus(3);
    let cfg = DatasetCfg { per_task: 4, threads: 2, ..Default::default() };
    let spec = GpuSpec::a100();
    let (trajs, stats) = generate(&corpus, &spec, ProfileId::GeminiFlash25,
                                  &cfg, &Session::default());
    assert_eq!(stats.trajectories, 12);

    let dir = std::env::temp_dir().join("qimeng_pipeline_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trajs.bin");
    save_trajectories(&trajs, &path).unwrap();
    let loaded = load_trajectories(&path).unwrap();
    assert_eq!(loaded, trajs);

    // replay each trajectory through a fresh TreeEnv with the recorded
    // seed: rewards and speedups must reproduce exactly
    for t in &loaded {
        let task = &corpus[t.task_idx as usize];
        let mut env = TreeEnv::new(task, spec.clone(),
                                   LlmProfile::get(ProfileId::GeminiFlash25),
                                   cfg.env.clone(), t.seed);
        for (si, step) in t.steps.iter().enumerate() {
            assert!(!env.env.state.done, "premature done at step {si}");
            let r = env.step(step.action as usize);
            assert!(
                (r.reward - step.reward as f64).abs() < 1e-5,
                "task {} step {si}: reward {} != recorded {}",
                task.id, r.reward, step.reward
            );
            assert!(
                (env.env.state.speedup - step.speedup as f64).abs()
                    < 1e-3 * step.speedup.abs() as f64 + 1e-5,
                "speedup replay diverged"
            );
        }
        assert!(env.env.state.done, "trajectory under-ran the episode");
    }
}

#[test]
fn hierarchical_beats_single_pass_on_fused_tasks() {
    // Table 6's core claim at test scale
    let tasks = kernelbench_level(2)[..12].to_vec();
    let spec = GpuSpec::a100();
    let cfg = EvalCfg { threads: 4, ..Default::default() };
    let ours = evaluate(
        &Method::Mtmc {
            macro_kind: MacroKind::GreedyLookahead,
            micro: ProfileId::GeminiFlash25,
        },
        &tasks, &spec, &cfg,
    );
    let no_hier = evaluate(&Method::MtmcNoHier {
        micro: ProfileId::GeminiFlash25,
    }, &tasks, &spec, &cfg);
    assert!(
        ours.metrics.exec_acc > no_hier.metrics.exec_acc + 0.15,
        "ours {:?} vs no-hier {:?}",
        ours.metrics, no_hier.metrics
    );
}

#[test]
fn action_space_beats_freeform_proposals() {
    // Table 7's core claim at test scale
    let tasks = kernelbench_level(2)[..12].to_vec();
    let spec = GpuSpec::a100();
    let cfg = EvalCfg { threads: 4, ..Default::default() };
    let with_as = evaluate(
        &Method::Mtmc {
            macro_kind: MacroKind::Heuristic {
                label: "GF-2.5".into(),
                mistake_rate: 0.32,
            },
            micro: ProfileId::GeminiFlash25,
        },
        &tasks, &spec, &cfg,
    );
    let without_as = evaluate(
        &Method::Mtmc {
            macro_kind: MacroKind::Freeform {
                label: "GF-2.5".into(),
                wildness: 0.45,
                mistake_rate: 0.32,
            },
            micro: ProfileId::GeminiFlash25,
        },
        &tasks, &spec, &cfg,
    );
    assert!(
        with_as.metrics.mean_speedup > without_as.metrics.mean_speedup,
        "AS {:?} vs freeform {:?}",
        with_as.metrics, without_as.metrics
    );
}

#[test]
fn cuda_target_degrades_micro_coding() {
    // Table 5's mechanism: CUDA error multipliers reduce accuracy
    let tasks = kernelbench_level(2)[..16].to_vec();
    let spec = GpuSpec::a100();
    let triton_cfg = EvalCfg { threads: 4, ..Default::default() };
    let cuda_cfg = EvalCfg { cuda: true, threads: 4, ..Default::default() };
    let m = Method::Baseline { profile: ProfileId::DeepSeekV3 };
    let triton = evaluate(&m, &tasks, &spec, &triton_cfg);
    let cuda = evaluate(&m, &tasks, &spec, &cuda_cfg);
    assert!(
        cuda.metrics.exec_acc <= triton.metrics.exec_acc,
        "cuda {:?} vs triton {:?}",
        cuda.metrics, triton.metrics
    );
}

#[test]
fn cross_gpu_consistency_of_mtmc() {
    // the paper's generalization claim: MTMC stays accurate and >1x on
    // every platform
    let tasks = kernelbench_level(2)[..10].to_vec();
    let cfg = EvalCfg { threads: 4, ..Default::default() };
    for spec in GpuSpec::all() {
        let r = evaluate(
            &Method::Mtmc {
                macro_kind: MacroKind::GreedyLookahead,
                micro: ProfileId::GeminiPro25,
            },
            &tasks, &spec, &cfg,
        );
        assert!(
            r.metrics.exec_acc >= 0.8,
            "{}: acc {:?}", spec.name, r.metrics
        );
        assert!(
            r.metrics.mean_speedup > 0.9,
            "{}: speedup {:?}", spec.name, r.metrics
        );
    }
}
