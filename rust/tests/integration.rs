//! Cross-module integration tests (no PJRT needed): tasks → lowering →
//! transforms → cost model → microcode → env, and the eval metrics.

use qimeng_mtmc::env::{EnvConfig, OptimEnv, StepSignal};
use qimeng_mtmc::eval::{aggregate, evaluate, EvalCfg, MacroKind, Method};
use qimeng_mtmc::gpusim::{
    eager_time_us, kernel_time_us, library_affinity, program_time_us, GpuSpec,
};
use qimeng_mtmc::graph::infer_shapes;
use qimeng_mtmc::kir::{analyze_regions, lower_naive, render, TargetLang};
use qimeng_mtmc::microcode::{LlmProfile, ProfileId};
use qimeng_mtmc::tasks::{
    kernelbench_level, kernelbench_suite, training_corpus, tritonbench_g,
    tritonbench_t,
};
use qimeng_mtmc::transform::{action_mask, STOP_ACTION};
use qimeng_mtmc::util::Rng;

#[test]
fn every_benchmark_task_lowers_prices_and_renders() {
    let spec = GpuSpec::a100();
    let mut all = kernelbench_suite();
    all.extend(tritonbench_g());
    all.extend(tritonbench_t());
    for task in &all {
        let shapes = infer_shapes(&task.graph);
        let p = lower_naive(&task.graph);
        p.validate(&task.graph)
            .unwrap_or_else(|e| panic!("{}: {e}", task.id));
        let t = program_time_us(&p, &task.graph, &shapes, &spec);
        assert!(t.is_finite() && t > 0.0, "{}: bad time {t}", task.id);
        let eager = eager_time_us(&task.graph, &shapes, &spec,
                                  library_affinity(&task.id));
        assert!(eager.is_finite() && eager > 0.0, "{}", task.id);
        let regions = analyze_regions(&p, &task.graph);
        assert!(!regions.is_empty(), "{}: no regions", task.id);
        let src = render(&p, &task.graph, &shapes, TargetLang::Triton);
        assert!(src.contains("@triton.jit"), "{}", task.id);
    }
}

#[test]
fn every_task_has_a_nonempty_action_mask() {
    let spec = GpuSpec::v100();
    for task in kernelbench_suite().iter().step_by(7) {
        let shapes = infer_shapes(&task.graph);
        let p = lower_naive(&task.graph);
        let mask = action_mask(&p, &task.graph, &shapes, &spec);
        assert!(mask[STOP_ACTION]);
        let n = mask.iter().filter(|&&m| m).count();
        assert!(n >= 2, "{}: only {n} valid actions", task.id);
    }
}

#[test]
fn full_episodes_over_suite_sample_never_panic_and_often_improve() {
    let spec = GpuSpec::h100();
    let mut improved = 0;
    let mut total = 0;
    for (i, task) in kernelbench_suite().iter().step_by(11).enumerate() {
        let mut env = OptimEnv::new(
            task,
            spec.clone(),
            LlmProfile::get(ProfileId::GeminiPro25),
            EnvConfig::default(),
            100 + i as u64,
        );
        let start = env.state.speedup;
        let mut rng = Rng::new(i as u64);
        while !env.state.done {
            let mask = env.mask();
            let valid: Vec<usize> =
                (0..mask.len()).filter(|&a| mask[a]).collect();
            env.step(*rng.choose(&valid));
        }
        total += 1;
        if env.state.best_speedup > start {
            improved += 1;
        }
    }
    assert!(
        improved * 2 > total,
        "random exploration improved only {improved}/{total} tasks"
    );
}

#[test]
fn episode_rewards_correlate_with_signals() {
    let task = &kernelbench_level(2)[3];
    let spec = GpuSpec::a100();
    let mut env = OptimEnv::new(
        task,
        spec,
        LlmProfile::get(ProfileId::GeminiFlash25),
        EnvConfig::default(),
        7,
    );
    let mut rng = Rng::new(3);
    while !env.state.done {
        let mask = env.mask();
        let valid: Vec<usize> = (0..mask.len()).filter(|&a| mask[a]).collect();
        let r = env.step(*rng.choose(&valid));
        match r.signal {
            StepSignal::CompileFail | StepSignal::WrongResult
            | StepSignal::Rejected => assert!(r.reward < 0.0),
            StepSignal::Correct { prev, now } => {
                if now > prev * 1.05 {
                    assert!(r.reward > 0.0, "improvement got {:.3}", r.reward);
                }
            }
            StepSignal::Stop { .. } => {}
        }
    }
}

#[test]
fn cost_model_hierarchy_over_suites() {
    // optimized programs must price below naive on every contraction task
    let spec = GpuSpec::a100();
    for task in kernelbench_level(1).iter().take(20) {
        let shapes = infer_shapes(&task.graph);
        let naive = lower_naive(&task.graph);
        let t_naive = program_time_us(&naive, &task.graph, &shapes, &spec);
        // drive greedy improvements via the harness-internal logic:
        // emulate by evaluating MTMC with perfect micro-coder
        let mut profile = LlmProfile::get(ProfileId::GeminiPro25);
        profile.atomic_err = 0.0;
        let mut env = OptimEnv::new(task, spec.clone(), profile,
                                    EnvConfig::default(), 1);
        let mut rng = Rng::new(9);
        while !env.state.done {
            let mask = env.mask();
            let valid: Vec<usize> =
                (0..mask.len() - 1).filter(|&a| mask[a]).collect();
            if valid.is_empty() {
                env.step(STOP_ACTION);
            } else {
                env.step(*rng.choose(&valid));
            }
        }
        let t_opt = env.eager_us / env.state.best_speedup;
        assert!(
            t_opt <= t_naive * 1.001,
            "{}: opt {t_opt:.1} worse than naive {t_naive:.1}",
            task.id
        );
    }
}

#[test]
fn kernel_cost_breakdown_consistent() {
    let task = &kernelbench_level(1)[0];
    let shapes = infer_shapes(&task.graph);
    let p = lower_naive(&task.graph);
    let spec = GpuSpec::h100();
    for k in &p.kernels {
        let c = kernel_time_us(k, &task.graph, &shapes, &spec);
        assert!(c.time_us >= c.t_comp_us.max(c.t_mem_us));
        assert!(c.flops >= 0.0 && c.hbm_bytes > 0.0);
        assert!((0.0..=1.0).contains(&c.occupancy));
    }
}

#[test]
fn eval_metrics_wired_through_harness() {
    let tasks = kernelbench_level(1)[..8].to_vec();
    let spec = GpuSpec::a100();
    let cfg = EvalCfg { threads: 2, ..Default::default() };
    let r = evaluate(&Method::Baseline { profile: ProfileId::GeminiPro25 },
                     &tasks, &spec, &cfg);
    assert_eq!(r.outcomes.len(), 8);
    assert_eq!(aggregate(&r.outcomes), r.metrics);
    assert!(r.metrics.call_acc >= r.metrics.exec_acc);
    assert!(r.metrics.exec_acc >= r.metrics.fast1);
    assert!(r.metrics.fast1 >= r.metrics.fast2);
}

#[test]
fn mtmc_scripted_runner_applies_plan() {
    let tasks = kernelbench_level(2)[..2].to_vec();
    let spec = GpuSpec::h100();
    let cfg = EvalCfg { threads: 1, ..Default::default() };
    // a plan of nothing but Stop: accuracy should be perfect (naive
    // lowering is correct) with modest speedup
    let r = evaluate(
        &Method::Mtmc {
            macro_kind: MacroKind::Scripted(vec![]),
            micro: ProfileId::GeminiPro25,
        },
        &tasks, &spec, &cfg,
    );
    assert!(r.metrics.exec_acc > 0.4); // assembly risk may claim one
}

#[test]
fn corpus_episode_determinism_across_runs() {
    let corpus = training_corpus(3);
    let spec = GpuSpec::a100();
    let run = || {
        let mut out = Vec::new();
        for (i, task) in corpus.iter().enumerate() {
            let mut env = OptimEnv::new(
                task, spec.clone(),
                LlmProfile::get(ProfileId::GeminiFlash25),
                EnvConfig::default(), i as u64,
            );
            let mut rng = Rng::new(42);
            while !env.state.done {
                let mask = env.mask();
                let valid: Vec<usize> =
                    (0..mask.len()).filter(|&a| mask[a]).collect();
                env.step(*rng.choose(&valid));
            }
            out.push(format!("{:.6}", env.state.best_speedup));
        }
        out
    };
    assert_eq!(run(), run());
}
