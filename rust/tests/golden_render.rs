//! Golden-snapshot tests for `kir::render`: the pseudo-Triton and
//! pseudo-CUDA source for two representative tasks (a fused
//! GEMM+bias+activation elementwise chain and a row-softmax reduction) is
//! checked in under `tests/goldens/` and compared byte-for-byte, so any
//! codegen regression is caught by `cargo test`.
//!
//! To regenerate after an *intentional* printer change:
//! `QIMENG_BLESS=1 cargo test --test golden_render` rewrites the golden
//! files in place; re-run without the env var to confirm, then commit.

use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::graph::{infer_shapes, Graph, Op};
use qimeng_mtmc::kir::{
    lower_naive, render, Kernel, LoopOrder, Program, Schedule, TargetLang,
};
use qimeng_mtmc::testkit::gens::{GraphRecipe, ProgramCase};

/// Fused elementwise representative: GEMM + bias + ReLU collapsed into a
/// single scheduled kernel (the shape every KernelBench-L2 winner takes).
fn fused_gemm_bias_relu() -> (Graph, Program) {
    let mut g = Graph::new("golden_fused");
    let x = g.input("x", &[64, 64]);
    let w = g.weight("w", &[64, 64]);
    let b = g.weight("b", &[64]);
    let mm = g.op(Op::MatMul, &[x, w]);
    let ba = g.op(Op::BiasAdd, &[mm, b]);
    let r = g.op(Op::Relu, &[ba]);
    g.mark_output(r);
    let p = Program {
        kernels: vec![Kernel {
            nodes: vec![mm, ba, r],
            schedule: Schedule {
                block_tile: Some((64, 64, 32)),
                reg_tile: Some((8, 8)),
                pipeline_depth: 2,
                loop_order: LoopOrder::Blocked,
                vector_width: 4,
            },
            name: "k0_matmul+k1_bias+k2_relu".to_string(),
        }],
        mutations: Vec::new(),
        compile_broken: false,
    };
    p.validate(&g).expect("golden program must be valid");
    (g, p)
}

/// Reduction representative: naive row softmax, unscheduled.
fn softmax_reduction() -> (Graph, Program) {
    let mut g = Graph::new("golden_softmax");
    let x = g.input("x", &[8, 128]);
    let sm = g.op(Op::Softmax, &[x]);
    g.mark_output(sm);
    let p = lower_naive(&g);
    (g, p)
}

fn check(name: &str, g: &Graph, p: &Program, lang: TargetLang, golden: &str) {
    let shapes = infer_shapes(g);
    let got = render(p, g, &shapes, lang);
    if std::env::var("QIMENG_BLESS").is_ok() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("tests/goldens")
            .join(format!("{name}.{}.txt", lang.label()));
        std::fs::write(&path, &got).expect("bless write");
        eprintln!("blessed {}", path.display());
        return;
    }
    assert_eq!(
        got, golden,
        "rendered {} source for `{name}` diverged from \
         tests/goldens/{name}.{}.txt — if the printer change is \
         intentional, regenerate with QIMENG_BLESS=1 cargo test --test \
         golden_render",
        lang.label(),
        lang.label()
    );
}

#[test]
fn fused_elementwise_triton_matches_golden() {
    let (g, p) = fused_gemm_bias_relu();
    check(
        "fused_gemm_bias_relu", &g, &p, TargetLang::Triton,
        include_str!("goldens/fused_gemm_bias_relu.triton.txt"),
    );
}

#[test]
fn fused_elementwise_cuda_matches_golden() {
    let (g, p) = fused_gemm_bias_relu();
    check(
        "fused_gemm_bias_relu", &g, &p, TargetLang::Cuda,
        include_str!("goldens/fused_gemm_bias_relu.cuda.txt"),
    );
}

#[test]
fn reduction_triton_matches_golden() {
    let (g, p) = softmax_reduction();
    check(
        "softmax_reduction", &g, &p, TargetLang::Triton,
        include_str!("goldens/softmax_reduction.triton.txt"),
    );
}

#[test]
fn reduction_cuda_matches_golden() {
    let (g, p) = softmax_reduction();
    check(
        "softmax_reduction", &g, &p, TargetLang::Cuda,
        include_str!("goldens/softmax_reduction.cuda.txt"),
    );
}

// ---------------------------------------------------------------------
// Generated-then-shrunk goldens: the property suite exercises the render
// path over testkit-generated programs, but those shapes only ever
// existed transiently inside a property run. The two cases below are
// pinned generator outputs (recipes shrunk to their minimal interesting
// form: a scheduled matmul chain and a 1-op elementwise graph), so the
// exact source the generators' program shapes render to is frozen.
//
// These goldens live on disk (not `include_str!`): the first run in a
// fresh checkout writes the snapshot, every later run compares
// byte-for-byte. `QIMENG_BLESS=1` rewrites them after an intentional
// printer change, exactly like the hand-written goldens above.

/// Shrunk case A: a generated matmul chain with a tiling + vectorize
/// action stream applied at full quality.
fn generated_case_a() -> (Graph, Program) {
    let case = ProgramCase {
        recipe: GraphRecipe { seed: 0xA11CE, n_ops: 3 },
        actions: (0..16).collect(),
        quality_milli: 1000,
    };
    let (g, _shapes, p) = case.build(&GpuSpec::a100());
    (g, p)
}

/// Shrunk case B: the generators' minimal graph (n_ops = 1), unscheduled
/// — what every shrink chain bottoms out at.
fn generated_case_b() -> (Graph, Program) {
    let case = ProgramCase {
        recipe: GraphRecipe { seed: 0xB0B, n_ops: 1 },
        actions: Vec::new(),
        quality_milli: 500,
    };
    let (g, _shapes, p) = case.build(&GpuSpec::a100());
    (g, p)
}

fn check_disk_golden(name: &str, g: &Graph, p: &Program, lang: TargetLang) {
    let shapes = infer_shapes(g);
    let got = render(p, g, &shapes, lang);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/goldens")
        .join(format!("{name}.{}.txt", lang.label()));
    if std::env::var("QIMENG_BLESS").is_ok() || !path.exists() {
        std::fs::write(&path, &got).expect("bless write");
        eprintln!("blessed {}", path.display());
        return;
    }
    let golden = std::fs::read_to_string(&path).expect("read golden");
    assert_eq!(
        got, golden,
        "rendered {} source for `{name}` diverged from {} — if the \
         printer or generator change is intentional, regenerate with \
         QIMENG_BLESS=1 cargo test --test golden_render",
        lang.label(),
        path.display()
    );
}

#[test]
fn generated_shrunk_case_a_matches_golden() {
    let (g, p) = generated_case_a();
    p.validate(&g).expect("generated program must be valid");
    check_disk_golden("gen_shrunk_a", &g, &p, TargetLang::Triton);
    check_disk_golden("gen_shrunk_a", &g, &p, TargetLang::Cuda);
}

#[test]
fn generated_shrunk_case_b_matches_golden() {
    let (g, p) = generated_case_b();
    p.validate(&g).expect("generated program must be valid");
    check_disk_golden("gen_shrunk_b", &g, &p, TargetLang::Triton);
    check_disk_golden("gen_shrunk_b", &g, &p, TargetLang::Cuda);
}

#[test]
fn generated_cases_are_stable_across_rebuilds() {
    // the recipes must materialize identically every time, or the goldens
    // above would be meaningless
    for mk in [generated_case_a, generated_case_b] {
        let (g1, p1) = mk();
        let (g2, p2) = mk();
        let s1 = infer_shapes(&g1);
        assert_eq!(p1, p2);
        assert_eq!(
            render(&p1, &g1, &s1, TargetLang::Triton),
            render(&p2, &g2, &infer_shapes(&g2), TargetLang::Triton)
        );
    }
}

#[test]
fn renders_are_deterministic() {
    let (g, p) = fused_gemm_bias_relu();
    let shapes = infer_shapes(&g);
    assert_eq!(
        render(&p, &g, &shapes, TargetLang::Triton),
        render(&p, &g, &shapes, TargetLang::Triton)
    );
}

/// The session render memo (`Session::render_cached`, what `--show-code`
/// goes through) returns exactly the direct `render` output for the
/// golden programs, in both dialects, and serves repeats from cache.
#[test]
fn session_render_memo_matches_direct_render() {
    let session = qimeng_mtmc::engine::Session::default();
    for (g, p) in [fused_gemm_bias_relu(), softmax_reduction()] {
        let shapes = infer_shapes(&g);
        for lang in [TargetLang::Triton, TargetLang::Cuda] {
            let direct = render(&p, &g, &shapes, lang);
            let memoized = session.render_cached(&p, &g, &shapes, lang);
            assert_eq!(
                *memoized, direct,
                "render memo diverged for `{}` ({})",
                g.name,
                lang.label()
            );
            let again = session.render_cached(&p, &g, &shapes, lang);
            assert!(
                std::sync::Arc::ptr_eq(&memoized, &again),
                "repeat render of `{}` was not served from the memo",
                g.name
            );
        }
    }
    let stats = session.stats();
    assert_eq!((stats.render_hits, stats.render_misses), (4, 4));
}
