"""Masked row-wise log-softmax as a Pallas kernel (the action head).

The Macro-Thinking action space is 65 discrete actions of which only the
region-analysis-valid subset may be sampled; the mask arrives from the rust
coordinator as a {0,1} f32 matrix. The kernel computes a numerically stable
log-softmax after adding -1e9 to masked-out lanes.

Layout note (TPU rethink of the paper's warp-shuffle reductions): rows live
along the 128-wide lane dimension, so the max/sum reductions are lane
reductions — no shared-memory tree needed. The whole (bm, A) block sits in
VMEM. ``interpret=True`` for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MASK_NEG

_BM = 128


def _masked_log_softmax_kernel(lg_ref, mk_ref, o_ref):
    lg = lg_ref[...]
    mk = mk_ref[...]
    masked = lg + (mk - 1.0) * (-MASK_NEG)
    m = jnp.max(masked, axis=-1, keepdims=True)
    z = masked - m
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=-1, keepdims=True))
    o_ref[...] = z - lse


@jax.custom_vjp
def masked_log_softmax(logits, mask):
    """Row-wise masked log-softmax; logits/mask: [B, A] f32 -> [B, A] f32."""
    return _masked_log_softmax_impl(logits, mask)


def _masked_log_softmax_impl(logits, mask):
    b, a = logits.shape
    bm = min(_BM, b) if b > 0 else 1
    pad = (-b) % bm
    if pad:
        zl = jnp.zeros((pad, a), logits.dtype)
        # Padding rows get a fully *valid* mask so the kernel never sees an
        # all-masked row (whose lse would be log(eps)-ish garbage).
        zm = jnp.ones((pad, a), mask.dtype)
        logits = jnp.concatenate([logits, zl], axis=0)
        mask = jnp.concatenate([mask, zm], axis=0)
    grid = ((b + pad) // bm,)
    out = pl.pallas_call(
        _masked_log_softmax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, a), lambda i: (i, 0)),
            pl.BlockSpec((bm, a), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, a), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b + pad, a), jnp.float32),
        interpret=True,
    )(logits, mask)
    return out[:b]


def _mls_fwd(logits, mask):
    logp = _masked_log_softmax_impl(logits, mask)
    return logp, (logp, mask)


def _mls_bwd(res, g):
    # d log_softmax: dL/dlogits = g - softmax * sum(g, axis=-1).
    # The mask enters only through the additive -1e9 constant, so its
    # cotangent is zero; masked lanes get (numerically) zero gradient via
    # their ~zero probabilities.
    logp, mask = res
    p = jnp.exp(logp) * mask
    gsum = jnp.sum(g, axis=-1, keepdims=True)
    dlogits = g - p * gsum
    return dlogits, jnp.zeros_like(mask)


masked_log_softmax.defvjp(_mls_fwd, _mls_bwd)
