"""Layer-1 Pallas kernels for the Macro-Thinking policy network.

Every kernel here runs under ``interpret=True`` (the CPU PJRT plugin cannot
execute Mosaic custom-calls), and is checked against the pure-jnp oracles in
:mod:`compile.kernels.ref` by ``python/tests``.
"""

from .fused_linear import fused_linear, matmul
from .masked_softmax import masked_log_softmax

__all__ = ["fused_linear", "matmul", "masked_log_softmax"]
