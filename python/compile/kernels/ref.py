"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

These are the *reference semantics*; the Pallas implementations in
``fused_linear.py`` / ``masked_softmax.py`` must match them to ~1e-5 f32
tolerance across shapes (swept by hypothesis in python/tests).
"""

import jax.numpy as jnp

MASK_NEG = -1e9  # additive mask penalty; large-but-finite keeps softmax stable


def matmul_ref(x, w):
    """Plain f32 matmul: x[B,K] @ w[K,N] -> [B,N]."""
    return jnp.matmul(x, w)


def fused_linear_ref(x, w, b, act="tanh"):
    """act(x @ w + b). ``act`` in {"tanh", "relu", "id"}."""
    y = jnp.matmul(x, w) + b
    if act == "tanh":
        return jnp.tanh(y)
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "id":
        return y
    raise ValueError(f"unknown act {act!r}")


def masked_log_softmax_ref(logits, mask):
    """Row-wise log-softmax over valid (mask==1) entries.

    Invalid entries receive an additive -1e9 before normalisation, so their
    resulting log-probability is ~-1e9 (probability ~0) — the rust
    coordinator must never sample them.
    """
    masked = logits + (mask - 1.0) * (-MASK_NEG)
    m = jnp.max(masked, axis=-1, keepdims=True)
    z = masked - m
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=-1, keepdims=True))
    return z - lse
