"""Tiled fused linear layer (matmul + bias + activation) as a Pallas kernel.

This is the Macro-Thinking policy network's hot spot: every trunk layer and
both heads are instances of ``act(x @ W + b)``.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid tiles the batch
dimension so each program instance holds an ``(bm, K)`` activation block, the
full ``(K, N)`` weight panel, and the ``(bm, N)`` output block in VMEM —
the BlockSpec index maps express the HBM->VMEM schedule that a CUDA kernel
would express with threadblocks + shared memory. ``K``/``N`` panels for the
policy net (<=256x256 f32 ~ 256 KiB) sit far below the ~16 MiB VMEM budget,
and the ``(bm, K) @ (K, N)`` inner product is shaped for the 128x128 MXU
(bm is capped at 128; K, N are multiples of 8 after padding).

A custom VJP routes the *backward* matmuls (dx = g @ W^T, dW = x^T @ g)
through the same Pallas matmul so training also exercises the L1 kernels.

``interpret=True`` everywhere: real-TPU lowering emits Mosaic custom-calls
the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch-tile cap: one MXU-aligned stripe of rows per program instance.
_BM = 128


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, act):
    """One (bm, N) output block: full-K contraction + bias + activation."""
    y = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    y = y + b_ref[...][None, :]
    if act == "tanh":
        y = jnp.tanh(y)
    elif act == "relu":
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def _pad_rows(x, bm):
    b = x.shape[0]
    pad = (-b) % bm
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x, b


def _row_tiled_call(kernel, x, cols_out, extra_args, extra_specs):
    """Run ``kernel`` over row tiles of ``x``; trailing operands unblocked."""
    bm = min(_BM, x.shape[0]) if x.shape[0] > 0 else 1
    xp, b = _pad_rows(x, bm)
    grid = (xp.shape[0] // bm,)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, x.shape[1]), lambda i: (i, 0)),
            *extra_specs,
        ],
        out_specs=pl.BlockSpec((bm, cols_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], cols_out), jnp.float32),
        interpret=True,
    )(xp, *extra_args)
    return out[:b]


def matmul(x, w):
    """Pallas row-tiled matmul: x[B,K] @ w[K,N] -> [B,N] (f32)."""
    k, n = w.shape
    return _row_tiled_call(
        _matmul_kernel,
        x,
        n,
        (w,),
        [pl.BlockSpec((k, n), lambda i: (0, 0))],
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, act="tanh"):
    """act(x @ w + b) with a Pallas forward and Pallas backward matmuls."""
    return _fused_linear_fwd_impl(x, w, b, act)


def _fused_linear_fwd_impl(x, w, b, act):
    k, n = w.shape
    return _row_tiled_call(
        functools.partial(_linear_kernel, act=act),
        x,
        n,
        (w, b),
        [
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
    )


def _fused_linear_fwd(x, w, b, act):
    y = _fused_linear_fwd_impl(x, w, b, act)
    return y, (x, w, y)


def _fused_linear_bwd(act, res, g):
    x, w, y = res
    if act == "tanh":
        dpre = g * (1.0 - y * y)
    elif act == "relu":
        dpre = g * (y > 0.0).astype(g.dtype)
    else:  # "id"
        dpre = g
    # Backward matmuls through the Pallas kernel (dW via the transposed
    # product so the row-tiled grid still tiles the long dimension).
    dx = matmul(dpre, w.T)
    dw = matmul(dpre.T, x).T
    db = jnp.sum(dpre, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)
