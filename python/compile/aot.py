"""AOT export: lower the L2 policy model to HLO *text* artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax>=0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust ``xla`` 0.1.6 crate) rejects; the text parser
re-assigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to --out, default ../artifacts):
- policy_fwd_b1.hlo.txt    request-path inference, B=1
- policy_fwd_b64.hlo.txt   batched eval fwd, B=64
- train_step.hlo.txt       fused PPO+Adam update, B=256
- meta.json                shapes + hyperparameters for the rust runtime

Run once via ``make artifacts``; python never runs on the request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import CONFIG, NP, fwd_flat, param_specs, train_step_flat


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def fwd_arg_specs(batch, cfg=CONFIG):
    params = [_spec(s) for _, s in param_specs(cfg)]
    obs = _spec((batch, cfg["obs_dim"]))
    mask = _spec((batch, cfg["act_dim"]))
    return (*params, obs, mask)


def train_arg_specs(cfg=CONFIG):
    b = cfg["train_batch"]
    params = [_spec(s) for _, s in param_specs(cfg)]
    m = [_spec(s) for _, s in param_specs(cfg)]
    v = [_spec(s) for _, s in param_specs(cfg)]
    t = _spec(())
    obs = _spec((b, cfg["obs_dim"]))
    mask = _spec((b, cfg["act_dim"]))
    act = _spec((b,), jnp.int32)
    old_logp = _spec((b,))
    adv = _spec((b,))
    ret = _spec((b,))
    return (*params, *m, *v, t, obs, mask, act, old_logp, adv, ret)


def lower_all():
    """Lower every artifact; returns {name: hlo_text}."""
    arts = {}
    for batch, name in ((1, "policy_fwd_b1"), (CONFIG["eval_batch"],
                                               "policy_fwd_b64")):
        lowered = jax.jit(fwd_flat).lower(*fwd_arg_specs(batch))
        arts[name] = to_hlo_text(lowered)
    lowered = jax.jit(train_step_flat).lower(*train_arg_specs())
    arts["train_step"] = to_hlo_text(lowered)
    return arts


def meta_json():
    return {
        "config": CONFIG,
        "num_params": NP,
        "param_specs": [[n, list(s)] for n, s in param_specs()],
        "artifacts": {
            "policy_fwd_b1": {"batch": 1},
            "policy_fwd_b64": {"batch": CONFIG["eval_batch"]},
            "train_step": {"batch": CONFIG["train_batch"]},
        },
        # fwd outputs: (logp[B,A], value[B]); train outputs: 24 state
        # arrays + metrics[6] = [loss, pg, vf, ent, kl, gnorm]
        "fwd_outputs": ["logp", "value"],
        "train_metrics": ["loss", "pg_loss", "v_loss", "entropy",
                          "approx_kl", "grad_norm"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for name, text in lower_all().items():
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    meta_path = os.path.join(args.out, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta_json(), f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
