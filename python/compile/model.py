"""Layer-2 JAX model: the Macro-Thinking policy network + PPO train step.

The policy is the paper's "lightweight LLM" substitute (DESIGN.md
substitution table): a structural featurizer (computed in rust, 64-dim)
feeds an MLP trunk with a masked 65-way action head and a value head. All
dense layers run through the L1 Pallas ``fused_linear`` kernel; the action
head goes through the Pallas ``masked_log_softmax``.

Everything is a pure function of explicitly-passed parameter arrays so the
AOT artifacts (``aot.py``) are stateless:

- ``policy_fwd(params, obs, mask) -> (logp, value)`` — the request-path
  artifact, exported at B=1 (inference) and B=64 (batched eval).
- ``train_step(params, opt_m, opt_v, t, batch...) -> (params', m', v',
  metrics)`` — one fused PPO+Adam update, exported at B=256.

Hyperparameters live in ``CONFIG`` and are baked into the HLO (the rust
side reads them back from artifacts/meta.json).
"""

import jax
import jax.numpy as jnp

from .kernels import fused_linear, masked_log_softmax
from .kernels.ref import fused_linear_ref, masked_log_softmax_ref

# ---------------------------------------------------------------- config

CONFIG = {
    "obs_dim": 64,        # featurizer output (rust env::obs must match)
    "act_dim": 65,        # 8 opt types x 8 regions + Stop
    "hidden": 128,
    "train_batch": 256,
    "eval_batch": 64,
    # PPO
    "clip_eps": 0.2,
    "vf_coef": 0.5,
    "ent_coef": 0.01,
    "lr": 3e-4,
    "adam_b1": 0.9,
    "adam_b2": 0.999,
    "adam_eps": 1e-8,
    "max_grad_norm": 0.5,
}

# parameter list: (name, shape) in the exact positional order the rust
# runtime passes literals.
def param_specs(cfg=CONFIG):
    f, h, a = cfg["obs_dim"], cfg["hidden"], cfg["act_dim"]
    return [
        ("w1", (f, h)),
        ("b1", (h,)),
        ("w2", (h, h)),
        ("b2", (h,)),
        ("wl", (h, a)),
        ("bl", (a,)),
        ("wv", (h, 1)),
        ("bv", (1,)),
    ]


def init_params(key, cfg=CONFIG):
    """Orthogonal-ish (scaled normal) init, matching rust policy::init."""
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if len(shape) == 2:
            scale = jnp.sqrt(2.0 / shape[0])
            if name == "wl":
                scale = scale * 0.01  # near-uniform initial policy
            if name == "wv":
                scale = scale * 1.0
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


# ---------------------------------------------------------------- forward


def policy_fwd(params, obs, mask, *, use_pallas=True):
    """(logp[B,A], value[B]) from obs[B,F] and action mask[B,A]."""
    w1, b1, w2, b2, wl, bl, wv, bv = params
    lin = fused_linear if use_pallas else fused_linear_ref
    sm = masked_log_softmax if use_pallas else masked_log_softmax_ref
    h1 = lin(obs, w1, b1, "tanh")
    h2 = lin(h1, w2, b2, "tanh")
    logits = lin(h2, wl, bl, "id")
    logp = sm(logits, mask)
    value = lin(h2, wv, bv, "id")[:, 0]
    return logp, value


# ---------------------------------------------------------------- PPO loss


def ppo_loss(params, obs, mask, act, old_logp, adv, ret, cfg=CONFIG,
             *, use_pallas=True):
    """Clipped-surrogate PPO loss with masked entropy bonus.

    act: int32[B] chosen actions; old_logp: f32[B] behaviour log-probs;
    adv: f32[B] GAE advantages (normalised rust-side); ret: f32[B] returns.
    """
    logp_all, value = policy_fwd(params, obs, mask, use_pallas=use_pallas)
    b = obs.shape[0]
    logp_a = logp_all[jnp.arange(b), act]

    ratio = jnp.exp(logp_a - old_logp)
    clipped = jnp.clip(ratio, 1.0 - cfg["clip_eps"], 1.0 + cfg["clip_eps"])
    pg_loss = -jnp.mean(jnp.minimum(ratio * adv, clipped * adv))

    v_loss = 0.5 * jnp.mean((value - ret) ** 2)

    # Masked entropy: p log p only over valid lanes (invalid lanes have
    # p ~ exp(-1e9) = 0 but 0 * (-1e9) would be -0*inf noise without mask).
    p = jnp.exp(logp_all) * mask
    ent = -jnp.sum(p * jnp.where(mask > 0, logp_all, 0.0), axis=-1)
    ent_mean = jnp.mean(ent)

    approx_kl = jnp.mean(old_logp - logp_a)
    loss = pg_loss + cfg["vf_coef"] * v_loss - cfg["ent_coef"] * ent_mean
    return loss, (pg_loss, v_loss, ent_mean, approx_kl)


# ---------------------------------------------------------------- Adam


def _global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(g * g) for g in grads) + 1e-12)


def train_step(params, opt_m, opt_v, t, obs, mask, act, old_logp, adv, ret,
               cfg=CONFIG, *, use_pallas=True):
    """One fused PPO epoch step: grad -> clip -> Adam -> new state.

    Returns (new_params, new_m, new_v, metrics[6]) where metrics =
    [loss, pg_loss, v_loss, entropy, approx_kl, grad_norm].
    """
    (loss, aux), grads = jax.value_and_grad(ppo_loss, has_aux=True)(
        params, obs, mask, act, old_logp, adv, ret, cfg,
        use_pallas=use_pallas)
    pg_loss, v_loss, ent, kl = aux

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg["max_grad_norm"] / gnorm)
    grads = [g * scale for g in grads]

    b1, b2, eps, lr = (cfg["adam_b1"], cfg["adam_b2"], cfg["adam_eps"],
                       cfg["lr"])
    t1 = t + 1.0
    bc1 = 1.0 - b1 ** t1
    bc2 = 1.0 - b2 ** t1
    new_params, new_m, new_v = [], [], []
    for p, m, v, g in zip(params, opt_m, opt_v, grads):
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * (g * g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        new_params.append(p - lr * update)
        new_m.append(m)
        new_v.append(v)

    metrics = jnp.stack([loss, pg_loss, v_loss, ent, kl, gnorm])
    return new_params, new_m, new_v, metrics


# ------------------------------------------------------- AOT entry points
# Flat-argument wrappers (HLO parameters are positional): 8 params [+8 m,
# +8 v, +t] + batch tensors. aot.py lowers exactly these.

NP = 8  # number of parameter arrays


def fwd_flat(*args):
    params = list(args[:NP])
    obs, mask = args[NP], args[NP + 1]
    logp, value = policy_fwd(params, obs, mask)
    return logp, value


def train_step_flat(*args):
    params = list(args[:NP])
    m = list(args[NP:2 * NP])
    v = list(args[2 * NP:3 * NP])
    t = args[3 * NP]
    obs, mask, act, old_logp, adv, ret = args[3 * NP + 1:3 * NP + 7]
    new_p, new_m, new_v, metrics = train_step(
        params, m, v, t, obs, mask, act, old_logp, adv, ret)
    return (*new_p, *new_m, *new_v, metrics)
