"""Build-time-only python package: L2 JAX policy model + L1 Pallas kernels.

Nothing in here is imported at runtime — ``compile.aot`` lowers everything
to HLO text once (``make artifacts``) and the rust coordinator loads the
artifacts through PJRT.
"""
