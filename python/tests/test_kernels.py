"""L1 correctness: Pallas kernels vs pure-jnp oracles (the core signal).

hypothesis sweeps shapes (including non-tile-multiple batches, B=1, and
ragged action widths) and degenerate masks.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_linear, masked_log_softmax, matmul
from compile.kernels.ref import (fused_linear_ref, masked_log_softmax_ref,
                                 matmul_ref)

jax.config.update("jax_platform_name", "cpu")


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ------------------------------------------------------------- matmul


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 300), k=st.integers(1, 96), n=st.integers(1, 96),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(b, k, n, seed):
    x = _rand(seed, b, k)
    w = _rand(seed + 1, k, n)
    np.testing.assert_allclose(matmul(x, w), matmul_ref(x, w),
                               rtol=1e-5, atol=1e-5)


def test_matmul_exact_small():
    x = jnp.array([[1.0, 2.0], [3.0, 4.0]])
    w = jnp.array([[1.0, 0.0], [0.0, 1.0]])
    np.testing.assert_allclose(matmul(x, w), x)


# --------------------------------------------------------- fused_linear


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 300), k=st.integers(1, 80), n=st.integers(1, 80),
       act=st.sampled_from(["tanh", "relu", "id"]),
       seed=st.integers(0, 2**31 - 1))
def test_fused_linear_matches_ref(b, k, n, act, seed):
    x = _rand(seed, b, k)
    w = _rand(seed + 1, k, n)
    bias = _rand(seed + 2, n)
    np.testing.assert_allclose(
        fused_linear(x, w, bias, act), fused_linear_ref(x, w, bias, act),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("act", ["tanh", "relu", "id"])
def test_fused_linear_grads_match_ref(act):
    """Custom-VJP backward (Pallas matmuls) vs autodiff through the oracle."""
    x = _rand(7, 33, 16)
    w = _rand(8, 16, 24)
    bias = _rand(9, 24)

    def loss_pallas(x, w, b):
        return jnp.sum(jnp.sin(fused_linear(x, w, b, act)))

    def loss_ref(x, w, b):
        return jnp.sum(jnp.sin(fused_linear_ref(x, w, b, act)))

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, bias)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, bias)
    for a, b_ in zip(gp, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-5)


def test_fused_linear_batch_one():
    x = _rand(3, 1, 64)
    w = _rand(4, 64, 128)
    bias = _rand(5, 128)
    np.testing.assert_allclose(
        fused_linear(x, w, bias, "tanh"),
        fused_linear_ref(x, w, bias, "tanh"), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------ masked softmax


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 300), a=st.integers(2, 80),
       seed=st.integers(0, 2**31 - 1))
def test_masked_log_softmax_matches_ref(b, a, seed):
    logits = 5.0 * _rand(seed, b, a)
    key = jax.random.PRNGKey(seed + 1)
    mask = jax.random.bernoulli(key, 0.7, (b, a)).astype(jnp.float32)
    # guarantee at least one valid action per row (env invariant: Stop is
    # always available)
    mask = mask.at[:, a - 1].set(1.0)
    np.testing.assert_allclose(
        masked_log_softmax(logits, mask),
        masked_log_softmax_ref(logits, mask), rtol=1e-5, atol=1e-5)


def test_masked_rows_are_normalised():
    logits = 3.0 * _rand(11, 37, 65)
    mask = jnp.ones((37, 65)).at[:, ::3].set(0.0).at[:, 64].set(1.0)
    logp = masked_log_softmax(logits, mask)
    p = jnp.exp(logp) * mask
    np.testing.assert_allclose(jnp.sum(p, axis=-1), jnp.ones(37),
                               rtol=1e-5, atol=1e-5)


def test_masked_lanes_never_sampled():
    logits = jnp.zeros((4, 65)) + 10.0
    mask = jnp.zeros((4, 65)).at[:, 7].set(1.0)
    logp = masked_log_softmax(logits, mask)
    assert float(jnp.max(jnp.exp(logp[:, 0]))) < 1e-20
    np.testing.assert_allclose(logp[:, 7], jnp.zeros(4), atol=1e-5)


def test_extreme_logits_stable():
    logits = jnp.array([[1e4, -1e4, 0.0, 3.0]])
    mask = jnp.ones((1, 4))
    logp = masked_log_softmax(logits, mask)
    assert bool(jnp.all(jnp.isfinite(logp)))
    np.testing.assert_allclose(logp[0, 0], 0.0, atol=1e-5)
