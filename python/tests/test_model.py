"""L2 correctness: policy fwd/train_step shapes, pallas-vs-ref parity,
PPO update sanity (loss decreases on a fixed batch, masks respected)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import (CONFIG, NP, fwd_flat, init_params, param_specs,
                           policy_fwd, ppo_loss, train_step,
                           train_step_flat)

jax.config.update("jax_platform_name", "cpu")

F, A, H = CONFIG["obs_dim"], CONFIG["act_dim"], CONFIG["hidden"]


def _batch(b, seed=0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 6)
    obs = jax.random.normal(ks[0], (b, F), jnp.float32)
    mask = jax.random.bernoulli(ks[1], 0.6, (b, A)).astype(jnp.float32)
    mask = mask.at[:, A - 1].set(1.0)
    act = jax.random.randint(ks[2], (b,), 0, A)
    # force chosen actions valid
    mask = mask.at[jnp.arange(b), act].set(1.0)
    old_logp = -1.5 + 0.1 * jax.random.normal(ks[3], (b,))
    adv = jax.random.normal(ks[4], (b,))
    ret = jax.random.normal(ks[5], (b,))
    return obs, mask, act, old_logp, adv, ret


def test_fwd_shapes_and_parity():
    params = init_params(jax.random.PRNGKey(1))
    obs, mask, *_ = _batch(9)
    logp, value = policy_fwd(params, obs, mask, use_pallas=True)
    logp_r, value_r = policy_fwd(params, obs, mask, use_pallas=False)
    assert logp.shape == (9, A) and value.shape == (9,)
    np.testing.assert_allclose(logp, logp_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(value, value_r, rtol=1e-4, atol=1e-5)


def test_fwd_distribution_valid():
    params = init_params(jax.random.PRNGKey(2))
    obs, mask, *_ = _batch(17, seed=3)
    logp, _ = policy_fwd(params, obs, mask)
    p = jnp.exp(logp) * mask
    np.testing.assert_allclose(p.sum(-1), np.ones(17), rtol=1e-5)
    # masked-out actions carry ~zero probability
    assert float(jnp.max(jnp.exp(logp) * (1 - mask))) < 1e-20


def test_ppo_loss_finite_and_pallas_parity():
    params = init_params(jax.random.PRNGKey(4))
    batch = _batch(32, seed=5)
    lp, auxp = ppo_loss(params, *batch, use_pallas=True)
    lr_, auxr = ppo_loss(params, *batch, use_pallas=False)
    assert np.isfinite(float(lp))
    np.testing.assert_allclose(float(lp), float(lr_), rtol=1e-4, atol=1e-5)
    for a, b in zip(auxp, auxr):
        np.testing.assert_allclose(float(a), float(b), rtol=1e-3, atol=1e-4)


def test_train_step_improves_surrogate():
    """A few Adam steps on a fixed batch must reduce the PPO loss."""
    params = init_params(jax.random.PRNGKey(6))
    zeros = [jnp.zeros_like(p) for p in params]
    m, v = list(zeros), [jnp.zeros_like(p) for p in params]
    batch = _batch(64, seed=7)
    l0 = float(ppo_loss(params, *batch, use_pallas=False)[0])
    t = jnp.float32(0.0)
    for i in range(5):
        params, m, v, metrics = train_step(params, m, v, t + i, *batch,
                                           use_pallas=False)
    l1 = float(ppo_loss(params, *batch, use_pallas=False)[0])
    assert l1 < l0
    assert np.isfinite(metrics).all()


def test_flat_wrappers_roundtrip():
    params = init_params(jax.random.PRNGKey(8))
    obs, mask, act, old_logp, adv, ret = _batch(CONFIG["train_batch"], 9)
    outs = train_step_flat(*params,
                           *[jnp.zeros_like(p) for p in params],
                           *[jnp.zeros_like(p) for p in params],
                           jnp.float32(0.0),
                           obs, mask, act, old_logp, adv, ret)
    assert len(outs) == 3 * NP + 1
    for (name, shape), o in zip(param_specs(), outs[:NP]):
        assert o.shape == shape, name
    assert outs[-1].shape == (6,)

    logp, value = fwd_flat(*params, obs[:1], mask[:1])
    assert logp.shape == (1, A) and value.shape == (1,)


def test_param_count_matches_specs():
    params = init_params(jax.random.PRNGKey(10))
    assert len(params) == NP == len(param_specs())
    n = sum(int(np.prod(s)) for _, s in param_specs())
    assert n == F * H + H + H * H + H + H * A + A + H + 1
