"""AOT export sanity: artifacts lower to parseable HLO text with the
expected parameter counts, and the lowered fwd executes (via jax) with the
same numbers as the eager path."""

import json

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import (fwd_arg_specs, lower_all, meta_json,
                         train_arg_specs)
from compile.model import CONFIG, NP, fwd_flat, init_params

jax.config.update("jax_platform_name", "cpu")


def test_lower_all_produces_hlo_text():
    arts = lower_all()
    assert set(arts) == {"policy_fwd_b1", "policy_fwd_b64", "train_step"}
    for name, text in arts.items():
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_fwd_param_arity():
    specs = fwd_arg_specs(1)
    assert len(specs) == NP + 2
    specs = train_arg_specs()
    assert len(specs) == 3 * NP + 1 + 6


def test_compiled_fwd_matches_eager():
    params = init_params(jax.random.PRNGKey(0))
    obs = jax.random.normal(jax.random.PRNGKey(1), (1, CONFIG["obs_dim"]))
    mask = jnp.ones((1, CONFIG["act_dim"]))
    eager = fwd_flat(*params, obs, mask)
    compiled = jax.jit(fwd_flat).lower(
        *fwd_arg_specs(1)).compile()(*params, obs, mask)
    np.testing.assert_allclose(np.asarray(eager[0]),
                               np.asarray(compiled[0]), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(eager[1]),
                               np.asarray(compiled[1]), rtol=1e-5, atol=1e-6)


def test_meta_json_schema():
    meta = meta_json()
    s = json.dumps(meta)
    assert "obs_dim" in s and "train_metrics" in s
    assert meta["num_params"] == NP
    assert len(meta["param_specs"]) == NP
