//! TritonBench-style evaluation demo: run MTMC and two baselines over
//! slices of TRITONBENCH-G and -T and print Table-4-style rows, plus a
//! per-family breakdown showing where the wins come from
//! (flash-attention-style tiling, fused layernorm epilogues, ...).
//!
//! ```bash
//! cargo run --release --example tritonbench_demo
//! ```

use qimeng_mtmc::eval::{evaluate, EvalCfg, MacroKind, Method};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::microcode::ProfileId;
use qimeng_mtmc::report::{metric_cells, Table};
use qimeng_mtmc::tasks::{tritonbench_g, tritonbench_t, Task};
use std::collections::BTreeMap;

fn main() {
    let spec = GpuSpec::a100();
    let cfg = EvalCfg::default();
    let n = std::env::var("TB_LIMIT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60usize);

    for (name, tasks_full) in
        [("TRITONBENCH-G", tritonbench_g()), ("TRITONBENCH-T", tritonbench_t())]
    {
        let tasks: Vec<Task> = tasks_full.into_iter().take(n).collect();
        let mut table = Table::new(
            &format!("{name} ({} tasks, A100)", tasks.len()),
            &["Method", "CallAcc(%)", "ExecAcc(%)", "fast1/fast2(%)",
              "Mean Speedup"],
        );
        let methods = [
            Method::Baseline { profile: ProfileId::GeminiFlash25 },
            Method::Baseline { profile: ProfileId::KernelLlm },
            Method::Mtmc {
                macro_kind: MacroKind::GreedyLookahead,
                micro: ProfileId::GeminiFlash25,
            },
        ];
        let mut mtmc_result = None;
        for m in &methods {
            let r = evaluate(m, &tasks, &spec, &cfg);
            table.row(metric_cells(&r, true));
            if matches!(m, Method::Mtmc { .. }) {
                mtmc_result = Some(r);
            }
        }
        print!("{}", table.render());

        // per-family breakdown of the MTMC run
        let r = mtmc_result.unwrap();
        let mut fam: BTreeMap<&str, (usize, usize, f64)> = BTreeMap::new();
        for (task, o) in tasks.iter().zip(&r.outcomes) {
            let e = fam.entry(task.family.label()).or_default();
            e.0 += 1;
            if o.correct {
                e.1 += 1;
                e.2 += o.speedup;
            }
        }
        println!("MTMC per-family (n, correct, mean speedup of correct):");
        for (f, (n, c, s)) in fam {
            println!("  {f:<18} n={n:<3} correct={c:<3} speedup={:.2}x",
                     if c > 0 { s / c as f64 } else { 0.0 });
        }
        println!();
    }
}
