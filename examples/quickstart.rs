//! Quickstart: optimize one GPU kernel with MTMC and watch the schedule
//! evolve.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Takes a fused GEMM+bias+activation task (KernelBench-L2-style), runs
//! the macro-thinking/micro-coding loop with a greedy macro policy, and
//! prints each semantic action, its micro-coding outcome and the speedup
//! trajectory vs expert-optimized PyTorch Eager — finishing with the
//! generated pseudo-Triton.

use qimeng_mtmc::env::{EnvConfig, OptimEnv};
use qimeng_mtmc::gpusim::{library_affinity, eager_time_us, GpuSpec};
use qimeng_mtmc::graph::infer_shapes;
use qimeng_mtmc::kir::{render, TargetLang};
use qimeng_mtmc::microcode::{LlmProfile, ProfileId};
use qimeng_mtmc::tasks::kernelbench_level;
use qimeng_mtmc::transform::{apply_action, decode_action, STOP_ACTION};

fn main() {
    let spec = GpuSpec::a100();
    let tasks = kernelbench_level(2);
    let task = &tasks[0];
    let shapes = infer_shapes(&task.graph);
    let eager = eager_time_us(&task.graph, &shapes, &spec,
                              library_affinity(&task.id));
    println!("task: {} on {}", task.id, spec.name);
    println!("PyTorch Eager reference: {:.1} us\n", eager);

    let mut env = OptimEnv::new(
        task,
        spec.clone(),
        LlmProfile::get(ProfileId::GeminiPro25),
        EnvConfig::default(),
        42,
    );
    println!("step  0  naive Triton lowering            speedup {:.2}x",
             env.state.speedup);

    let mut step = 1;
    // edges that already failed at this tree node (the env is
    // edge-deterministic: retrying cannot succeed)
    let mut failed: std::collections::HashSet<usize> = Default::default();
    while !env.state.done {
        // greedy macro-thinking: pick the action with the best one-step
        // improvement under the hardware cost model
        let mask = env.mask();
        let best = (0..STOP_ACTION)
            .filter(|&a| mask[a] && !failed.contains(&a))
            .filter_map(|a| {
                apply_action(&env.state.program, &task.graph, &shapes,
                             &decode_action(a), &spec, 1.0)
                    .ok()
                    .map(|p| {
                        (a, qimeng_mtmc::gpusim::program_time_us(
                            &p, &task.graph, &shapes, &spec))
                    })
            })
            .min_by(|x, y| x.1.partial_cmp(&y.1).unwrap());
        let now_us = eager / env.state.speedup;
        let action = match best {
            Some((a, t)) if t < now_us * 0.99 => a,
            _ => STOP_ACTION,
        };
        if action == STOP_ACTION {
            env.step(action);
            println!("step {step:>2}  Stop");
            break;
        }
        let act = decode_action(action);
        let before = env.state.path_hash;
        let r = env.step(action);
        if env.state.path_hash == before {
            failed.insert(action);
        } else {
            failed.clear();
        }
        println!(
            "step {step:>2}  {:<16} region {}  ->  {:<13} speedup {:.2}x",
            format!("{:?}", act.opt),
            act.region,
            format!("{:?}", discriminant_name(&r.signal)),
            env.state.speedup
        );
        step += 1;
    }

    println!("\nbest speedup: {:.2}x over PyTorch Eager", env.state.best_speedup);
    println!("\n--- generated pseudo-Triton ---\n{}",
             render(&env.state.best_program, &task.graph, &shapes,
                    TargetLang::Triton));
}

fn discriminant_name(s: &qimeng_mtmc::env::StepSignal) -> &'static str {
    use qimeng_mtmc::env::StepSignal::*;
    match s {
        CompileFail => "compile-fail",
        WrongResult => "wrong-result",
        Rejected => "rejected",
        Correct { .. } => "ok",
        Stop { .. } => "stop",
    }
}
