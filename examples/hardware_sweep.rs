//! Hardware portability demo (the paper's cross-GPU generalization
//! claim): optimize the same tasks for V100, A100 and H100 and show how
//! the chosen schedules — and the resulting speedups — differ per
//! architecture (e.g. PipelineAsync is illegal on Volta; tile sizes track
//! shared-memory capacity).
//!
//! ```bash
//! cargo run --release --example hardware_sweep
//! ```

use qimeng_mtmc::env::{EnvConfig, OptimEnv};
use qimeng_mtmc::eval::{evaluate, EvalCfg, MacroKind, Method};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::graph::infer_shapes;
use qimeng_mtmc::microcode::{LlmProfile, ProfileId};
use qimeng_mtmc::report::Table;
use qimeng_mtmc::tasks::kernelbench_level;
use qimeng_mtmc::transform::{apply_action, decode_action, STOP_ACTION};

fn main() {
    // -- part 1: one matmul task, schedule story per GPU ----------------
    let tasks = kernelbench_level(1);
    let task = tasks.iter().find(|t| t.id.contains("matmul")).unwrap();
    let shapes = infer_shapes(&task.graph);
    println!("schedule chosen for {} per GPU:\n", task.id);
    for spec in GpuSpec::all() {
        let mut env = OptimEnv::new(task, spec.clone(),
                                    LlmProfile::get(ProfileId::GeminiPro25),
                                    EnvConfig::default(), 7);
        let mut failed: std::collections::HashSet<usize> = Default::default();
        while !env.state.done {
            let mask = env.mask();
            let best = (0..STOP_ACTION)
                .filter(|&a| mask[a] && !failed.contains(&a))
                .filter_map(|a| {
                    apply_action(&env.state.program, &task.graph, &shapes,
                                 &decode_action(a), &spec, 1.0)
                        .ok()
                        .map(|p| (a, qimeng_mtmc::gpusim::program_time_us(
                            &p, &task.graph, &shapes, &spec)))
                })
                .min_by(|x, y| x.1.partial_cmp(&y.1).unwrap());
            let now = env.eager_us / env.state.speedup;
            match best {
                Some((a, t)) if t < now * 0.99 => {
                    let before = env.state.path_hash;
                    env.step(a);
                    if env.state.path_hash == before {
                        failed.insert(a);
                    } else {
                        failed.clear();
                    }
                }
                _ => {
                    env.step(STOP_ACTION);
                }
            }
        }
        let k = &env.state.best_program.kernels[0];
        println!(
            "  {:<5} tile {:?} reg {:?} pipeline {} order {:?} vec {}  \
             -> {:.2}x",
            spec.name,
            k.schedule.block_tile,
            k.schedule.reg_tile,
            k.schedule.pipeline_depth,
            k.schedule.loop_order,
            k.schedule.vector_width,
            env.state.best_speedup
        );
    }

    // -- part 2: suite-level consistency across GPUs ---------------------
    println!("\nKernelBench L2 subset across GPUs (MTMC greedy):\n");
    let l2: Vec<_> = kernelbench_level(2).into_iter().step_by(5).collect();
    let mut table = Table::new(
        "MTMC across hardware (20 L2 tasks)",
        &["GPU", "Accuracy(%)", "Mean Speedup"],
    );
    for spec in GpuSpec::all() {
        let r = evaluate(
            &Method::Mtmc {
                macro_kind: MacroKind::GreedyLookahead,
                micro: ProfileId::GeminiPro25,
            },
            &l2, &spec, &EvalCfg::default(),
        );
        table.row(vec![
            spec.name.to_string(),
            format!("{:.0}", r.metrics.exec_acc * 100.0),
            format!("{:.2}", r.metrics.mean_speedup),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\nnote: Volta picks depth-2 pipelines (no cp.async), Hopper fits \
         bigger smem tiles — the paper's 'universal optimization \
         strategies' story."
    );
}
