//! End-to-end driver (the repository's required E2E validation): proves
//! all three layers compose on a real small workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```
//!
//! 1. Generates an offline trajectory dataset on the training corpus
//!    (tree-structured environment, paper §4.2).
//! 2. PPO-trains the Macro-Thinking policy **through the AOT artifacts**:
//!    rollouts sample actions from the L2/L1 network via PJRT (`fwd_b1`),
//!    updates run the fused PPO+Adam `train_step` — python is never
//!    executed. Logs the reward/entropy curves.
//! 3. Evaluates the trained policy on held-out KernelBench subsets
//!    against the greedy surrogate and a baseline LLM, reporting the
//!    paper's metrics.
//!
//! Scale knobs (defaults run in a few minutes):
//!   E2E_TASKS=24 E2E_ITERS=30 E2E_EVAL=20

use anyhow::{Context, Result};
use qimeng_mtmc::dataset::{generate, DatasetCfg};
use qimeng_mtmc::engine::Session;
use qimeng_mtmc::eval::{evaluate, EvalCfg, MacroKind, Method};
use qimeng_mtmc::gpusim::GpuSpec;
use qimeng_mtmc::microcode::ProfileId;
use qimeng_mtmc::paths;
use qimeng_mtmc::runtime::{save_params, ParamSet, PjrtRuntime, TrainState};
use qimeng_mtmc::tasks::{kernelbench_level, training_corpus};
use qimeng_mtmc::train::{train_ppo, PpoCfg};

fn envnum(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let n_tasks = envnum("E2E_TASKS", 24);
    let iters = envnum("E2E_ITERS", 30);
    let n_eval = envnum("E2E_EVAL", 20);
    let spec = GpuSpec::a100();

    // one session for the whole driver: dataset generation and PPO
    // rollouts pool transitions in the same memo trio
    let session = Session::default();

    println!("== [1/3] offline dataset over the training corpus ==");
    let corpus = training_corpus(n_tasks);
    let ds_cfg = DatasetCfg { per_task: 16, ..Default::default() };
    let t0 = std::time::Instant::now();
    let (_trajs, stats) =
        generate(&corpus, &spec, ProfileId::GeminiFlash25, &ds_cfg, &session);
    println!(
        "{} trajectories / {} steps in {:.1}s ({:.0} steps/s); \
         correct-step rate {:.0}%, mean final speedup {:.2}x\n",
        stats.trajectories,
        stats.steps,
        t0.elapsed().as_secs_f64(),
        stats.steps as f64 / t0.elapsed().as_secs_f64(),
        stats.correct_step_frac * 100.0,
        stats.mean_final_speedup
    );

    println!("== [2/3] PPO training through the PJRT artifacts ==");
    let rt = PjrtRuntime::load(&paths::artifacts_dir())
        .context("artifacts missing — run `make artifacts` first")?;
    println!("PJRT platform: {} | obs_dim {} act_dim {} train_batch {}",
             rt.platform(), rt.meta.obs_dim, rt.meta.act_dim,
             rt.meta.train_batch);
    let params = ParamSet::init(&rt.meta.raw, 0x5EED)?;
    println!("policy parameters: {}", params.num_params());
    let mut state = TrainState::new(params);
    let cfg = PpoCfg { iterations: iters, ..Default::default() };
    let t0 = std::time::Instant::now();
    let logs = train_ppo(&rt, &mut state, &corpus, &spec, &cfg, &session)?;
    println!("\nreward curve (iteration, mean episode reward, speedup):");
    for l in logs.iter().step_by((logs.len() / 10).max(1)) {
        println!("  iter {:>3}  reward {:+.3}  final speedup {:.2}x  \
                  entropy {:.3}",
                 l.iter, l.mean_episode_reward, l.mean_final_speedup,
                 l.entropy);
    }
    let first = &logs[0];
    let last = logs.last().unwrap();
    println!(
        "\ntrained {} iters in {:.1}s: reward {:+.3} -> {:+.3}, \
         rollout speedup {:.2}x -> {:.2}x",
        logs.len(), t0.elapsed().as_secs_f64(),
        first.mean_episode_reward, last.mean_episode_reward,
        first.mean_final_speedup, last.mean_final_speedup
    );
    let ppath = paths::default_policy_path();
    save_params(&state.params, &ppath)?;
    println!("saved policy to {}\n", ppath.display());

    println!("== [3/3] evaluation on KernelBench subsets ==");
    let cfg = EvalCfg::default();
    for level in 1..=3usize {
        let tasks: Vec<_> = kernelbench_level(level)
            .into_iter()
            .take(n_eval)
            .collect();
        let learned = evaluate(
            &Method::Mtmc {
                macro_kind: MacroKind::LearnedOrGreedy {
                    params_path: Some(ppath.clone()),
                },
                micro: ProfileId::GeminiPro25,
            },
            &tasks, &spec, &cfg,
        );
        let baseline = evaluate(
            &Method::Baseline { profile: ProfileId::Claude4Sonnet },
            &tasks, &spec, &cfg,
        );
        println!(
            "L{level}: MTMC(learned) acc {:>3.0}% speedup {:.2}x | \
             Claude-4 baseline acc {:>3.0}% speedup {:.2}x",
            learned.metrics.exec_acc * 100.0,
            learned.metrics.mean_speedup,
            baseline.metrics.exec_acc * 100.0,
            baseline.metrics.mean_speedup,
        );
    }
    println!("\n(record of this run lives in EXPERIMENTS.md §E2E)");
    Ok(())
}
